"""Perf-floor gate: fail CI when the O(change) rows regress badly.

Compares a fresh ``--quick`` benchmark JSON against the committed
``BENCH_xtable.quick.json`` baseline and exits non-zero when any guarded
row is more than ``--factor`` (default 3x) slower than its baseline.  The
guarded rows are the ones that encode the architectural guarantees this
repo's PRs established — the transactional backlog drain (``drain.*.txn``),
the pipelined write path (``write_pipeline.*``), the executor's FULL
bootstrap concurrency (``executor.full.*``), and the sharded sync fleet
(``fleet.*``) — so silently reverting to a per-commit, serial-write, or
single-worker code path fails the job even though every correctness test
would still pass.

The factor is deliberately loose: CI runners are noisy, and the guarded
speedups are ~4x+, so a 3x regression means the mechanism is gone, not
that the machine was busy.  Rows present on only one side are ignored
(new benchmarks should not fail the gate retroactively), but an EMPTY
intersection fails — a renamed row must update the baseline knowingly.

On top of the wall-clock floors, *speedup* floors check the fresh run's
own derived ``speedup=`` column: ``executor.full.concurrent`` must beat
its serial arm (>= 1.0x) — the concurrent bootstrap path regressing to
slower-than-serial is exactly the failure mode PR 6 fixed, and it is
invisible to a pure us-per-call comparison when both arms drift together.

*Request-pair* floors compare derived ``reqs=`` censuses between two rows
of the NEW run: ``restart.cold`` must spend materially more storage
requests than ``restart.warm`` — the checkpoint warm restart staying
O(new commits) while the cold one rebuilds O(history) is the whole point
of the durable-checkpoint subsystem, and it is a counter invariant, so it
holds on any machine at any load.

*Read-plane* floors check the snapshot server's own derived counters on
the ``read_plane.readers.n64`` row (present in both quick and full
shapes): ``hit_rate`` must stay >= 0.9 (the fleet is served from the
not-modified path / snapshot LRU, not per-reader replays) and
``reqs_per_reader`` must stay <= 0.5 (storage requests amortize across
the fleet instead of scaling with it).  Both are counter invariants —
losing the conditional-GET or single-flight machinery makes every reader
pay its own probe+replay, blowing through either bound on any machine.

*Byte-pair* floors compare derived ``bytes=`` censuses between two rows
of the NEW run: ``read_plane.scan.wide_full`` must fetch >= 3x the bytes
of ``read_plane.scan.projected`` — a scan projecting 2 of 16 columns
through the CHK3 column-offset index moves ~1/8 of the body bytes, and
losing the ranged-read path (falling back to full bodies) makes the two
censuses equal, which any floor > 1 catches on any machine.

Usage: ``python benchmarks/check_floor.py NEW.json --baseline OLD.json``
"""

import argparse
import fnmatch
import json
import re
import sys

GUARDED = ("drain.*.txn", "write_pipeline.*", "executor.full.*", "fleet.*",
           "read_plane.readers.*")
# derived-metric rows (counters, not wall time) are not floor-checked
EXCLUDE = ("write_pipeline.head_reads.*",)
# row -> minimum value of its derived "speedup=N.NNx" column, checked on
# the NEW run alone (both arms measured in the same process, so this floor
# is immune to machine-speed drift)
SPEEDUP_FLOORS = {"executor.full.concurrent": 1.0}
# (cheap row, expensive row) -> minimum expensive/cheap ratio of their
# derived "reqs=N" censuses, checked on the NEW run alone (counters are
# load-immune).  The quick shape's history is shallow, so the floor sits
# well under the full run's ~4x — losing the checkpoint resume path makes
# the two censuses EQUAL, which any floor > 1 catches.
REQUEST_PAIR_FLOORS = {("restart.warm", "restart.cold"): 1.4}
# read-plane row -> (minimum "hit_rate=", maximum "reqs_per_reader=") of
# its derived column, checked on the NEW run alone (counters, load-immune)
READ_PLANE_FLOORS = {"read_plane.readers.n64": (0.9, 0.5)}
# (cheap row, expensive row) -> minimum expensive/cheap ratio of their
# derived "bytes=" censuses, checked on the NEW run alone: the projected
# scan must keep moving a small fraction of the full scan's bytes
BYTES_PAIR_FLOORS = {
    ("read_plane.scan.projected", "read_plane.scan.wide_full"): 3.0}


def load_rows(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    return {r["name"]: (float(r["us"]), r.get("derived", ""))
            for r in data.get("rows", [])}


def guarded(name: str) -> bool:
    return any(fnmatch.fnmatch(name, g) for g in GUARDED) and \
        not any(fnmatch.fnmatch(name, e) for e in EXCLUDE)


def parse_speedup(derived: str) -> float | None:
    m = re.search(r"speedup=([0-9.]+)x", derived)
    return float(m.group(1)) if m else None


def parse_reqs(derived: str) -> int | None:
    m = re.search(r"reqs=([0-9]+)\b", derived)
    return int(m.group(1)) if m else None


def parse_named_float(derived: str, key: str) -> float | None:
    m = re.search(rf"{key}=([0-9.]+)\b", derived)
    return float(m.group(1)) if m else None


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("new", help="freshly produced quick-bench JSON")
    ap.add_argument("--baseline", default="BENCH_xtable.quick.json",
                    help="committed baseline JSON (default: the tracked "
                         "BENCH_xtable.quick.json)")
    ap.add_argument("--factor", type=float, default=3.0,
                    help="fail when new > factor * baseline (default 3)")
    args = ap.parse_args(argv)

    new, base = load_rows(args.new), load_rows(args.baseline)
    checked, failures = 0, []
    for name, (base_us, _) in sorted(base.items()):
        if not guarded(name) or name not in new:
            continue
        checked += 1
        new_us = new[name][0]
        ratio = new_us / max(base_us, 1e-9)
        status = "FAIL" if ratio > args.factor else "ok"
        print(f"{status:4s} {name}: {new_us:.1f}us vs baseline "
              f"{base_us:.1f}us ({ratio:.2f}x)")
        if ratio > args.factor:
            failures.append(name)

    for name, floor in sorted(SPEEDUP_FLOORS.items()):
        if name not in new:
            continue
        checked += 1
        speedup = parse_speedup(new[name][1])
        if speedup is None:
            print(f"FAIL {name}: no speedup= in derived column "
                  f"({new[name][1]!r})")
            failures.append(name)
            continue
        status = "FAIL" if speedup < floor else "ok"
        print(f"{status:4s} {name}: speedup={speedup:.2f}x "
              f"(floor {floor:.2f}x)")
        if speedup < floor:
            failures.append(name)

    for (cheap, dear), floor in sorted(REQUEST_PAIR_FLOORS.items()):
        if cheap not in new or dear not in new:
            continue
        checked += 1
        a, b = parse_reqs(new[cheap][1]), parse_reqs(new[dear][1])
        if not a or b is None:
            print(f"FAIL {cheap}/{dear}: no reqs= in derived columns")
            failures.append(f"{cheap}/{dear}")
            continue
        ratio = b / a
        status = "FAIL" if ratio < floor else "ok"
        print(f"{status:4s} {dear} vs {cheap}: reqs {b} vs {a} "
              f"({ratio:.2f}x, floor {floor:.2f}x)")
        if ratio < floor:
            failures.append(f"{cheap}/{dear}")

    for (cheap, dear), floor in sorted(BYTES_PAIR_FLOORS.items()):
        if cheap not in new or dear not in new:
            continue
        checked += 1
        a = parse_named_float(new[cheap][1], "bytes")
        b = parse_named_float(new[dear][1], "bytes")
        if not a or b is None:
            print(f"FAIL {cheap}/{dear}: no bytes= in derived columns")
            failures.append(f"{cheap}/{dear}")
            continue
        ratio = b / a
        status = "FAIL" if ratio < floor else "ok"
        print(f"{status:4s} {dear} vs {cheap}: bytes {b:.0f} vs {a:.0f} "
              f"({ratio:.2f}x, floor {floor:.2f}x)")
        if ratio < floor:
            failures.append(f"{cheap}/{dear}")

    for name, (hit_floor, rpr_ceiling) in sorted(READ_PLANE_FLOORS.items()):
        if name not in new:
            continue
        checked += 1
        hit = parse_named_float(new[name][1], "hit_rate")
        rpr = parse_named_float(new[name][1], "reqs_per_reader")
        if hit is None or rpr is None:
            print(f"FAIL {name}: no hit_rate=/reqs_per_reader= in derived "
                  f"column ({new[name][1]!r})")
            failures.append(name)
            continue
        bad = hit < hit_floor or rpr > rpr_ceiling
        status = "FAIL" if bad else "ok"
        print(f"{status:4s} {name}: hit_rate={hit:.3f} (floor "
              f"{hit_floor:.2f}) reqs_per_reader={rpr:.3f} "
              f"(ceiling {rpr_ceiling:.2f})")
        if bad:
            failures.append(name)

    if checked == 0:
        print("# perf floor: no guarded rows matched between "
              f"{args.new} and {args.baseline}", file=sys.stderr)
        sys.exit(1)
    if failures:
        print(f"# perf floor: {len(failures)} of {checked} guarded rows "
              f"failed: {failures}", file=sys.stderr)
        sys.exit(1)
    print(f"# perf floor: {checked} guarded rows within {args.factor}x "
          f"of baseline")


if __name__ == "__main__":
    main()
