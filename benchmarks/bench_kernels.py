"""Kernel micro-benchmarks (interpret-mode correctness + XLA-path timing on
host; on TPU these run the Pallas path)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

# --quick smoke mode (set by benchmarks.run): single timed iteration
QUICK = False


def _time(fn, *args, iters=3):
    if QUICK:
        iters = 1
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters


def bench_attention_xla(report):
    from repro.models.layers import blocked_attention
    key = jax.random.PRNGKey(0)
    b, s, h, kv, dh = 2, 1024, 8, 2, 64
    q = jax.random.normal(key, (b, s, h, dh), jnp.float32)
    k = jax.random.normal(key, (b, s, kv, dh), jnp.float32)
    fn = jax.jit(lambda q, k, v: blocked_attention(q, k, v, q_blocks=8))
    dt = _time(fn, q, k, k)
    flops = 4 * b * h * dh * s * s * 9 / 16
    report("attn.xla_blocked_1k", dt * 1e6, f"{flops / dt / 1e9:.1f}GFLOP/s")


def bench_ssd_xla(report):
    from repro.kernels.ssd.ref import ssd_ref
    key = jax.random.PRNGKey(0)
    b, s, h, p, n = 2, 512, 8, 32, 32
    x = jax.random.normal(key, (b, s, h, p))
    dt_ = jax.nn.softplus(jax.random.normal(key, (b, s, h)))
    A = -jnp.exp(jax.random.normal(key, (h,)) * 0.3)
    B = jax.random.normal(key, (b, s, 1, n))
    fn = jax.jit(lambda *a: ssd_ref(*a)[0])
    dt = _time(fn, x, dt_, A, B, B)
    report("ssd.ref_seq_512", dt * 1e6, "sequential oracle")


def bench_moe_dispatch(report):
    from repro.models.layers import moe_mlp
    from repro.configs import smoke_config
    from repro.models.param import init_params
    from repro.models.layers import moe_template
    from dataclasses import replace
    key = jax.random.PRNGKey(0)
    cfg = replace(smoke_config("dbrx-132b"), d_model=128, d_ff=256,
                  n_experts=8, top_k=2)
    p = init_params(moe_template(cfg), key)
    x = jax.random.normal(key, (4, 512, 128), jnp.bfloat16)
    fn = jax.jit(lambda x, p: moe_mlp(x, p, cfg)[0])
    dt = _time(fn, x, p)
    report("moe.dispatch_gshard", dt * 1e6, "sort+gather combine")


ALL = [bench_attention_xla, bench_ssd_xla, bench_moe_dispatch]
