"""Benchmark harness: ``PYTHONPATH=src python -m benchmarks.run``.

Prints ``name,us_per_call,derived`` CSV — one family per paper claim
(translation overhead / incrementality / omni-direction / scaling) plus the
compute-layer micro-benches. The roofline table (per arch x shape x mesh)
is produced separately by ``repro.launch.dryrun`` + ``repro.launch.roofline``
from compiled artifacts.
"""

import sys


def main() -> None:
    from benchmarks import bench_kernels, bench_xtable

    rows = []

    def report(name: str, us: float, derived: str = "") -> None:
        rows.append((name, us, derived))
        print(f"{name},{us:.1f},{derived}", flush=True)

    print("name,us_per_call,derived")
    for mod in (bench_xtable, bench_kernels):
        for bench in mod.ALL:
            try:
                bench(report)
            except Exception as e:  # keep the harness honest but resilient
                print(f"{mod.__name__}.{bench.__name__},FAIL,{e}",
                      file=sys.stderr)
                raise
    print(f"# {len(rows)} benchmarks ok", file=sys.stderr)


if __name__ == "__main__":
    main()
