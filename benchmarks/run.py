"""Benchmark harness: ``PYTHONPATH=src python -m benchmarks.run``.

Prints ``name,us_per_call,derived`` CSV — one family per paper claim
(translation overhead / incrementality / omni-direction / scaling / backlog
drain) plus the compute-layer micro-benches — and writes the same rows as
machine-readable ``BENCH_xtable.json`` (``{"rows": [{name, us, derived}]}``)
so the perf trajectory can be tracked across PRs.

``--filter SUBSTR`` runs only the benchmark functions whose name contains
SUBSTR (e.g. ``--filter drain``).  ``--quick`` is the CI smoke mode: every
sweep shrinks to its smallest shape so the whole harness proves itself in
seconds (results go to ``BENCH_xtable.quick.json`` — a smoke run never
clobbers the full record).  ``--out PATH`` moves the JSON artifact.

The harness is a CI *gate*: a benchmark that raises, or that completes
without reporting a single row, marks the run failed — every other
benchmark still runs (and the JSON of the surviving rows is still
written), but the process exits non-zero, so a broken bench can never
hide behind a partial artifact.
The roofline table (per arch x shape x mesh) is produced separately by
``repro.launch.dryrun`` + ``repro.launch.roofline`` from compiled artifacts.
"""

import argparse
import json
import os
import sys

# support both invocations: ``python -m benchmarks.run`` (repo root already
# importable) and ``python benchmarks/run.py`` (sys.path[0] is benchmarks/)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--filter", default="",
                    help="only run benchmark functions whose name contains "
                         "this substring")
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode: minimal sweep sizes, smallest tables")
    ap.add_argument("--out", default=None,
                    help="where to write the machine-readable results "
                         "(default: BENCH_xtable.json; a --filter or "
                         "--quick run writes BENCH_xtable.partial.json / "
                         "BENCH_xtable.quick.json so a partial sweep never "
                         "clobbers the full record)")
    args = ap.parse_args(argv)
    if args.out is None:
        args.out = ("BENCH_xtable.quick.json" if args.quick
                    else "BENCH_xtable.partial.json" if args.filter
                    else "BENCH_xtable.json")

    from benchmarks import bench_kernels, bench_xtable

    if args.quick:
        for mod in (bench_xtable, bench_kernels):
            if hasattr(mod, "QUICK"):
                mod.QUICK = True

    rows = []

    def report(name: str, us: float, derived: str = "") -> None:
        rows.append({"name": name, "us": round(us, 1), "derived": derived})
        print(f"{name},{us:.1f},{derived}", flush=True)

    print("name,us_per_call,derived")
    ran = 0
    failures = []
    for mod in (bench_xtable, bench_kernels):
        for bench in mod.ALL:
            if args.filter and args.filter not in bench.__name__:
                continue
            ran += 1
            name = f"{mod.__name__}.{bench.__name__}"
            rows_before = len(rows)
            try:
                bench(report)
            except Exception as e:  # finish the sweep, but fail the run
                print(f"{name},FAIL,{e}", file=sys.stderr)
                failures.append(f"{name}: {type(e).__name__}: {e}")
                continue
            if len(rows) == rows_before:
                # a bench that "succeeds" without measuring anything is
                # broken too — an empty artifact must not gate green
                failures.append(f"{name}: reported no rows")
    if ran == 0:
        failures.append(f"no benchmark matched --filter {args.filter!r}")
    with open(args.out, "w") as f:
        json.dump({"rows": rows}, f, indent=1)
    if failures:
        print(f"# FAILED {len(failures)} of {ran} benchmarks "
              f"({len(rows)} rows) -> {args.out}", file=sys.stderr)
        for line in failures:
            print(f"#   {line}", file=sys.stderr)
        sys.exit(1)
    print(f"# {ran} benchmarks ok ({len(rows)} rows) -> {args.out}",
          file=sys.stderr)


if __name__ == "__main__":
    main()
