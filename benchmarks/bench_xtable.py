"""Benchmarks for the paper's claims (one per claim; the paper is a demo
paper without numbered tables, so each benchmark pins one §3 property):

* low-overhead   — metadata-only translation vs. full data rewrite
* incremental    — commit-by-commit sync cost vs. full re-sync, scaling in
                   the number of NEW commits (staleness minimization)
* omni-direction — the full 6-cell (source, target) sync matrix
* scaling        — translation cost vs. number of data files (metadata size)
* checkpoints    — LST checkpoint save / XTable sync / restore throughput
* concurrency    — the planner/executor architecture: a multi-dataset
                   2-target matrix synced serially vs. on the thread pool
* backlog drain  — O(change) target writes: per-commit vs. transactional
                   vs. coalesced drain of an N-commit backlog, with
                   counting-FS reads/writes alongside wall-clock
* object store   — the same drain against a simulated object store:
                   RTT sweep x sequential vs. batched metadata fetch,
                   with instrumented request counters
* continuous     — the always-on daemon: steady-state freshness lag and
                   per-cycle storage requests for poll-drain cycles vs.
                   one-shot full resyncs under a scripted append workload
* write pipeline — the drain's WRITE side: serial puts vs. staged
                   non-commit objects flushed in pipelined write_many
                   rounds (RTT sweep, serial round-trips per commit), plus
                   the daemon's per-cycle head memoization (source-head
                   reads per changed cycle, 3 -> 1)
* chunk codec    — the chunkfile string-column codec: vectorized
                   fixed-width C casts vs. the legacy per-string msgpack
                   loop (encode + decode)
* fleet          — the sharded sync fleet: one-cycle lag-drain throughput
                   over ~1k tiered tables at 1 / 2 / 4 workers, and
                   lag-aware (urgency) vs. FIFO scheduling under a
                   maxUnitsPerCycle drain budget (hot-tier p50/p99 lag)
* warm restart   — crash-safe restart cost: a restarted daemon resuming a
                   64-commit table from the durable checkpoint (O(new
                   commits)) vs. a cold restart that rebuilds the whole
                   source index (O(history)), over a 10 ms-RTT store,
                   wall clock + storage-request census
* read plane     — the snapshot-serving read plane: reader fleets
                   (64/512/2048 at 10 ms RTT) conditionally reading an
                   actively syncing table's translated view (p99 latency,
                   snapshot hit rate, storage reqs/reader), stats-footer
                   scan pruning (pruned vs. full scanned bytes, cached-
                   footer re-scan), and CHK3 columnar projection pushdown
                   (full vs. projected vs. late-materialized scans of a
                   16-column table: fetched-byte census)
"""

from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.core import MetadataCache, SyncConfig, run_sync
from repro.lst import LakeTable, LocalFS, MemoryFS
from repro.lst.schema import Field, PartitionSpec, Schema
from repro.lst.storage import RetryPolicy, StorageProfile, layer_fs

SCHEMA = Schema([Field("k", "int64"), Field("part", "string"),
                 Field("val", "float64")])
FORMATS = ("delta", "iceberg", "hudi")

# --quick smoke mode (set by benchmarks.run): shrink every sweep so the
# whole harness proves itself in seconds instead of minutes
QUICK = False


def _mk_table(fs, fmt: str, n_commits: int, rows_per_commit: int = 2048):
    base = tempfile.mkdtemp() + "/t"
    t = LakeTable.create(fs, base, SCHEMA, fmt, PartitionSpec(["part"]))
    rng = np.random.default_rng(0)
    for c in range(n_commits):
        n = rows_per_commit
        t.append({"k": rng.integers(0, 1 << 30, n),
                  "part": np.array([f"p{i % 4}" for i in range(n)]),
                  "val": rng.random(n)})
    return base, t


def _sync(fs, base, src, targets):
    cfg = SyncConfig.from_dict({
        "sourceFormat": src.upper(),
        "targetFormats": [t.upper() for t in targets],
        "datasets": [{"tableBasePath": base}]})
    t0 = time.perf_counter()
    res = run_sync(cfg, fs)
    dt = time.perf_counter() - t0
    assert all(r.ok for r in res), res
    return dt, res


def bench_low_overhead(report):
    """Translation (metadata-only) vs. rewriting the data into the target."""
    fs = LocalFS()
    base, t = _mk_table(fs, "hudi", n_commits=4 if QUICK else 8)
    data_bytes = t.state().total_bytes()
    dt_sync, _ = _sync(fs, base, "hudi", ["delta"])
    # the rewrite alternative: read all rows + write a new delta table
    t0 = time.perf_counter()
    rows = t.read_all()
    base2 = tempfile.mkdtemp() + "/copy"
    t2 = LakeTable.create(fs, base2, SCHEMA, "delta", PartitionSpec(["part"]))
    t2.append(rows)
    dt_rewrite = time.perf_counter() - t0
    report("low_overhead.translate", dt_sync * 1e6,
           f"{data_bytes / 2**20:.1f}MiB data untouched")
    report("low_overhead.rewrite", dt_rewrite * 1e6,
           f"speedup={dt_rewrite / max(dt_sync, 1e-9):.1f}x")


def bench_incremental_vs_full(report):
    """Cost of syncing k new commits incrementally vs. full re-sync."""
    fs = LocalFS()
    base, t = _mk_table(fs, "delta", n_commits=4 if QUICK else 16,
                        rows_per_commit=512)
    _sync(fs, base, "delta", ["iceberg"])          # bootstrap
    for k in (1,) if QUICK else (1, 4, 16):
        rng = np.random.default_rng(k)
        for _ in range(k):
            t.append({"k": rng.integers(0, 99, 64),
                      "part": np.array([f"p{i % 4}" for i in range(64)]),
                      "val": rng.random(64)})
        dt_inc, res = _sync(fs, base, "delta", ["iceberg"])
        assert res[0].mode == "INCREMENTAL"
        report(f"incremental.k{k}", dt_inc * 1e6,
               f"{res[0].commits_synced} commits")
    # full re-sync of the same table into a fresh format for comparison
    dt_full, _ = _sync(fs, base, "delta", ["hudi"])
    report("incremental.full_resync", dt_full * 1e6,
           f"{len(t.state().files)} files")


def bench_omni_matrix(report):
    """All 6 (source -> target) directions translate correctly + timing."""
    fs = LocalFS()
    for src in FORMATS:
        base, t = _mk_table(fs, src, n_commits=2 if QUICK else 4,
                            rows_per_commit=512)
        want = t.state().total_records()
        targets = [f for f in FORMATS if f != src]
        dt, _ = _sync(fs, base, src, targets)
        for tgt in targets:
            got = LakeTable.open(fs, base, tgt).state().total_records()
            assert got == want, (src, tgt)
        report(f"omni.{src}->both", dt * 1e6, f"{want} rows")


def bench_file_count_scaling(report):
    """Translation cost vs. number of data files (metadata volume)."""
    fs = LocalFS()
    for n_commits in (4,) if QUICK else (4, 16, 64):
        base, t = _mk_table(fs, "hudi", n_commits=n_commits,
                            rows_per_commit=64)
        dt, _ = _sync(fs, base, "hudi", ["iceberg"])
        report(f"scaling.files{4 * n_commits}", dt * 1e6,
               f"{len(t.state().files)} files")


def bench_checkpoint_throughput(report):
    import jax.numpy as jnp
    from repro.checkpoint import LSTCheckpointManager
    fs = LocalFS()
    base = tempfile.mkdtemp() + "/ckpt"
    mgr = LSTCheckpointManager(fs, base, fmt="hudi",
                               sync_targets=("iceberg",))
    tree = {f"layer{i}": jnp.ones((256, 256), jnp.float32) * i
            for i in range(8)}
    nbytes = 8 * 256 * 256 * 4
    t0 = time.perf_counter()
    mgr.save(1, tree)
    dt_save = time.perf_counter() - t0
    t0 = time.perf_counter()
    _, back = mgr.restore(fmt="iceberg")
    dt_restore = time.perf_counter() - t0
    report("ckpt.save+sync", dt_save * 1e6,
           f"{nbytes / 2**20:.0f}MiB {nbytes / dt_save / 2**20:.0f}MiB/s")
    report("ckpt.restore_via_iceberg", dt_restore * 1e6,
           f"{nbytes / dt_restore / 2**20:.0f}MiB/s")


def bench_serial_vs_concurrent(report):
    """Planner/executor payoff: N datasets x 2 targets, FULL bootstrap and
    an incremental backlog, synced serially (max_workers=1) vs. on the
    auto-sized thread pool.  Same plan, same units — only the execution
    strategy moves.

    The measured regime is the one the concurrency targets: a simulated
    object store (2ms RTT), where every unit is dominated by round trips
    the pool overlaps.  Against zero-RTT local storage the units are pure
    CPU-bound metadata translation, and the executor's auto sizing caps
    the pool at the core count instead of convoying 8 threads on the GIL
    (the sub-1x "concurrent" regression this row used to measure).
    """
    n_ds = 2 if QUICK else 4

    def build_fleet(raw):
        bases = []
        rng = np.random.default_rng(0)
        for i in range(n_ds):
            base = f"bkt/sc{i}"
            t = LakeTable.create(raw, base, SCHEMA, "delta",
                                 PartitionSpec(["part"]),
                                 {"delta.checkpointInterval": "100000"})
            for _ in range(4 if QUICK else 8):
                n = 256
                t.append({"k": rng.integers(0, 1 << 30, n),
                          "part": np.array([f"p{i % 4}" for i in range(n)]),
                          "val": rng.random(n)})
            bases.append((base, t))
        return bases

    def backlog(bases):
        rng = np.random.default_rng(1)
        for _, t in bases:
            for _ in range(6):
                n = 128
                t.append({"k": rng.integers(0, 1 << 30, n),
                          "part": np.array([f"p{i % 4}" for i in range(n)]),
                          "val": rng.random(n)})

    times = {}
    for label, workers in (("serial", 1), ("concurrent", None)):
        raw = MemoryFS()
        bases = build_fleet(raw)
        cfg = SyncConfig.from_dict({
            "sourceFormat": "DELTA",
            "targetFormats": ["ICEBERG", "HUDI"],
            "datasets": [{"tableBasePath": b} for b, _ in bases]})
        fs = layer_fs(raw, profile=StorageProfile(rtt_ms=2,
                                                  pipeline_depth=16),
                      retry=RetryPolicy())
        t0 = time.perf_counter()
        res = run_sync(cfg, fs, max_workers=workers)
        times[f"full.{label}"] = time.perf_counter() - t0
        assert all(r.ok and r.mode == "FULL" for r in res), res
        backlog(bases)
        t0 = time.perf_counter()
        res = run_sync(cfg, fs, max_workers=workers)
        times[f"incr.{label}"] = time.perf_counter() - t0
        assert all(r.ok and r.mode == "INCREMENTAL" for r in res), res
    for phase in ("full", "incr"):
        s, c = times[f"{phase}.serial"], times[f"{phase}.concurrent"]
        report(f"executor.{phase}.serial", s * 1e6,
               f"{n_ds} datasets x 2 targets @2ms RTT")
        report(f"executor.{phase}.concurrent", c * 1e6,
               f"speedup={s / max(c, 1e-9):.2f}x")


class _CountingFS(LocalFS):
    """LocalFS counting read/write calls under a path prefix.

    fsync is off so the benchmark measures metadata-translation work, not
    disk flushes (identical in every strategy; object stores own durability).
    """

    def __init__(self):
        super().__init__(fsync=False)
        self.reads = {}
        self.writes = {}

    def read_bytes(self, path):
        self.reads[path] = self.reads.get(path, 0) + 1
        return super().read_bytes(path)

    def write_bytes(self, path, data, *, overwrite=False):
        self.writes[path] = self.writes.get(path, 0) + 1
        return super().write_bytes(path, data, overwrite=overwrite)

    def reset(self):
        self.reads, self.writes = {}, {}

    def count(self, table, prefix):
        p = f"{table}/{prefix}"
        return (sum(n for k, n in self.reads.items() if k.startswith(p)),
                sum(n for k, n in self.writes.items() if k.startswith(p)))


def bench_backlog_drain(report):
    """O(change) incremental sync: drain an N-commit backlog into iceberg
    per-commit (seed path: target state re-read every commit), inside one
    transaction (state read once, threaded through the drain), and coalesced
    (one net target commit).  Derived column shows target-side metadata
    reads/writes from a counting FS — the transactional drain's reads stay
    flat in N while the per-commit drain's grow ~quadratically."""
    strategies = (
        ("percommit", {"transactionalTargets": False}),
        ("txn", {}),
        ("coalesced", {"coalesceIncremental": True}),
    )
    from repro.core import MetadataCache

    def one_drain(n, kw):
        """Build table + backlog, time ONE drain; returns (dt, reads, writes).

        The target is grown the way a long-lived synced table grows — a
        FULL bootstrap plus a 32-commit incremental stretch (one manifest
        per synced commit) — so the per-commit path pays the realistic
        O(manifests) re-read every commit.  A continuous syncer holds its
        metadata cache across runs, so the timed region pays only the
        source tail refresh plus the drain."""
        fs = _CountingFS()
        base, t = _mk_table(fs, "delta", n_commits=4, rows_per_commit=64)
        d = {"sourceFormat": "DELTA", "targetFormats": ["ICEBERG"],
             "datasets": [{"tableBasePath": base}]}
        grow_cfg = SyncConfig.from_dict(d)       # same shape for every run
        cfg = SyncConfig.from_dict({**d, **kw})  # strategy under test
        cache = MetadataCache(fs)
        res = run_sync(grow_cfg, fs, cache=cache)
        assert res[0].ok and res[0].mode == "FULL"
        rng = np.random.default_rng(n)

        def backlog(k):
            for _ in range(k):
                t.append({"k": rng.integers(0, 1 << 30, 64),
                          "part": np.array([f"p{i % 4}" for i in range(64)]),
                          "val": rng.random(64)})

        backlog(32)                          # grow the target's history
        res = run_sync(grow_cfg, fs, cache=cache)
        assert res[0].ok and res[0].mode == "INCREMENTAL"
        backlog(n)                           # the measured backlog
        fs.reset()
        t0 = time.perf_counter()
        res = run_sync(cfg, fs, cache=cache)
        dt = time.perf_counter() - t0
        assert res[0].ok and res[0].mode == "INCREMENTAL"
        assert res[0].commits_synced == n
        return dt, *fs.count(base, "metadata")

    for n in (4,) if QUICK else (4, 16, 64):
        times = {}
        for label, kw in strategies:
            # best-of-3: repeats absorb cold-cache noise
            runs = [one_drain(n, kw) for _ in range(1 if QUICK else 3)]
            _, r, w = runs[0]
            dt = min(d for d, _, _ in runs)
            times[label] = dt
            speed = times["percommit"] / max(dt, 1e-9)
            report(f"drain.n{n}.{label}", dt * 1e6,
                   f"tgt_reads={r} tgt_writes={w} "
                   f"speedup={speed:.2f}x")


def bench_object_store_sync(report):
    """Incremental sync against a simulated object store: RTT sweep x
    sequential (pipeline_depth=1) vs batched metadata fetch.

    The measured run drains a 16-commit incremental backlog from a hudi
    source with a 48-commit pre-synced history into a delta target, as a
    fresh sync process (cold metadata cache) — how the XTable CLI actually
    runs — so the source-log replay is the dominant metadata-fetch cost and
    batching is what pipelines it.  The table is built and bootstrapped on
    the raw in-memory store (setup is not what's measured); only the timed
    sync goes through the latency-injecting wrapper.  Derived columns carry
    the instrumented request counters: total run requests, the unit's own
    census, and the batched arm's speedup over sequential at the same RTT.

    A final warm-cache row (a continuous syncer holding its metadata cache)
    pins the steady state: source reads O(new commits), target reads O(1).
    """
    backlog_n, history_n = 16, 8 if QUICK else 48
    rtts = (0, 10) if QUICK else (0, 5, 10, 20)

    def build(raw):
        base = "bkt/t"
        # checkpointing off so the delta target's transactional drain never
        # pays the one bounded snapshot read-back mid-measurement
        t = LakeTable.create(raw, base, SCHEMA, "hudi",
                             PartitionSpec(["part"]),
                             {"delta.checkpointInterval": "100000"})
        rng = np.random.default_rng(0)

        def grow(k):
            for _ in range(k):
                n = 64
                t.append({"k": rng.integers(0, 1 << 30, n),
                          "part": np.array([f"p{i % 4}" for i in range(n)]),
                          "val": rng.random(n)})

        cfg = SyncConfig.from_dict({
            "sourceFormat": "HUDI", "targetFormats": ["DELTA"],
            "datasets": [{"tableBasePath": "mem://bkt/t"}]})
        grow(4)
        res = run_sync(cfg, layer_fs(raw))
        assert res[0].ok and res[0].mode == "FULL"
        grow(history_n)                      # pre-synced history
        res = run_sync(cfg, layer_fs(raw))
        assert res[0].ok and res[0].mode == "INCREMENTAL"
        grow(backlog_n)                      # the measured backlog
        return cfg

    seq_dt = {}
    for rtt in rtts:
        for label, depth in (("seq", 1), ("batched", 16)):
            raw = MemoryFS()
            cfg = build(raw)
            fs = layer_fs(raw,
                          profile=StorageProfile(rtt_ms=rtt,
                                                 pipeline_depth=depth),
                          retry=RetryPolicy())
            t0 = time.perf_counter()
            res = run_sync(cfg, fs)
            dt = time.perf_counter() - t0
            assert res[0].ok and res[0].mode == "INCREMENTAL"
            assert res[0].commits_synced == backlog_n
            if label == "seq":
                seq_dt[rtt] = dt
            s = fs.stats()
            unit = res[0].storage_ops
            report(f"objstore.rtt{rtt}.{label}", dt * 1e6,
                   f"reqs={s.requests} get={s.get} put={s.put} "
                   f"unit_reqs={unit['requests']} "
                   f"speedup={seq_dt[rtt] / max(dt, 1e-9):.2f}x")

    # warm-cache steady state: a continuous syncer's cache makes the source
    # side O(new commits) and the target side O(1) per unit
    raw = MemoryFS()
    cfg = build(raw)
    fs = layer_fs(raw, profile=StorageProfile(rtt_ms=10, pipeline_depth=16),
                  retry=RetryPolicy())
    cache = MetadataCache(fs)
    assert run_sync(cfg, fs, cache=cache)[0].ok      # drains + builds cache
    t2 = LakeTable.open(raw, "bkt/t", "hudi")
    rng = np.random.default_rng(1)
    for _ in range(backlog_n):
        t2.append({"k": rng.integers(0, 99, 8, np.int64),
                   "part": np.array([f"p{i % 4}" for i in range(8)]),
                   "val": rng.random(8)})
    before = fs.stats().requests
    t0 = time.perf_counter()
    res = run_sync(cfg, fs, cache=cache)
    dt = time.perf_counter() - t0
    assert res[0].ok and res[0].commits_synced == backlog_n
    run_reqs = fs.stats().requests - before
    unit = res[0].storage_ops
    report("objstore.rtt10.warm.batched", dt * 1e6,
           f"reqs={run_reqs} (O(new)={backlog_n} source reads) "
           f"unit_reqs={unit['requests']} unit_get={unit['get']} (O(1) tgt)")


def bench_continuous_sync(report):
    """Always-on freshness: the daemon's poll-drain cycles vs. one-shot
    full resyncs under the same scripted append workload.

    A writer appends ``appends`` commits per round for ``rounds`` rounds.
    After each round the arm under test brings the iceberg target fresh:
    the daemon runs one watch -> replan -> drain cycle (warm shared
    metadata cache, tail-only refresh, O(1) head probes), while the
    one-shot arm re-runs a cold full resync — how cron-driven batch
    translation actually behaves.  Derived columns carry the per-cycle
    storage-request census and the freshness lag in commits right after
    the sync step (the steady-state staleness a reader observes); a final
    idle-cycle row pins the watch overhead of a quiet table.
    """
    from repro.core import ManualClock, SyncDaemon

    rounds = 3 if QUICK else 8
    appends = 2 if QUICK else 4

    def build():
        raw = MemoryFS()
        base = "bkt/cont"
        t = LakeTable.create(raw, base, SCHEMA, "delta",
                             PartitionSpec(["part"]),
                             {"delta.checkpointInterval": "100000"})
        rng = np.random.default_rng(0)

        def grow(k):
            for _ in range(k):
                n = 64
                t.append({"k": rng.integers(0, 1 << 30, n),
                          "part": np.array([f"p{i % 4}" for i in range(n)]),
                          "val": rng.random(n)})

        grow(4)
        return raw, base, grow

    def run_arm(step, fs, grow):
        """Per round: append, sync via ``step``, sample time/requests/lag."""
        times, reqs, lags = [], [], []
        for _ in range(rounds):
            grow(appends)
            before = fs.stats().requests
            t0 = time.perf_counter()
            lag_after = step()
            times.append(time.perf_counter() - t0)
            reqs.append(fs.stats().requests - before)
            lags.append(lag_after)
        return (sum(times) / rounds, sum(reqs) / rounds,
                sum(lags) / rounds)

    # -- poll-drain daemon: warm cache, head probes, tail-only refresh
    raw, base, grow = build()
    cfg = SyncConfig.from_dict({
        "sourceFormat": "DELTA", "targetFormats": ["ICEBERG"],
        "datasets": [{"tableBasePath": base}]})
    fs = layer_fs(raw)
    daemon = SyncDaemon(cfg, fs, clock=ManualClock())
    rep = daemon.run_cycle()                    # FULL bootstrap
    assert rep.units_drained == 1

    def daemon_step():
        rep = daemon.run_cycle()
        assert rep.commits_applied == appends, rep.summary()
        return rep.total_lag

    dt_d, rq_d, lag_d = run_arm(daemon_step, fs, grow)
    report("continuous.daemon_cycle", dt_d * 1e6,
           f"reqs/cycle={rq_d:.0f} lag={lag_d:.0f} commits "
           f"({appends} appends/round)")

    # idle steady state: a quiet table costs exactly one head probe
    before = fs.stats().requests
    t0 = time.perf_counter()
    rep = daemon.run_cycle()
    dt_idle = time.perf_counter() - t0
    idle_reqs = fs.stats().requests - before
    assert rep.idle and idle_reqs == 1
    report("continuous.daemon_idle_cycle", dt_idle * 1e6,
           f"reqs/cycle={idle_reqs} (head probe only)")

    # -- one-shot full resync: cold cache + FULL rewrite every round
    raw, base, grow = build()
    cfg_full = SyncConfig.from_dict({
        "sourceFormat": "DELTA", "targetFormats": ["ICEBERG"],
        "datasets": [{"tableBasePath": base}], "incremental": False})
    fs2 = layer_fs(raw)
    res = run_sync(cfg_full, fs2)
    assert res[0].ok and res[0].mode == "FULL"

    def full_step():
        res = run_sync(cfg_full, fs2)           # fresh cache: cold replay
        assert res[0].ok and res[0].mode == "FULL"
        return 0

    dt_f, rq_f, _lag = run_arm(full_step, fs2, grow)
    report("continuous.full_resync", dt_f * 1e6,
           f"reqs/cycle={rq_f:.0f} lag=0 commits "
           f"speedup={dt_f / max(dt_d, 1e-9):.1f}x vs daemon, "
           f"reqs {rq_f / max(rq_d, 1e-9):.1f}x")


def bench_write_pipeline(report):
    """The write-RTT term of a high-latency drain: a 16-commit transactional
    drain into iceberg + hudi, serial writes (``pipelineDepth: 1`` — every
    staged object pays its own round trip) vs. pipelined staged flushes
    (depth 16), swept over RTT.

    The drain runs as a fresh sync process over a pre-synced history; reads
    are batched identically in both arms (PR 3), so the spread is the write
    side: per commit the serial arm pays one RTT per object (manifests,
    manifest-lists, requested/inflight markers, per-commit hint moves),
    while the pipelined arm overlaps every non-commit object of the WHOLE
    chain in ~1 write_many round and pays serial RTTs only for the ordered
    commit-point puts.  Derived columns carry the simulated store's
    *serial round-trip* census (a batch of N counts ceil(N/depth)) per
    commit, and the speedup at the same RTT.

    A final row pins the daemon's per-cycle head memoization: source-head
    reads during one CHANGED cycle, legacy (probe + planner head read +
    refresh head read) vs. hinted (the probe IS the cycle's head read).
    """
    backlog_n, history_n = 16, 4 if QUICK else 16
    rtts = (0, 10) if QUICK else (0, 5, 10, 20)

    def build(raw):
        base = "bkt/wp"
        t = LakeTable.create(raw, base, SCHEMA, "delta",
                             PartitionSpec(["part"]),
                             {"delta.checkpointInterval": "100000"})
        rng = np.random.default_rng(0)

        def grow(k):
            for _ in range(k):
                n = 64
                t.append({"k": rng.integers(0, 1 << 30, n),
                          "part": np.array([f"p{i % 4}" for i in range(n)]),
                          "val": rng.random(n)})

        cfg = SyncConfig.from_dict({
            "sourceFormat": "DELTA", "targetFormats": ["ICEBERG", "HUDI"],
            "datasets": [{"tableBasePath": "mem://bkt/wp"}]})
        grow(2)
        res = run_sync(cfg, layer_fs(raw))
        assert all(r.ok and r.mode == "FULL" for r in res)
        grow(history_n)                      # pre-synced history
        res = run_sync(cfg, layer_fs(raw))
        assert all(r.ok and r.mode == "INCREMENTAL" for r in res)
        grow(backlog_n)                      # the measured backlog
        return cfg

    from repro.lst.storage import SimulatedObjectStore

    serial_dt = {}
    for rtt in rtts:
        for label, depth in (("serial", 1), ("pipelined", 16)):
            raw = MemoryFS()
            cfg = build(raw)
            sim = SimulatedObjectStore(
                raw, StorageProfile(rtt_ms=rtt, pipeline_depth=depth))
            fs = layer_fs(sim, retry=RetryPolicy())
            rounds0, puts0 = sim.serial_rounds(), layer_puts(fs)
            t0 = time.perf_counter()
            res = run_sync(cfg, fs)
            dt = time.perf_counter() - t0
            assert all(r.ok and r.mode == "INCREMENTAL" and
                       r.commits_synced == backlog_n for r in res)
            if label == "serial":
                serial_dt[rtt] = dt
            rounds = sim.serial_rounds() - rounds0
            report(f"write_pipeline.rtt{rtt}.{label}", dt * 1e6,
                   f"serial_rtts/commit={rounds / backlog_n:.1f} "
                   f"puts={layer_puts(fs) - puts0} "
                   f"speedup={serial_dt[rtt] / max(dt, 1e-9):.2f}x")

    # -- per-cycle head memoization: source-head reads on a CHANGED cycle
    from repro.core import ManualClock, SyncDaemon, SyncPlanner

    class _HeadReadCountingFS(MemoryFS):
        head_reads = 0

        def list_dir(self, path):
            if path.rstrip("/").endswith("_delta_log"):
                self.head_reads += 1
            return super().list_dir(path)

    def changed_cycle_head_reads(hinted: bool) -> int:
        raw = _HeadReadCountingFS()
        base = "bkt/wp"
        t = LakeTable.create(raw, base, SCHEMA, "delta",
                             PartitionSpec(["part"]),
                             {"delta.checkpointInterval": "100000"})
        rng = np.random.default_rng(0)
        for _ in range(3):
            t.append({"k": rng.integers(0, 99, 8),
                      "part": np.array([f"p{i % 4}" for i in range(8)]),
                      "val": rng.random(8)})
        cfg = SyncConfig.from_dict({
            "sourceFormat": "DELTA", "targetFormats": ["ICEBERG"],
            "datasets": [{"tableBasePath": base}]})
        fs = layer_fs(raw)
        daemon = SyncDaemon(cfg, fs, clock=ManualClock())
        daemon.run_cycle()                   # FULL bootstrap
        assert daemon.run_cycle().idle
        t.append({"k": rng.integers(0, 99, 8),
                  "part": np.array([f"p{i % 4}" for i in range(8)]),
                  "val": rng.random(8)})
        raw.head_reads = 0
        if hinted:
            rep = daemon.run_cycle()         # probe doubles as the hint
            assert rep.units_drained == 1
        else:
            # the pre-memoization sequence: probe, then an unhinted replan
            # (planner head read + index refresh head read)
            idx = daemon.cache.index("delta", base)
            idx.probe()
            idx.end_cycle()
            planner = SyncPlanner(cfg, fs, daemon.cache)
            units = planner.plan_dataset(cfg.datasets[0])
            assert units[0].mode == "INCREMENTAL"
        return raw.head_reads

    legacy, hinted = changed_cycle_head_reads(False), \
        changed_cycle_head_reads(True)
    report("write_pipeline.head_reads.changed_cycle", float(hinted),
           f"hinted={hinted} legacy={legacy} (per table per cycle)")


def bench_chunk_encode(report):
    """Chunkfile string codec: the vectorized fixed-width C-cast path vs.
    the legacy per-string msgpack listcomp, on the string-column shape
    ``LakeTable.append`` actually produces.  The legacy loop held the GIL
    for the whole column — the convoy behind the CPU-bound concurrent
    bootstrap regression; the vectorized path is a single ``astype`` cast
    (ASCII) or buffer memcpy (UCS4)."""
    import msgpack

    from repro.lst.chunkfile import (_decode_array, _encode_array,
                                     _encode_str_legacy)

    n = 30_000 if QUICK else 200_000
    arr = np.array([f"part-{i % 97:03d}/file-{i:011d}" for i in range(n)])
    decl, raw = _encode_array(arr, False)          # doubles as the warm-up
    legacy_raw = _encode_str_legacy(arr)
    legacy_decl = {"dtype": "str", "shape": list(arr.shape)}
    _decode_array(decl, raw), _decode_array(legacy_decl, legacy_raw)

    reps = range(3)                                # best-of-3 absorbs noise
    dt_enc = min(_timed(lambda: _encode_array(arr, False)) for _ in reps)
    dt_enc_leg = min(_timed(lambda: _encode_str_legacy(arr)) for _ in reps)
    dt_dec = min(_timed(lambda: _decode_array(decl, raw)) for _ in reps)
    dt_dec_leg = min(_timed(lambda: _decode_array(legacy_decl, legacy_raw))
                     for _ in reps)
    assert (_decode_array(decl, raw) == arr).all()
    assert msgpack.unpackb(legacy_raw)[0] == arr[0]

    report("chunk.encode_str.legacy", dt_enc_leg * 1e6,
           f"{n} strings (msgpack listcomp)")
    report("chunk.encode_str.vectorized", dt_enc * 1e6,
           f"enc={decl['enc']} speedup={dt_enc_leg / max(dt_enc, 1e-9):.2f}x")
    report("chunk.decode_str.legacy", dt_dec_leg * 1e6, f"{n} strings")
    report("chunk.decode_str.vectorized", dt_dec * 1e6,
           f"speedup={dt_dec_leg / max(dt_dec, 1e-9):.2f}x")


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _percentiles(lags: list, n_total: int) -> tuple[int, int]:
    """p50/p99 over ``n_total`` tables where ``lags`` holds only the
    nonzero entries (the daemon's lag dict omits caught-up tables)."""
    full = sorted([0] * (n_total - len(lags)) + list(lags))
    return (full[int(0.50 * (n_total - 1))],
            full[int(0.99 * (n_total - 1))])


def bench_fleet(report):
    """The sharded sync fleet at (simulated) scale.

    Phase 1 — lag-drain throughput scaling: ~1k single-target tables with
    a tiered backlog (hot 10% / warm 30% / cold 60%) behind a simulated
    object store (1 ms RTT quick / 10 ms full, pipelined batches),
    drained for one daemon cycle at 1 / 2 / 4 workers from
    identical cloned stores.  ``maxCommitsPerSync=4`` gives the cycle the
    daemon's real backpressure shape.  workers=1 is the *serial* daemon
    path (the honest baseline — no fleet machinery at all).  Derived
    columns: commits drained, requests/sec, p50/p99 remaining lag in
    commits, and the throughput scaling vs. 1 worker.

    Phase 2 — lag-aware vs. FIFO scheduling at equal width: a smaller
    fleet under a ``maxUnitsPerCycle`` budget tight enough that not every
    changed table drains each cycle, driven through rounds of tiered
    appends.  The urgency scheduler (backlog x EWMA commit rate) keeps
    the hot tables first in line; FIFO lets cold tables crowd them out.
    Derived columns: hot-tier p50/p99 lag after the last round.
    """
    from repro.core import FleetOptions, ManualClock, SyncDaemon

    # ---- phase 1: drain throughput scaling over workers ----------------
    n1 = 60 if QUICK else 1000
    # quick keeps the RTT tiny so CI smoke stays fast; the full shape
    # measures the regime the fleet exists for (real object-store RTT,
    # where probe/plan/drain overlap across workers is the win)
    rtt = 1 if QUICK else 10
    tiers = lambda i: 8 if i % 10 == 0 else (4 if i % 10 < 4 else 1)  # noqa: E731

    raw = MemoryFS()
    rng = np.random.default_rng(0)

    def grow(t, k):
        for _ in range(k):
            t.append({"k": rng.integers(0, 1 << 30, 8),
                      "part": np.array([f"p{i % 4}" for i in range(8)]),
                      "val": rng.random(8)})

    tables = []
    for i in range(n1):
        base = f"bkt/f{i:04d}"
        t = LakeTable.create(raw, base, SCHEMA, "delta",
                             PartitionSpec(["part"]),
                             {"delta.checkpointInterval": "100000"})
        grow(t, 1)
        tables.append((base, t))
    cfg = SyncConfig.from_dict({
        "sourceFormat": "DELTA", "targetFormats": ["ICEBERG"],
        "maxCommitsPerSync": 4,
        "datasets": [{"tableBasePath": b} for b, _ in tables]})
    # bootstrap on the raw store (setup, not measured), then the tiered
    # backlog every arm will face
    res = run_sync(cfg, layer_fs(raw))
    assert all(r.ok and r.mode == "FULL" for r in res)
    appended = {}
    for i, (base, t) in enumerate(tables):
        grow(t, tiers(i))
        appended[base] = tiers(i)

    dt_w1 = None
    for workers in (1, 2, 4):
        arm_raw = raw.clone()
        fs = layer_fs(arm_raw, profile=StorageProfile(rtt_ms=rtt,
                                                      pipeline_depth=16),
                      retry=RetryPolicy())
        daemon = SyncDaemon(cfg, fs, clock=ManualClock(),
                            fleet=FleetOptions(workers=workers))
        before = fs.stats().requests
        t0 = time.perf_counter()
        rep = daemon.run_cycle()
        dt = time.perf_counter() - t0
        daemon.close()
        assert rep.units_drained == n1, rep.summary()
        reqs = fs.stats().requests - before
        p50, p99 = _percentiles(rep.lag.values(), n1)
        if workers == 1:
            dt_w1 = dt
        report(f"fleet.drain.w{workers}", dt * 1e6,
               f"{n1} tables commits={rep.commits_applied} "
               f"reqs/s={reqs / max(dt, 1e-9):.0f} "
               f"p50_lag={p50} p99_lag={p99} "
               f"speedup={dt_w1 / max(dt, 1e-9):.2f}x")

    # ---- phase 2: urgency vs fifo under a drain budget ------------------
    n2 = 40 if QUICK else 300
    rounds = 4
    hot = lambda i: i % 8 == 0          # noqa: E731 — hot tier, spread out

    raw2 = MemoryFS()
    tables2 = []
    for i in range(n2):
        base = f"bkt/s{i:04d}"
        t = LakeTable.create(raw2, base, SCHEMA, "delta",
                             PartitionSpec(["part"]),
                             {"delta.checkpointInterval": "100000"})
        grow(t, 1)
        tables2.append((base, t))
    cfg2 = SyncConfig.from_dict({
        "sourceFormat": "DELTA", "targetFormats": ["ICEBERG"],
        "datasets": [{"tableBasePath": b} for b, _ in tables2]})
    res = run_sync(cfg2, layer_fs(raw2))
    assert all(r.ok and r.mode == "FULL" for r in res)

    for kind in ("urgency", "fifo"):
        arm_raw = raw2.clone()
        fs = layer_fs(arm_raw)
        clock = ManualClock()
        daemon = SyncDaemon(cfg2, fs, clock=clock,
                            fleet=FleetOptions(workers=2, scheduler=kind,
                                               max_units_per_cycle=n2 // 8))
        arm_tables = [(b, LakeTable.open(arm_raw, b, "delta"))
                      for b, _ in tables2]
        # results key by dataset *name* (the base path's last component)
        names = [b.rsplit("/", 1)[-1] for b, _ in arm_tables]
        written = dict.fromkeys(names, 0)
        synced = dict.fromkeys(names, 0)
        t0 = time.perf_counter()
        for _ in range(rounds):
            for i, (_, t) in enumerate(arm_tables):
                k = 4 if hot(i) else 1
                grow(t, k)
                written[names[i]] += k
            rep = daemon.run_cycle()
            for r in rep.results:
                synced[r.dataset] += r.commits_synced
            clock.advance(1.0)
        dt = time.perf_counter() - t0
        daemon.close()
        hot_lags = [written[nm] - synced[nm]
                    for i, nm in enumerate(names) if hot(i)]
        n_hot = len(hot_lags)
        p50, p99 = _percentiles([v for v in hot_lags if v], n_hot)
        report(f"fleet.sched.{kind}", dt * 1e6,
               f"{n2} tables budget={n2 // 8}/cycle x{rounds} "
               f"hot_p50_lag={p50} hot_p99_lag={p99}")


def bench_warm_restart(report):
    """Crash-safe restart cost: checkpoint resume vs. cold index rebuild.

    A daemon syncs a deep delta history into iceberg (saving durable
    checkpoints), the process "dies", and 2 new commits land while it is
    down.  Both arms then restart over identical clones of the surviving
    store behind a 10 ms-RTT pipelined object store and run ONE cycle:

    * ``restart.warm`` — checkpoint enabled: the watch token, the index
      tail seed and the estimator state restore from the newest
      generation, so the first cycle replays only the NEW commits;
    * ``restart.cold`` — no checkpoint: the first cycle rebuilds the whole
      source index before it can drain the same 2 commits.

    Derived columns carry the storage-request census (``reqs=``) of each
    arm — the number ``check_floor.py`` guards: warm must stay O(new
    commits) while cold grows O(history).
    """
    from repro.core import ManualClock, SyncDaemon

    history = 16 if QUICK else 64
    new_commits = 2
    rtt = 5 if QUICK else 10

    raw = MemoryFS()
    base = "bkt/restart"
    t = LakeTable.create(raw, base, SCHEMA, "delta", PartitionSpec(["part"]),
                         {"delta.checkpointInterval": "100000"})
    rng = np.random.default_rng(0)

    def grow(k):
        for _ in range(k):
            n = 32
            t.append({"k": rng.integers(0, 1 << 30, n),
                      "part": np.array([f"p{i % 4}" for i in range(n)]),
                      "val": rng.random(n)})

    grow(history)
    cfg_ck = SyncConfig.from_dict({
        "sourceFormat": "DELTA", "targetFormats": ["ICEBERG"],
        "datasets": [{"tableBasePath": base}],
        "checkpoint": {"enabled": True}})
    cfg_cold = SyncConfig.from_dict({
        "sourceFormat": "DELTA", "targetFormats": ["ICEBERG"],
        "datasets": [{"tableBasePath": base}]})

    # setup (not measured): sync + checkpoint on the raw store, then the
    # writer moves on while the daemon is "dead"
    d0 = SyncDaemon(cfg_ck, layer_fs(raw), clock=ManualClock())
    rep = d0.run_cycle()
    assert rep.units_drained == 1 and rep.checkpoint_gen is not None
    grow(new_commits)

    def arm(cfg):
        fs = layer_fs(raw.clone(),
                      profile=StorageProfile(rtt_ms=rtt, pipeline_depth=16),
                      retry=RetryPolicy())
        t0 = time.perf_counter()
        daemon = SyncDaemon(cfg, fs, clock=ManualClock())
        rep = daemon.run_cycle()
        dt = time.perf_counter() - t0
        assert rep.commits_applied == new_commits, rep.summary()
        return dt, fs.stats().requests, daemon.restored_from_checkpoint

    dt_w, rq_w, restored = arm(cfg_ck)
    assert restored
    dt_c, rq_c, _ = arm(cfg_cold)
    report("restart.warm", dt_w * 1e6,
           f"history={history} new={new_commits} rtt={rtt}ms reqs={rq_w} "
           f"(checkpoint resume: O(new commits))")
    report("restart.cold", dt_c * 1e6,
           f"history={history} new={new_commits} rtt={rtt}ms reqs={rq_c} "
           f"speedup={dt_c / max(dt_w, 1e-9):.1f}x vs warm, "
           f"reqs {rq_c / max(rq_w, 1):.1f}x")


def bench_read_plane(report):
    """The snapshot-serving read plane under a reader fleet + scan pruning.

    Fleet arms (``read_plane.readers.nN``): N conditional-GET readers poll
    the ICEBERG view of a delta table that a daemon keeps syncing, over a
    10 ms-RTT pipelined object store.  Each pass expires the server's TTL
    window first, so the fleet pays the worst legal cost: one head probe
    plus (on changed passes) ONE tail-only snapshot build, amortized over
    all N readers.  Derived columns carry the two numbers
    ``check_floor.py`` guards — ``hit_rate`` (fraction of reads served
    from the not-modified path or the snapshot LRU) and
    ``reqs_per_reader`` (storage requests per read, which must head
    toward zero as the fleet grows).

    Scan arms (``read_plane.scan.*``): a stats-poor table (footers are
    the only pruning power) scanned with a selective predicate — full
    bodies vs. footer-pruned vs. a re-scan over the warm footer cache,
    with the scanned/skipped byte census.  The pruned rows are asserted
    identical to masking the full scan.

    Projection arms (``read_plane.scan.wide_full`` / ``.projected`` /
    ``.late``): a 16-column table scanned in full vs. projecting 2
    columns through the CHK3 column-offset index vs. a late-materialized
    predicate + projection where only one chunk's data contains the probe
    value — the byte census ``check_floor.py`` gates (projected bytes
    must stay >= 3x under full bytes).
    """
    from repro.core import ManualClock, ReadPlaneOptions, SyncDaemon
    from repro.lst import chunkfile
    from repro.lst.table import Predicate
    from repro.serve import SnapshotServer

    fleets = (16, 64) if QUICK else (64, 512, 2048)
    rounds = 3                       # head-moved passes (+1 quiet pass)
    history = 4 if QUICK else 8
    appends = 2
    rtt = 5 if QUICK else 10
    rows = 64

    raw = MemoryFS()
    base = "bkt/readers"
    t = LakeTable.create(raw, base, SCHEMA, "delta", PartitionSpec(["part"]),
                         {"delta.checkpointInterval": "100000"})
    rng = np.random.default_rng(0)

    def grow(table, k):
        for _ in range(k):
            table.append({"k": rng.integers(0, 1 << 30, rows),
                          "part": np.array([f"p{i % 4}" for i in range(rows)]),
                          "val": rng.random(rows)})

    grow(t, history)
    cfg = SyncConfig.from_dict({
        "sourceFormat": "DELTA", "targetFormats": ["ICEBERG"],
        "datasets": [{"tableBasePath": base}]})

    for n in fleets:
        arm_raw = raw.clone()
        writer = LakeTable.open(arm_raw, base, "delta")  # RTT-free producer
        fs = layer_fs(arm_raw,
                      profile=StorageProfile(rtt_ms=rtt, pipeline_depth=16),
                      retry=RetryPolicy())
        clock = ManualClock()
        cache = MetadataCache(fs)
        server = SnapshotServer(fs, options=ReadPlaneOptions(ttl_ms=1000.0),
                                cache=cache, clock=clock)
        daemon = SyncDaemon(cfg, fs, cache=cache, clock=clock)
        daemon.run_cycle()                   # bootstrap the iceberg view
        tokens: list = [None] * n
        lat: list = []
        reader_reqs = 0
        read_wall = 0.0
        passes = 0

        def reader_pass():
            nonlocal reader_reqs, read_wall, passes
            clock.advance(2.0)               # expire the TTL window
            before = fs.stats().requests
            p0 = time.perf_counter()
            for i in range(n):
                r0 = time.perf_counter()
                res = server.read(base, "iceberg", if_token=tokens[i])
                lat.append(time.perf_counter() - r0)
                if res.snapshot is not None:
                    tokens[i] = res.token
            read_wall += time.perf_counter() - p0
            reader_reqs += fs.stats().requests - before
            passes += 1

        for _ in range(rounds):              # the table changes every pass
            grow(writer, appends)
            daemon.run_cycle()
            reader_pass()
        reader_pass()                        # quiet pass: nothing changed
        daemon.close()
        lat.sort()
        p99 = lat[min(len(lat) - 1, round(0.99 * (len(lat) - 1)))]
        total = n * passes
        report(f"read_plane.readers.n{n}", read_wall / total * 1e6,
               f"fleet={n} passes={passes} rtt={rtt}ms "
               f"p99={p99 * 1e3:.2f}ms hit_rate={server.stats.hit_rate:.3f} "
               f"reqs_per_reader={reader_reqs / total:.3f}")

    # ---- scan arms: stats-footer pushdown over the same RTT store ------
    n_chunks = 8 if QUICK else 24
    rows_c = 256
    scan_raw = MemoryFS()
    sbase = "bkt/scan"
    st = LakeTable.create(scan_raw, sbase, SCHEMA, "delta")
    metas = []
    for c in range(n_chunks):               # disjoint k bands per chunk
        lo = c * 10_000
        m = chunkfile.write_chunk(
            scan_raw, sbase, f"data/part-{c:03d}.chunk",
            {"k": np.arange(lo, lo + rows_c),
             "part": np.array([f"p{i % 4}" for i in range(rows_c)]),
             "val": rng.random(rows_c)})
        # strip metadata-layer stats: the footer is the only pruning power
        metas.append(chunkfile.DataFileMeta(
            path=m.path, size_bytes=m.size_bytes,
            record_count=m.record_count, column_stats={}))
    st.handle.commit(metas, [])

    sfs = layer_fs(scan_raw.clone(),
                   profile=StorageProfile(rtt_ms=rtt, pipeline_depth=16),
                   retry=RetryPolicy())
    server = SnapshotServer(sfs)
    snap = server.read(sbase, "delta").snapshot
    pred = (Predicate("k", ">=", (n_chunks - 1) * 10_000),)  # 1 chunk left

    t0 = time.perf_counter()
    full = server.scan_snapshot(snap)        # no pushdown: every body
    dt_full = time.perf_counter() - t0
    t0 = time.perf_counter()
    pruned = server.scan_snapshot(snap, pred)
    dt_pruned = time.perf_counter() - t0
    before = sfs.stats().requests
    t0 = time.perf_counter()
    again = server.scan_snapshot(snap, pred)  # footers already cached
    dt_cached = time.perf_counter() - t0
    rq_cached = sfs.stats().requests - before

    expect = full.rows["k"][full.rows["k"] >= pred[0].value]
    assert np.array_equal(pruned.rows["k"], expect)
    assert np.array_equal(again.rows["k"], expect)
    report("read_plane.scan.full", dt_full * 1e6,
           f"chunks={n_chunks} bytes={full.bytes_scanned} rtt={rtt}ms "
           f"(no pushdown: every body fetched)")
    report("read_plane.scan.pruned", dt_pruned * 1e6,
           f"scanned={pruned.files_scanned}/{n_chunks} "
           f"bytes={pruned.bytes_scanned} saved={pruned.bytes_skipped} "
           f"(cold footers, rows identical)")
    report("read_plane.scan.cached", dt_cached * 1e6,
           f"reqs={rq_cached} hits={server.stats_cache.hits} "
           f"(warm footer cache: body fetch only)")

    # ---- projection arms: CHK3 column pushdown over a WIDE table -------
    # 16 equal-width columns; a query touching 3 of them (1 predicate + 2
    # projected) should move ~3/16 of the body bytes.  The late arm's
    # chunks all pass the stats check for the probe value (overlapping
    # ranges) but only one chunk's DATA contains it — phase 1 fetches one
    # column everywhere, phase 2 only the surviving chunk's projection.
    wcols = 16
    w_chunks = 4 if QUICK else 8
    wrows = 256
    wide_raw = MemoryFS()
    wbase = "bkt/wide"
    wschema = Schema([Field(f"c{i:02d}", "float64" if i % 2 == 0
                            else "int64") for i in range(wcols)])
    wt = LakeTable.create(wide_raw, wbase, wschema, "delta")
    for c in range(w_chunks):
        data = {f"c{i:02d}": (rng.random(wrows) if i % 2 == 0 else
                              rng.integers(0, wrows, wrows) * 2)
                for i in range(wcols)}
        if c == 0:                            # the only odd-valued chunk
            data["c01"] = np.arange(wrows) * 2 + 1
        wt.append(data)

    wfs = layer_fs(wide_raw.clone(),
                   profile=StorageProfile(rtt_ms=rtt, pipeline_depth=16),
                   retry=RetryPolicy())
    wserver = SnapshotServer(wfs)
    wsnap = wserver.read(wbase, "delta").snapshot

    t0 = time.perf_counter()
    wfull = wserver.scan_snapshot(wsnap)      # all 16 columns, full bodies
    dt_wfull = time.perf_counter() - t0
    before = wfs.stats().requests
    t0 = time.perf_counter()
    wproj = wserver.scan_snapshot(wsnap, columns=["c02", "c03"])
    dt_wproj = time.perf_counter() - t0
    rq_wproj = wfs.stats().requests - before
    probe = 51                                # odd: only chunk 0's data has it
    wpred = (Predicate("c01", "==", probe),)
    before = wfs.stats().requests
    t0 = time.perf_counter()
    wlate = wserver.scan_snapshot(wsnap, wpred, columns=["c02", "c03"])
    dt_wlate = time.perf_counter() - t0
    rq_wlate = wfs.stats().requests - before

    for c in ("c02", "c03"):                  # byte-identical to the full path
        assert np.array_equal(wproj.rows[c], wfull.rows[c])
        assert np.array_equal(wlate.rows[c],
                              wfull.rows[c][wfull.rows["c01"] == probe])
    report("read_plane.scan.wide_full", dt_wfull * 1e6,
           f"chunks={w_chunks} cols={wcols} bytes={wfull.bytes_scanned} "
           f"rtt={rtt}ms (every column of every body)")
    report("read_plane.scan.projected", dt_wproj * 1e6,
           f"bytes={wproj.bytes_scanned} saved={wproj.bytes_projected_away} "
           f"reqs={rq_wproj} (2/{wcols} columns via the CHK3 index)")
    report("read_plane.scan.late", dt_wlate * 1e6,
           f"bytes={wlate.bytes_scanned} "
           f"pruned_late={wlate.files_pruned_late}/{w_chunks} "
           f"reqs={rq_wlate} (data-refuted chunks skip phase 2)")


def bench_catalog(report):
    """Catalog group publish + catalog-pinned group reads.

    ``catalog.publish.nN``: a daemon over N delta tables (ICEBERG target)
    on an RTT-injected pipelined store drains one appended commit per
    table, then group-publishes all N pointers as ONE catalog generation.
    The derived census carries the cycle's total request cost — the
    manifest swap itself is 1 LIST + 1 conditional PUT regardless of N
    (view pinning rides the drain's already-installed index state).

    ``catalog.read_group.warm``: a separate reader process resolving the
    whole group at one catalog generation through the snapshot LRU — a
    warm ``read_group`` costs exactly ONE storage request total (the
    catalog freshness LIST), independent of group size.
    """
    from repro.core import ManualClock, SyncDaemon
    from repro.lst.catalog import Catalog
    from repro.serve import SnapshotServer

    n_tables = 4 if QUICK else 16
    rtt = 5 if QUICK else 10
    rows = 64
    raw = MemoryFS()
    rng = np.random.default_rng(0)
    bases = [f"bkt/cat{i:02d}" for i in range(n_tables)]
    tables = []                                  # RTT-free producers
    for b in bases:
        t = LakeTable.create(raw, b, SCHEMA, "delta", PartitionSpec(["part"]),
                             {"delta.checkpointInterval": "100000"})
        t.append({"k": rng.integers(0, 1 << 30, rows),
                  "part": np.array([f"p{i % 4}" for i in range(rows)]),
                  "val": rng.random(rows)})
        tables.append(t)

    cfg = SyncConfig.from_dict({
        "sourceFormat": "DELTA", "targetFormats": ["ICEBERG"],
        "datasets": [{"tableBasePath": b} for b in bases],
        "catalog": {"enabled": True, "group": "bench"}})
    fs = layer_fs(raw, profile=StorageProfile(rtt_ms=rtt, pipeline_depth=16),
                  retry=RetryPolicy())
    clock = ManualClock()
    daemon = SyncDaemon(cfg, fs, cache=MetadataCache(fs), clock=clock)
    daemon.run_cycle()                           # bootstrap + generation 1
    for t in tables:
        t.append({"k": rng.integers(0, 1 << 30, rows),
                  "part": np.array([f"p{i % 4}" for i in range(rows)]),
                  "val": rng.random(rows)})
    before = fs.stats().requests
    t0 = time.perf_counter()
    rep = daemon.run_cycle()                     # drain + group publish
    dt = time.perf_counter() - t0
    reqs = fs.stats().requests - before
    assert rep.catalog_generation == 2
    report(f"catalog.publish.n{n_tables}", dt * 1e6,
           f"gen={rep.catalog_generation} "
           f"publishes={daemon.catalog.store.publishes} "
           f"conflicts={daemon.catalog.store.conflicts} reqs={reqs} "
           f"rtt={rtt}ms (ONE manifest swap for {n_tables} tables)")
    daemon.close()

    rfs = layer_fs(raw.clone(),
                   profile=StorageProfile(rtt_ms=rtt, pipeline_depth=16),
                   retry=RetryPolicy())
    catalog = Catalog(rfs, daemon.catalog.store.base_path)
    server = SnapshotServer(rfs)
    server.read_group(catalog, group="bench")    # cold: builds the snapshots
    before = rfs.stats().requests
    t0 = time.perf_counter()
    group = server.read_group(catalog, group="bench")
    dt = time.perf_counter() - t0
    reqs = rfs.stats().requests - before
    assert len(group) == n_tables and reqs <= 1
    report("catalog.read_group.warm", dt * 1e6,
           f"tables={n_tables} gen={group.generation} reqs={reqs} "
           f"reqs_per_table={reqs / n_tables:.3f} "
           f"(1 freshness LIST, snapshots from the LRU)")


def layer_puts(fs) -> int:
    return fs.stats().put


ALL = [bench_low_overhead, bench_incremental_vs_full, bench_omni_matrix,
       bench_file_count_scaling, bench_checkpoint_throughput,
       bench_serial_vs_concurrent, bench_backlog_drain,
       bench_object_store_sync, bench_continuous_sync,
       bench_write_pipeline, bench_chunk_encode, bench_fleet,
       bench_warm_restart, bench_read_plane, bench_catalog]
