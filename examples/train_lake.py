"""End-to-end driver — train a (reduced) LM a few hundred steps on a data
lake, with LST checkpoints + XTable sync + kill/restore (paper Scenario 2
inside the training framework: trainer writes Hudi, evaluator reads Iceberg).

Run: PYTHONPATH=src python examples/train_lake.py [--steps 200] [--arch yi-9b]
"""

import argparse
import sys
import tempfile
from dataclasses import replace

sys.path.insert(0, "src")

from repro.configs import smoke_config
from repro.data import LakeDataLoader, write_synth_corpus
from repro.lst import LocalFS
from repro.models.model import Model
from repro.train.trainer import Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="yi-9b")
args = ap.parse_args()

fs = LocalFS()
root = tempfile.mkdtemp()
print("world dir:", root)

# corpus lives in a Delta table (could be any format)
write_synth_corpus(fs, f"{root}/corpus", fmt="delta", n_docs=128,
                   pack_len=65, vocab=256, n_shards=4)

cfg = replace(smoke_config(args.arch), vocab_size=256)
model = Model(cfg)
loader = LakeDataLoader(fs, f"{root}/corpus", "delta", batch_size=8,
                        seq_len=64)

trainer = Trainer(model, loader, fs, f"{root}/ckpt", TrainerConfig(
    steps=args.steps, save_every=50, log_every=20, ce_chunk=64,
    ckpt_format="hudi", sync_targets=("iceberg", "delta")))
trainer.init_or_restore()
history = trainer.run()
print(f"loss: {history[0][1]:.3f} -> {history[-1][1]:.3f}")

# --- simulate preemption + restart reading the ICEBERG view ---------------
loader2 = LakeDataLoader(fs, f"{root}/corpus", "delta", batch_size=8,
                         seq_len=64)
restarted = Trainer(model, loader2, fs, f"{root}/ckpt", TrainerConfig(
    steps=args.steps + 20, save_every=50, log_every=20, ce_chunk=64,
    restore_format="iceberg"))
step = restarted.init_or_restore()
print(f"restarted from step {step} (restored via ICEBERG metadata, "
      f"loader cursor {loader2.row})")
restarted.run()
print("done; checkpoints visible as:",
      restarted.ckpt.steps(), "(hudi) ==",
      restarted.ckpt.steps(fmt="delta"), "(delta)")
