"""Scenario 3 — engine flexibility: the server restores weights through the
Iceberg view of checkpoints the trainer wrote as Hudi (snapshot+manifest
metadata with file statistics = the right shape for serving-fleet scan
planning), then serves batched requests.

Run: PYTHONPATH=src python examples/serve_flex.py
"""

import sys
import tempfile
from dataclasses import replace

sys.path.insert(0, "src")

import numpy as np

from repro.configs import smoke_config
from repro.data import LakeDataLoader, write_synth_corpus
from repro.lst import LocalFS
from repro.models.model import Model
from repro.serve.engine import Request, ServeEngine
from repro.train.trainer import Trainer, TrainerConfig

fs = LocalFS()
root = tempfile.mkdtemp()

# quick training run to produce checkpoints (trainer = Hudi engine)
write_synth_corpus(fs, f"{root}/corpus", fmt="delta", n_docs=64,
                   pack_len=65, vocab=256)
cfg = replace(smoke_config("stablelm-3b"), vocab_size=256)
model = Model(cfg)
trainer = Trainer(
    model,
    LakeDataLoader(fs, f"{root}/corpus", "delta", batch_size=8, seq_len=64),
    fs, f"{root}/ckpt",
    TrainerConfig(steps=60, save_every=30, log_every=20, ce_chunk=64,
                  ckpt_format="hudi", sync_targets=("iceberg",)))
trainer.init_or_restore()
trainer.run()

# the serving engine opens the SAME checkpoint directory as ICEBERG
engine = ServeEngine.from_lake(model, fs, f"{root}/ckpt", fmt="iceberg",
                               cache_len=96)
rng = np.random.default_rng(0)
requests = [Request(prompt=rng.integers(0, 256, size=n).tolist(),
                    max_new=12) for n in (5, 3, 8, 2)]
outs = engine.generate(requests, temperature=0.0)
for i, (req, out) in enumerate(zip(requests, outs)):
    print(f"req{i} prompt={req.prompt} -> {out}")
print("served from the Iceberg view of Hudi-written checkpoints — "
      "no weight files copied.")
