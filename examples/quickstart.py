"""Quickstart — the paper's Listing 1 + Listing 2 + Scenarios 1/2 in 60 lines.

Creates a *sales* table in Hudi (Listing 1 lifecycle), syncs it to Delta and
Iceberg with an XTable config identical to Listing 2, and reads the SAME data
files back through all three formats' connectors.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np

from repro.core import SyncConfig, Telemetry, run_sync
from repro.lst import LakeTable, LocalFS
from repro.lst.schema import Field, PartitionSpec, Schema
from repro.lst.table import Predicate

fs = LocalFS()
base = tempfile.mkdtemp() + "/sales"

# --- Listing 1: CREATE TABLE sales (s_id, s_type) PARTITIONED BY s_type ---
schema = Schema([Field("s_id", "int64"), Field("s_type", "string")])
sales = LakeTable.create(fs, base, schema, "hudi", PartitionSpec(["s_type"]))
sales.append({"s_id": np.array([1, 2, 3]), "s_type": np.array(["a", "a", "b"])})
sales.delete_where(Predicate("s_id", "==", 2))        # copy-on-write
print("hudi timeline:", sales.history())

# --- Listing 2: the XTable config, verbatim shape ---
config = SyncConfig.from_yaml(f"""
sourceFormat: HUDI
targetFormats:
  - DELTA
  - ICEBERG
datasets:
  -
    tableBasePath: file://host{base}
""")
telemetry = Telemetry()
for result in run_sync(config, fs, telemetry):
    print(f"sync -> {result.target_format}: {result.mode} "
          f"({result.elapsed_s * 1e3:.1f} ms)")

# --- Scenario 1/2: one copy of data, three formats -------------------------
for fmt in ("hudi", "delta", "iceberg"):
    t = LakeTable.open(fs, base, fmt)
    rows = sorted(t.read_all()["s_id"].tolist())
    print(f"{fmt:8s} sees rows {rows} via {len(t.state().files)} shared files")

# incremental follow-up commit
sales.append({"s_id": np.array([7]), "s_type": np.array(["b"])})
for result in run_sync(config, fs, telemetry):
    print(f"re-sync -> {result.target_format}: {result.mode} "
          f"({result.commits_synced} commits)")

print("\nXTable event timeline (demo utility):")
for line in telemetry.timeline():
    print(" ", line)
