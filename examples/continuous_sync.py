"""Continuous sync — the always-on daemon the paper's promise implies.

A table written in one format is readable in any other "with negligible
overhead" only if translation keeps up with the writer.  This example runs
the :class:`~repro.core.daemon.SyncDaemon` as that companion process: a
scripted Hudi writer appends against an ``s3sim://`` object store while
the daemon's watch -> replan -> drain cycles keep Delta and Iceberg
targets fresh, then the daemon drains the tail gracefully and stops.
Ctrl-C at any point is a *graceful* stop: the daemon finishes the backlog
before exiting instead of dying mid-drain.

Usage::

    PYTHONPATH=src python examples/continuous_sync.py
    PYTHONPATH=src python examples/continuous_sync.py --workers 4
    PYTHONPATH=src python examples/continuous_sync.py --restart

    # the same daemon, driven from your own code:
    from repro.core import SyncConfig, SyncDaemon, run_daemon

    config = SyncConfig.from_yaml('''
    sourceFormat: HUDI
    targetFormats: [DELTA, ICEBERG]
    datasets:
      - tableBasePath: s3sim://warehouse/events
    checkpoint:
      enabled: true               # durable warm-restart state (see --restart)
    daemon:
      pollIntervalMs: 1000        # watch cadence
      maxCyclesIdle: 30           # exit after 30 quiet cycles (omit: forever)
      backoff: {baseDelayMs: 200, maxDelayMs: 30000}   # per-table 503 backoff
    ''')

    reports = run_daemon(config, cycles=100)   # bounded run, or:
    daemon = SyncDaemon(config)                # long-lived service object
    daemon.run()                               # ... until daemon.stop()
    daemon.stop(drain=True)                    # finish the backlog, then stop

Each cycle probes every source head with ONE cheap request (delta log-tail
listing / iceberg version-hint read / hudi newest-instant listing), replans
only tables whose head moved or that still carry a capped backlog, and
drains them through the transactional executor path — a quiet table costs
exactly its head probe.  ``maxCommitsPerSync`` bounds each cycle's drain;
a transient storage error backs off the one affected table with jittered
exponential delays while every other table keeps syncing.

``--workers N`` (N > 1) runs the same cycles through the sharded sync
fleet (``core/fleet.py``): probes and planning fan out over N worker
threads, and the planned (dataset, target) cells drain through per-worker
shard queues — most-urgent-first, with work stealing.  Equivalent to a
``fleet: {workers: N}`` block in the config.

``--restart`` demonstrates crash-safe warm restarts: the daemon is killed
mid-drain (abandoned with a capped backlog, like a power cut), the writer
keeps appending while it is down, and then two restarted daemons race over
clones of the surviving store — one resuming from the durable checkpoint,
one cold — printing the request census of each.  The warm restart replays
only the commits that landed since the last checkpoint (O(new commits));
the cold one rebuilds the whole source index (O(history)).
"""

import argparse
import sys

sys.path.insert(0, "src")

args = argparse.ArgumentParser(description="continuous-sync daemon demo")
args.add_argument("--workers", type=int, default=1,
                  help="fleet width; >1 engages the sharded fleet cycle path")
args.add_argument("--restart", action="store_true",
                  help="kill the daemon mid-drain, then race a checkpoint "
                       "warm restart against a cold one")
args = args.parse_args()

import numpy as np

from repro.core import FleetOptions, SyncConfig, SyncDaemon, Telemetry
from repro.lst import LakeTable
from repro.lst.schema import Field, PartitionSpec, Schema
from repro.lst.storage import layer_fs, shared_store

BASE = "warehouse/events"

# --- the writer's side: a Hudi table on the simulated object store --------
store = shared_store("s3sim")          # the bucket namespace s3sim:// resolves to
schema = Schema([Field("event_id", "int64"), Field("kind", "string")])
events = LakeTable.create(store, BASE, schema, "hudi", PartitionSpec(["kind"]))
events.append({"event_id": np.array([1, 2, 3]),
               "kind": np.array(["view", "view", "buy"])})

# --- the daemon's side: Listing-2 config + a daemon block -----------------
_YAML = """
sourceFormat: HUDI
targetFormats:
  - DELTA
  - ICEBERG
datasets:
  -
    tableBasePath: s3sim://warehouse/events
maxCommitsPerSync: 2
checkpoint:
  enabled: {ckpt}
daemon:
  pollIntervalMs: 50
  backoff: {{baseDelayMs: 100}}
"""
config = SyncConfig.from_yaml(_YAML.format(ckpt="true"))
telemetry = Telemetry()
daemon = SyncDaemon(config, telemetry=telemetry,
                    fleet=FleetOptions(workers=args.workers))
if args.workers > 1:
    print(f"== sharded fleet: {args.workers} workers "
          f"({daemon.fleet_opts.shard_strategy}-sharded, "
          f"{daemon.fleet_opts.scheduler} scheduling)")

rng = np.random.default_rng(0)


def _burst(n, rows=4):
    for _ in range(n):
        events.append({"event_id": rng.integers(100, 1000, rows),
                       "kind": np.array(["view", "buy", "view", "view"][:rows])})


def _verify(fs, label=""):
    want = sorted(events.read_all()["event_id"].tolist())
    for fmt in ("hudi", "delta", "iceberg"):
        got = sorted(LakeTable.open(fs, BASE, fmt).read_all()
                     ["event_id"].tolist())
        marker = "ok" if got == want else "MISMATCH"
        print(f"{fmt:8s} sees {len(got)} rows via shared data files "
              f"[{marker}]{label}")
        assert got == want, fmt


def _drain_to_idle(d):
    """Cycle until idle; returns (cycles, total requests, first report)."""
    reqs = cycles = 0
    first = None
    while True:
        rep = d.run_cycle()
        first = first or rep
        cycles += 1
        reqs += (rep.storage_ops or {}).get("requests", 0)
        if rep.idle:
            return cycles, reqs, first


# --- scripted workload: appends interleaved with daemon cycles ------------
# Ctrl-C anywhere below falls through to the graceful drain-stop: the
# in-flight cycle completes (commits are atomic puts), the backlog drains,
# and only then does the process exit.
interrupted = False
try:
    print("== bootstrap cycle (FULL sync into both targets)")
    print("  ", daemon.run_cycle().summary())

    for round_no in range(3):
        _burst(round_no + 1)                   # growing burst each round
        rep = daemon.run_cycle()
        print(f"== round {round_no}: writer appended {round_no + 1} commits")
        print("  ", rep.summary())
        if rep.lag:
            print("   lag:", {f"{d}->{t}": n for (d, t), n in rep.lag.items()})
except KeyboardInterrupt:
    interrupted = True
    print("\n== SIGINT: draining the backlog before exit (Ctrl-C again to "
          "abort hard)")

print("== graceful stop: drain whatever backlog is left, then halt")
daemon.stop(drain=True)
for rep in daemon.run():
    print("  ", rep.summary())
daemon.close()

# --- proof: all three formats read the same rows --------------------------
_verify(store)

print("\ndaemon telemetry counters:", {
    k: v for k, v in telemetry.summary().items() if k.startswith("daemon.")})

# --- the --restart arm: power cut mid-drain, then warm vs cold restart ----
if args.restart and not interrupted:
    print("\n== restart demo: deepen the history, then cut the power")
    _burst(12)
    d1 = SyncDaemon(config)                    # restores, then drains the 12
    while not d1.run_cycle().idle:
        pass
    _burst(3)
    rep = d1.run_cycle()                       # capped cycle: backlog remains
    print("   mid-drain report:", rep.summary())
    del d1                                     # the power cut: no stop(), no
    _burst(2)                                  # drain; writer keeps going

    snap = store.clone()                       # both arms see the same wreck
    warm_fs, cold_fs = layer_fs(snap.clone()), layer_fs(snap.clone())

    warm = SyncDaemon(config, warm_fs)
    print(f"   warm restart: restored_from_checkpoint="
          f"{warm.restored_from_checkpoint}")
    w_cycles, w_reqs, w_first = _drain_to_idle(warm)

    cold = SyncDaemon(SyncConfig.from_yaml(_YAML.format(ckpt="false")),
                      cold_fs)
    c_cycles, c_reqs, c_first = _drain_to_idle(cold)

    print(f"   warm: {w_cycles} cycles, {w_reqs} storage requests "
          f"(first cycle drained {w_first.commits_applied} commits)")
    print(f"   cold: {c_cycles} cycles, {c_reqs} storage requests "
          f"(rebuilt the whole source index first)")
    print(f"   resumed-vs-cold census: {w_reqs} vs {c_reqs} requests "
          f"({c_reqs / max(1, w_reqs):.1f}x) — O(new commits) vs O(history)")
    assert warm.restored_from_checkpoint and w_reqs < c_reqs
    _verify(warm_fs, label="  (warm-restart arm)")
