"""Continuous sync — the always-on daemon the paper's promise implies.

A table written in one format is readable in any other "with negligible
overhead" only if translation keeps up with the writer.  This example runs
the :class:`~repro.core.daemon.SyncDaemon` as that companion process: a
scripted Hudi writer appends against an ``s3sim://`` object store while
the daemon's watch -> replan -> drain cycles keep Delta and Iceberg
targets fresh, then the daemon drains the tail gracefully and stops.

Usage::

    PYTHONPATH=src python examples/continuous_sync.py
    PYTHONPATH=src python examples/continuous_sync.py --workers 4

    # the same daemon, driven from your own code:
    from repro.core import SyncConfig, SyncDaemon, run_daemon

    config = SyncConfig.from_yaml('''
    sourceFormat: HUDI
    targetFormats: [DELTA, ICEBERG]
    datasets:
      - tableBasePath: s3sim://warehouse/events
    daemon:
      pollIntervalMs: 1000        # watch cadence
      maxCyclesIdle: 30           # exit after 30 quiet cycles (omit: forever)
      backoff: {baseDelayMs: 200, maxDelayMs: 30000}   # per-table 503 backoff
    ''')

    reports = run_daemon(config, cycles=100)   # bounded run, or:
    daemon = SyncDaemon(config)                # long-lived service object
    daemon.run()                               # ... until daemon.stop()
    daemon.stop(drain=True)                    # finish the backlog, then stop

Each cycle probes every source head with ONE cheap request (delta log-tail
listing / iceberg version-hint read / hudi newest-instant listing), replans
only tables whose head moved or that still carry a capped backlog, and
drains them through the transactional executor path — a quiet table costs
exactly its head probe.  ``maxCommitsPerSync`` bounds each cycle's drain;
a transient storage error backs off the one affected table with jittered
exponential delays while every other table keeps syncing.

``--workers N`` (N > 1) runs the same cycles through the sharded sync
fleet (``core/fleet.py``): probes and planning fan out over N worker
threads, and the planned (dataset, target) cells drain through per-worker
shard queues — most-urgent-first, with work stealing.  Equivalent to a
``fleet: {workers: N}`` block in the config.
"""

import argparse
import sys

sys.path.insert(0, "src")

args = argparse.ArgumentParser(description="continuous-sync daemon demo")
args.add_argument("--workers", type=int, default=1,
                  help="fleet width; >1 engages the sharded fleet cycle path")
args = args.parse_args()

import numpy as np

from repro.core import FleetOptions, SyncConfig, SyncDaemon, Telemetry
from repro.lst import LakeTable
from repro.lst.schema import Field, PartitionSpec, Schema
from repro.lst.storage import shared_store

BASE = "warehouse/events"

# --- the writer's side: a Hudi table on the simulated object store --------
store = shared_store("s3sim")          # the bucket namespace s3sim:// resolves to
schema = Schema([Field("event_id", "int64"), Field("kind", "string")])
events = LakeTable.create(store, BASE, schema, "hudi", PartitionSpec(["kind"]))
events.append({"event_id": np.array([1, 2, 3]),
               "kind": np.array(["view", "view", "buy"])})

# --- the daemon's side: Listing-2 config + a daemon block -----------------
config = SyncConfig.from_yaml("""
sourceFormat: HUDI
targetFormats:
  - DELTA
  - ICEBERG
datasets:
  -
    tableBasePath: s3sim://warehouse/events
maxCommitsPerSync: 2
daemon:
  pollIntervalMs: 50
  backoff: {baseDelayMs: 100}
""")
telemetry = Telemetry()
daemon = SyncDaemon(config, telemetry=telemetry,
                    fleet=FleetOptions(workers=args.workers))
if args.workers > 1:
    print(f"== sharded fleet: {args.workers} workers "
          f"({daemon.fleet_opts.shard_strategy}-sharded, "
          f"{daemon.fleet_opts.scheduler} scheduling)")

# --- scripted workload: appends interleaved with daemon cycles ------------
print("== bootstrap cycle (FULL sync into both targets)")
print("  ", daemon.run_cycle().summary())

rng = np.random.default_rng(0)
for round_no in range(3):
    for _ in range(round_no + 1):              # growing burst each round
        events.append({"event_id": rng.integers(100, 1000, 4),
                       "kind": np.array(["view", "buy", "view", "view"])})
    rep = daemon.run_cycle()
    print(f"== round {round_no}: writer appended {round_no + 1} commits")
    print("  ", rep.summary())
    if rep.lag:
        print("   lag:", {f"{d}->{t}": n for (d, t), n in rep.lag.items()})

print("== graceful stop: drain whatever backlog is left, then halt")
daemon.stop(drain=True)
for rep in daemon.run():
    print("  ", rep.summary())

# --- proof: all three formats read the same rows --------------------------
want = sorted(events.read_all()["event_id"].tolist())
for fmt in ("hudi", "delta", "iceberg"):
    got = sorted(LakeTable.open(store, BASE, fmt).read_all()
                 ["event_id"].tolist())
    marker = "ok" if got == want else "MISMATCH"
    print(f"{fmt:8s} sees {len(got)} rows via shared data files [{marker}]")
    assert got == want, fmt

print("\ndaemon telemetry counters:", {
    k: v for k, v in telemetry.summary().items() if k.startswith("daemon.")})
