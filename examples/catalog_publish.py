"""Catalog registration + atomic multi-table group publish.

The Unity-Catalog-style loop from the paper's ecosystem (SNIPPETS.md):
a writer owns several tables of one *dataset*, XTable keeps every format
view fresh, and a catalog is the single place readers discover them.
What the demo pins down is the part one-table-at-a-time registration
cannot give you: the daemon publishes each cycle's drained tables as ONE
atomic catalog generation (a *group commit*), so a reader joining
``orders`` against ``customers`` can never observe orders from cycle N
next to customers from cycle N-1 — whatever crashes or races happen.

The cast:

* **writer** — appends Delta commits to ``orders`` and ``customers`` on
  an ``s3sim://`` object store;
* **daemon** — continuous sync (Delta -> Iceberg + Hudi) with a
  ``catalog:`` block: post-drain, every cleanly drained table's pointer
  (base path + per-format-view pinned head token/commit) lands in the
  catalog as one generation;
* **reader** — a completely separate process stack (own metadata cache,
  own ``SnapshotServer``) that resolves the group through the catalog
  and reads every member pinned at one generation — in ANY format view.

Run: PYTHONPATH=src python examples/catalog_publish.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import ManualClock, MetadataCache, SyncConfig, SyncDaemon
from repro.lst import LakeTable
from repro.lst.catalog import Catalog
from repro.lst.schema import Field, PartitionSpec, Schema
from repro.lst.storage import layer_fs, shared_store
from repro.serve import SnapshotServer

# --- the writer's side: two Delta tables of ONE dataset -------------------
store = shared_store("s3sim")
schema = Schema([Field("id", "int64"), Field("part", "string")])
tables = {}
for name in ("orders", "customers"):
    t = LakeTable.create(store, f"warehouse/{name}", schema, "delta",
                         PartitionSpec(["part"]))
    t.append({"id": np.arange(3, dtype=np.int64),
              "part": np.array(["a", "a", "b"])})
    tables[name] = t

# --- the daemon's side: sync + catalog group publish ----------------------
config = SyncConfig.from_yaml("""
sourceFormat: DELTA
targetFormats: [ICEBERG, HUDI]
datasets:
  - tableBasePath: s3sim://warehouse/orders
  - tableBasePath: s3sim://warehouse/customers
catalog:
  enabled: true
  group: sales          # both tables publish under ONE dataset group
  publishViews: all     # pin iceberg + hudi views too, not just delta
""")
clock = ManualClock()
daemon = SyncDaemon(config, clock=clock)
daemon.read_plane = SnapshotServer(daemon.fs, cache=daemon.cache,
                                   clock=clock)

rep = daemon.run_cycle()
print("== cycle 0:", rep.summary())
print(f"   catalog generation {rep.catalog_generation} published "
      f"(both tables, ONE atomic manifest swap)")

# --- the reader's side: a separate process stack --------------------------
reader_fs = layer_fs(store)
catalog = Catalog(reader_fs, daemon.catalog.store.base_path)
server = SnapshotServer(reader_fs, cache=MetadataCache(reader_fs))

group = server.read_group(catalog, group="sales")
print(f"== reader resolves group 'sales' at generation {group.generation}: "
      f"{group.table_names()}")
for name in group.table_names():
    snap = group[name]
    rows = sorted(server.scan_snapshot(snap).rows["id"].tolist())
    print(f"   {name:9s} [{snap.view_format}] pinned at "
          f"commit {snap.head_commit}: rows {rows}")

# any format view, same pinned generation
iceberg_group = server.read_group(catalog, group="sales", fmt="iceberg")
print("== the same group through the ICEBERG views:",
      {n: iceberg_group[n].view_format for n in iceberg_group.table_names()})

# --- the consistency claim, demonstrated ----------------------------------
# The writer moves BOTH tables; until the daemon's next group publish the
# reader keeps resolving the OLD generation — never orders-new next to
# customers-old.
for name, t in tables.items():
    t.append({"id": np.array([100], np.int64), "part": np.array(["b"])})
stale = server.read_group(catalog, group="sales")
print(f"== writer appended to both; reader still sees generation "
      f"{stale.generation} (consistent, just not fresh)")

rep = daemon.run_cycle()
print("== cycle 1:", rep.summary())
fresh = server.read_group(catalog, group="sales")
print(f"== after the group publish the reader sees generation "
      f"{fresh.generation}; members move TOGETHER:")
for name in fresh.table_names():
    rows = sorted(server.scan_snapshot(fresh[name]).rows["id"].tolist())
    assert 100 in rows, f"{name} missing the new rows"
    print(f"   {name:9s} rows {rows}")

# the held stale group is immutable: still the old rows, byte-identical
for name in stale.table_names():
    assert 100 not in server.scan_snapshot(stale[name]).rows["id"].tolist()
print("== the reader's held generation-1 group still serves the OLD rows "
      "(snapshots are immutable)")

print("\ncatalog store counters:",
      {"publishes": daemon.catalog.store.publishes,
       "conflicts": daemon.catalog.store.conflicts})
