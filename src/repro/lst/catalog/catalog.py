"""The catalog facade: consistent multi-table registration and resolution.

A :class:`Catalog` is the single entry point readers discover synced
tables through (ROADMAP open item 2 — the org-scale version of the
paper's interoperability claim: one catalog, any format, consistent
cross-table reads).  It resolves an immutable :class:`CatalogSnapshot`
of the newest generation manifest — every table pointer and every group
in one atomic unit — and publishes changes through
:class:`CatalogTransaction` **group commits**: any number of pointer
updates and group edits staged together become visible in ONE atomic
manifest swap, so a reader can never observe half of a multi-table
publish.

Concurrency is optimistic, the same shape as every LST commit protocol
in this repo: a transaction reads a base generation, stages updates in
memory, and publishes ``base + 1`` with a conditional put.  Losing the
race (:class:`~repro.lst.catalog.store.CatalogConflict`) re-reads the
winning manifest, re-applies the staged updates on top, and tries the
next generation — updates to *different* tables interleave without loss,
updates to the *same* table resolve last-writer-wins at a generation
boundary, and every published generation is internally consistent.

Request economics: resolving a snapshot costs one LIST (freshness) plus
one GET only when the generation actually moved — repeat resolutions of
an unchanged catalog reuse the parsed manifest.  A publish costs the
base resolution plus exactly one PUT.
"""

from __future__ import annotations

import threading

from repro.lst.catalog.pointer import (TablePointer, pointer_from_json,
                                       pointer_to_json)
from repro.lst.catalog.store import CatalogConflict, CatalogStore

__all__ = ["UnknownTableError", "CatalogSnapshot", "Catalog",
           "CatalogTransaction"]


class UnknownTableError(KeyError):
    """The requested table (or group) is not registered in this catalog
    generation."""


class CatalogSnapshot:
    """One immutable generation of the catalog: all pointers, all groups.

    Resolving through a snapshot is what gives cross-table consistency:
    every ``resolve()`` against one snapshot answers from the same
    atomically-published manifest, however many publishes land after it
    was taken.
    """

    def __init__(self, generation: int, tables: dict, groups: dict):
        self.generation = generation
        self._tables = dict(tables)       # name -> TablePointer
        self._groups = {g: tuple(m) for g, m in groups.items()}

    @property
    def tables(self) -> dict:
        return dict(self._tables)

    @property
    def groups(self) -> dict:
        return dict(self._groups)

    def table_names(self) -> list:
        return sorted(self._tables)

    def resolve(self, name: str) -> TablePointer:
        ptr = self._tables.get(name)
        if ptr is None:
            raise UnknownTableError(
                f"table {name!r} is not registered "
                f"(generation {self.generation}; "
                f"registered: {self.table_names()})")
        return ptr

    def group(self, name: str) -> tuple:
        members = self._groups.get(name)
        if members is None:
            raise UnknownTableError(
                f"group {name!r} is not registered "
                f"(generation {self.generation}; "
                f"groups: {sorted(self._groups)})")
        return members

    # ------------------------------------------------------------- manifest
    def to_manifest(self) -> dict:
        return {"tables": {n: pointer_to_json(p)
                           for n, p in sorted(self._tables.items())},
                # membership order is the publisher's (set_group /
                # add_to_group order) — preserved, not sorted, so every
                # reader of a generation sees the same tuple
                "groups": {g: list(m)
                           for g, m in sorted(self._groups.items())}}

    @staticmethod
    def from_manifest(generation: int, manifest: dict) -> "CatalogSnapshot":
        tables = {n: pointer_from_json(d)
                  for n, d in manifest.get("tables", {}).items()}
        groups = {g: tuple(m)
                  for g, m in manifest.get("groups", {}).items()}
        return CatalogSnapshot(generation, tables, groups)


class Catalog:
    """Catalog over one storage prefix (see module doc).

    Thread-safe: snapshots are immutable, the parsed-manifest memo is
    lock-guarded, and publish atomicity comes from the store's
    conditional put — concurrent transactions from any number of threads
    or processes serialize at the generation boundary.
    """

    def __init__(self, fs, base_path: str, *, retain: int = 8):
        self.fs = fs
        self.store = CatalogStore(fs, base_path, retain=retain)
        self._lock = threading.Lock()
        self._cached: CatalogSnapshot | None = None

    # ------------------------------------------------------------ resolution
    def snapshot(self) -> CatalogSnapshot:
        """The newest catalog generation as an immutable snapshot.

        One LIST for freshness; the manifest GET is skipped when the
        generation has not moved since the last resolution (including a
        publish this instance made itself).  An unreadable newest
        generation falls back one generation instead of failing readers.
        """
        head = self.store.head_generation()
        with self._lock:
            cached = self._cached
        if cached is not None and cached.generation == head:
            return cached
        if head == 0:
            snap = CatalogSnapshot(0, {}, {})
        else:
            manifest = self.store.load_generation(head)
            if manifest is None:
                gen, manifest = self.store.load()
                snap = CatalogSnapshot.from_manifest(gen, manifest)
            else:
                snap = CatalogSnapshot.from_manifest(head, manifest)
        with self._lock:
            if self._cached is None or \
                    snap.generation >= self._cached.generation:
                self._cached = snap
        return snap

    def resolve(self, name: str) -> TablePointer:
        """``snapshot().resolve(name)`` — the single-table convenience."""
        return self.snapshot().resolve(name)

    def seed_generation(self, gen: int) -> None:
        """Advisory warm-start hint (see ``CatalogStore.seed_generation``)."""
        self.store.seed_generation(gen)

    @property
    def last_generation(self) -> int:
        """The newest generation this instance has resolved or published
        (no storage requests; 0 before any resolution)."""
        with self._lock:
            return self._cached.generation if self._cached else 0

    # -------------------------------------------------------------- mutation
    def transaction(self) -> "CatalogTransaction":
        """Stage pointer/group updates and publish them as ONE atomic
        generation; usable as a context manager (commits on clean exit)::

            with catalog.transaction() as txn:
                txn.put(pointer_a)
                txn.put(pointer_b)
                txn.set_group("orders", ["a", "b"])
            # <- both pointers + the group are now visible, atomically
        """
        return CatalogTransaction(self)

    def register_table(self, pointer: TablePointer,
                       group: str | None = None) -> CatalogSnapshot:
        """One-pointer convenience transaction (optionally joining a
        group); returns the published snapshot."""
        with self.transaction() as txn:
            txn.put(pointer)
            if group:
                txn.add_to_group(group, pointer.name)
        return txn.published

    # -------------------------------------------------------------- internals
    def _install(self, snap: CatalogSnapshot) -> None:
        with self._lock:
            if self._cached is None or \
                    snap.generation >= self._cached.generation:
                self._cached = snap


class CatalogTransaction:
    """Staged catalog updates published as one atomic generation.

    Staging is pure in-memory bookkeeping; nothing touches storage until
    :meth:`commit`, and commit performs exactly one PUT per attempt — the
    manifest swap IS the commit point.  A conflict (another publisher won
    the generation) re-reads the winning manifest and re-applies the
    staged updates on top; after ``max_attempts`` losses the conflict
    propagates.  A transaction commits at most once.
    """

    def __init__(self, catalog: Catalog, *, max_attempts: int = 16):
        if max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        self.catalog = catalog
        self.max_attempts = max_attempts
        self._puts: dict[str, TablePointer] = {}
        self._drops: set[str] = set()
        self._group_sets: dict[str, tuple] = {}
        self._group_adds: dict[str, list] = {}
        self.published: CatalogSnapshot | None = None

    # -------------------------------------------------------------- staging
    def put(self, pointer: TablePointer) -> "CatalogTransaction":
        """Stage a pointer registration/update (last stage of a name wins)."""
        self._drops.discard(pointer.name)
        self._puts[pointer.name] = pointer
        return self

    def drop(self, name: str) -> "CatalogTransaction":
        """Stage a de-registration (the name also leaves every group)."""
        self._puts.pop(name, None)
        self._drops.add(name)
        return self

    def set_group(self, group: str, members) -> "CatalogTransaction":
        """Stage a group definition (replaces the membership outright)."""
        self._group_sets[group] = tuple(members)
        self._group_adds.pop(group, None)
        return self

    def add_to_group(self, group: str, *members: str) -> "CatalogTransaction":
        """Stage additions to a group (created if absent, merged with the
        base manifest's membership at commit time)."""
        self._group_adds.setdefault(group, []).extend(members)
        return self

    @property
    def empty(self) -> bool:
        return not (self._puts or self._drops or self._group_sets
                    or self._group_adds)

    # --------------------------------------------------------------- commit
    def commit(self) -> CatalogSnapshot:
        """Publish every staged update as ONE new generation (see class
        doc); returns the published snapshot.  An empty transaction is a
        no-op returning the current snapshot."""
        if self.published is not None:
            raise RuntimeError("transaction already committed")
        if self.empty:
            self.published = self.catalog.snapshot()
            return self.published
        last: CatalogConflict | None = None
        for _ in range(self.max_attempts):
            base = self.catalog.snapshot()
            snap = self._apply(base)
            try:
                gen = self.catalog.store.publish(
                    snap.to_manifest(), base_generation=base.generation)
            except CatalogConflict as e:
                last = e
                continue    # rebase on the winner's manifest and retry
            snap.generation = gen
            self.catalog._install(snap)
            self.published = snap
            return snap
        raise last if last is not None else CatalogConflict("publish failed")

    def _apply(self, base: CatalogSnapshot) -> CatalogSnapshot:
        tables = base.tables
        groups = {g: list(m) for g, m in base.groups.items()}
        for name in self._drops:
            tables.pop(name, None)
            for members in groups.values():
                if name in members:
                    members.remove(name)
        tables.update(self._puts)
        for g, members in self._group_sets.items():
            groups[g] = list(members)
        for g, added in self._group_adds.items():
            members = groups.setdefault(g, [])
            members.extend(m for m in added if m not in members)
        # membership is only meaningful over registered tables
        for g in list(groups):
            groups[g] = [m for m in groups[g] if m in tables]
        return CatalogSnapshot(base.generation, tables, groups)

    # ------------------------------------------------------ context manager
    def __enter__(self) -> "CatalogTransaction":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None and self.published is None:
            self.commit()
