"""Durable catalog manifests: one generation document per publish.

The whole catalog — every table pointer and every group definition — is
one JSON *manifest* object per generation (``gen-NNNNNNNNNN.json``),
persisted through the same :class:`~repro.lst.storage.base.FileSystem`
protocol and with the same single-atomic-commit-point discipline the
target writers and ``core/checkpoint.py`` use: publishing generation
``N+1`` is exactly ONE conditional put (put-if-absent).  That is the
entire atomicity story —

* a crash anywhere before the put leaves readers at generation ``N``;
* a torn put (applied, response lost) leaves a fully durable ``N+1``;
* two publishers racing the same base generation see exactly one winner
  (:class:`CatalogConflict` for the loser, who re-reads and rebases —
  see ``catalog.py``).

Unlike the checkpoint store, the loser must NOT blindly take the next
free slot: a manifest's content depends on the manifest it was derived
from, so the conflict is surfaced to the transaction layer for a
re-read + re-apply instead of being swallowed here.

``load()`` walks generations newest-first and skips unreadable or
unparseable documents, so a corrupted newest generation degrades one
generation instead of poisoning every reader.  Old generations are
pruned best-effort after a successful publish (``retain``).
"""

from __future__ import annotations

import json
import threading

from repro.lst.storage.base import PutIfAbsentError, join

__all__ = ["CATALOG_VERSION", "CatalogConflict", "CatalogStore"]

CATALOG_VERSION = 1

_GEN_PREFIX = "gen-"
_GEN_SUFFIX = ".json"


class CatalogConflict(RuntimeError):
    """A publish lost the generation race (another manifest landed first).

    Carries no partial state by construction: the loser's manifest was
    never written.  Transactions catch this, re-read the winning
    manifest, re-apply their staged updates and publish again.
    """


class CatalogStore:
    """Generation-numbered catalog manifests under one storage prefix."""

    def __init__(self, fs, base_path: str, *, retain: int = 8):
        if retain < 1:
            raise ValueError("retain must be >= 1")
        self.fs = fs
        self.base_path = base_path.rstrip("/")
        self.retain = retain
        self._lock = threading.Lock()
        self._gen_hint: int = 0       # highest generation seen (advisory)
        self.publishes = 0
        self.conflicts = 0
        self.load_fallbacks = 0       # corrupt generations skipped on load

    def _path(self, gen: int) -> str:
        return join(self.base_path, f"{_GEN_PREFIX}{gen:010d}{_GEN_SUFFIX}")

    def _scan(self) -> list[int]:
        """Existing generation numbers, ascending (one LIST request)."""
        try:
            names = self.fs.list_dir(self.base_path)
        except FileNotFoundError:
            return []
        gens = []
        for n in names:
            if n.startswith(_GEN_PREFIX) and n.endswith(_GEN_SUFFIX):
                try:
                    gens.append(int(n[len(_GEN_PREFIX):-len(_GEN_SUFFIX)]))
                except ValueError:
                    continue
        return sorted(gens)

    def seed_generation(self, gen: int) -> None:
        """Advisory warm-start hint (a restarted daemon's checkpoint rides
        this in): primes the generation cursor so freshness checks and
        publish attempts start at the right slot.  Never trusted over a
        live LIST — a wrong seed costs one extra conflict, never a wrong
        manifest."""
        with self._lock:
            self._gen_hint = max(self._gen_hint, int(gen))

    def head_generation(self) -> int:
        """The newest existing generation number (0 = empty catalog); one
        LIST request."""
        gens = self._scan()
        head = gens[-1] if gens else 0
        with self._lock:
            self._gen_hint = max(self._gen_hint, head)
        return head

    # ------------------------------------------------------------------ load
    def load(self) -> tuple[int, dict]:
        """``(generation, manifest)`` of the newest readable+parseable
        generation; ``(0, {})`` for an empty catalog.  Unreadable newest
        generations (crash mid-publish of a non-atomic store, corruption)
        are skipped, not fatal."""
        gens = self._scan()
        with self._lock:
            self._gen_hint = max(self._gen_hint, gens[-1] if gens else 0)
        for gen in reversed(gens):
            payload = self.load_generation(gen)
            if payload is not None:
                return gen, payload
            with self._lock:
                self.load_fallbacks += 1
        return 0, {}

    def load_generation(self, gen: int) -> dict | None:
        """One specific generation's manifest, or None when unreadable."""
        try:
            payload = json.loads(self.fs.read_bytes(self._path(gen)))
            if payload.get("version") != CATALOG_VERSION:
                raise ValueError(f"unknown catalog version "
                                 f"{payload.get('version')!r}")
            return payload
        except Exception:
            return None

    # --------------------------------------------------------------- publish
    def publish(self, manifest: dict, *, base_generation: int) -> int:
        """Publish ``manifest`` as generation ``base_generation + 1``.

        ONE conditional put — the atomic commit point of the whole
        catalog.  Raises :class:`CatalogConflict` when that generation
        already exists (a racing publisher won); the caller re-reads and
        rebases.  On success, prunes the generation that fell off the
        retention window, best-effort.
        """
        gen = int(base_generation) + 1
        manifest = dict(manifest)
        manifest["version"] = CATALOG_VERSION
        manifest["generation"] = gen
        data = json.dumps(manifest, sort_keys=True).encode()
        try:
            self.fs.write_bytes(self._path(gen), data)
        except PutIfAbsentError:
            with self._lock:
                self.conflicts += 1
                self._gen_hint = max(self._gen_hint, gen)
            raise CatalogConflict(
                f"catalog generation {gen} was published concurrently")
        with self._lock:
            self.publishes += 1
            self._gen_hint = max(self._gen_hint, gen)
        stale = gen - self.retain
        if stale >= 1:
            try:
                self.fs.delete(self._path(stale))
            except Exception:
                pass        # retention is best-effort; never fail a publish
        return gen
