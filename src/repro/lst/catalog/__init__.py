"""Catalog subsystem: one consistent entry point over XTable-synced tables.

Each table syncs independently (that is what makes the write path
O(change)), but a *dataset* is usually many tables — and a reader joining
orders against customers must never see orders at cycle N with customers
at cycle N-1.  This package closes that gap (ROADMAP open item 2):

* ``pointer``  — :class:`TablePointer` / :class:`ViewRef`: immutable
                 name -> (base path, format views, pinned head token +
                 commit) registration records.
* ``store``    — :class:`CatalogStore`: generation-numbered manifest
                 documents persisted through the ``FileSystem`` protocol;
                 publishing is ONE atomic put-if-absent (the same
                 durability pattern as ``core/checkpoint.py``), losers
                 get :class:`CatalogConflict`.
* ``catalog``  — :class:`Catalog` / :class:`CatalogSnapshot` /
                 :class:`CatalogTransaction`: optimistic **group
                 commit** — any number of pointer and group updates
                 staged together become visible in one atomic manifest
                 swap, so cross-table readers observe either all of a
                 publish or none of it.

The daemon publishes through it (``catalog:`` config block), the read
plane pins cross-table reads to one generation
(:meth:`~repro.serve.read_plane.SnapshotServer.read_group`), and
``ServeEngine.from_lake`` resolves tables by catalog name.  See
``docs/catalog-registration.md`` for the end-to-end walkthrough.
"""

from repro.lst.catalog.catalog import (Catalog, CatalogSnapshot,
                                       CatalogTransaction, UnknownTableError)
from repro.lst.catalog.pointer import (TablePointer, ViewRef,
                                       pointer_from_json, pointer_to_json)
from repro.lst.catalog.store import (CATALOG_VERSION, CatalogConflict,
                                     CatalogStore)

__all__ = ["Catalog", "CatalogSnapshot", "CatalogTransaction",
           "UnknownTableError", "TablePointer", "ViewRef",
           "pointer_to_json", "pointer_from_json", "CATALOG_VERSION",
           "CatalogConflict", "CatalogStore"]
