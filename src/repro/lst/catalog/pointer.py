"""Catalog pointer records: one atomic fact per registered table.

A :class:`TablePointer` is everything a reader needs to open one synced
table without touching the table's own metadata first: where it lives
(``base_path``), which format views exist there, and — per view — the
head *token* and head *commit id* the pointer was published at
(:class:`ViewRef`).  The token is the read plane's conditional-GET ETag
(what ``head_token()`` returns); the commit id is what pins a snapshot:
``state_at(commit)`` resolves the exact published state even after the
table has moved on, which is what makes cross-table group reads
consistent instead of merely fresh.

Pointers are immutable values inside a catalog generation manifest — an
update is a NEW pointer in a NEW generation, never a mutation — so a
reader holding a resolved pointer can never observe it change.
"""

from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["ViewRef", "TablePointer", "pointer_to_json",
           "pointer_from_json"]


@dataclass(frozen=True)
class ViewRef:
    """One format view of a table at publish time: the opaque head token
    (conditional-GET identity) and the commit id the view is pinned at."""
    token: str
    commit: str


@dataclass(frozen=True)
class TablePointer:
    """name -> (base path, format views, pinned heads) registration.

    ``views`` maps each published format view to its :class:`ViewRef`;
    ``source_format`` names the writer's native format (the default view
    for readers that do not ask for a specific one).  ``properties`` is
    free-form registration metadata (owner, description, ...).
    """
    name: str
    base_path: str
    source_format: str
    views: dict = field(default_factory=dict)       # fmt -> ViewRef
    properties: dict = field(default_factory=dict)

    def __post_init__(self):
        if not self.name:
            raise ValueError("pointer name must be non-empty")
        if not self.base_path:
            raise ValueError("pointer base_path must be non-empty")
        if self.source_format not in self.views:
            raise ValueError(
                f"pointer {self.name!r} must carry a view for its source "
                f"format {self.source_format!r}; has {sorted(self.views)}")

    @property
    def formats(self) -> tuple:
        """The published format views, source format first."""
        rest = sorted(f for f in self.views if f != self.source_format)
        return (self.source_format, *rest)

    def view(self, fmt: str | None = None) -> ViewRef:
        """The pinned head of ``fmt`` (default: the source format view).

        Raises ``KeyError`` with the available views when the requested
        one was not published — a pointer never silently substitutes a
        different (differently pinned) view.
        """
        fmt = fmt or self.source_format
        ref = self.views.get(fmt)
        if ref is None:
            raise KeyError(
                f"table {self.name!r} has no published {fmt!r} view "
                f"(published: {sorted(self.views)})")
        return ref


def pointer_to_json(p: TablePointer) -> dict:
    return {"name": p.name, "basePath": p.base_path,
            "sourceFormat": p.source_format,
            "views": {f: {"token": r.token, "commit": r.commit}
                      for f, r in sorted(p.views.items())},
            "properties": dict(p.properties)}


def pointer_from_json(d: dict) -> TablePointer:
    return TablePointer(
        name=d["name"], base_path=d["basePath"],
        source_format=d["sourceFormat"],
        views={f: ViewRef(v["token"], v["commit"])
               for f, v in d.get("views", {}).items()},
        properties=dict(d.get("properties", {})))
