"""The "engine" role: a format-agnostic table API over any LST.

Plays the part Spark/Trino/Flink play in the paper's demo — an engine that
reads/writes a table *through one format's connector*.  Scan planning uses
the metadata layer only (partition pruning + column min/max stats), which is
the mechanism behind the paper's Scenario 3 (Trino running faster on Iceberg
statistics): after an XTable sync, the same pruning power is available in
every target format because the statistics were translated with the metadata.
"""

from __future__ import annotations

import uuid
from dataclasses import dataclass
from typing import Iterator, Mapping

import numpy as np

from repro.lst import chunkfile, delta, hudi, iceberg
from repro.lst.chunkfile import DataFileMeta
from repro.lst.schema import PartitionSpec, Schema, TableState

FORMATS = {"delta": delta.DeltaTable, "iceberg": iceberg.IcebergTable,
           "hudi": hudi.HudiTable}


@dataclass(frozen=True)
class Predicate:
    """column <op> value; op in {==, <=, >=, <, >}. Stats-prunable."""
    column: str
    op: str
    value: object

    def may_match_file(self, f: DataFileMeta) -> bool:
        # partition pruning
        if self.column in f.partition_values:
            pv = f.partition_values[self.column]
            try:
                pv = type(self.value)(pv)
            except (TypeError, ValueError):
                pass
            return _cmp(pv, self.op, self.value, exact=True)
        st = f.column_stats.get(self.column)
        if st is None or st.min is None or st.max is None:
            return True  # no stats -> cannot prune
        if self.op == "==":
            return st.min <= self.value <= st.max
        if self.op in ("<", "<="):
            return _cmp(st.min, self.op, self.value, exact=False)
        if self.op in (">", ">="):
            return _cmp(st.max, self.op, self.value, exact=False)
        return True

    def mask(self, col: np.ndarray) -> np.ndarray:
        return _cmp(col, self.op, self.value, exact=True)


def _cmp(lhs, op, rhs, exact: bool):
    if op == "==":
        return lhs == rhs if exact else True
    return {"<": lhs < rhs, "<=": lhs <= rhs,
            ">": lhs > rhs, ">=": lhs >= rhs}[op]


class LakeTable:
    """Engine-facing handle: open with ANY format, same logical table."""

    def __init__(self, fs, base_path: str, handle):
        self.fs = fs
        self.base = base_path
        self.handle = handle

    # ------------------------------------------------------------- lifecycle
    @classmethod
    def create(cls, fs, base_path: str, schema: Schema, fmt: str,
               partition_spec: PartitionSpec = PartitionSpec(),
               properties: dict | None = None) -> "LakeTable":
        handle = FORMATS[fmt].create(fs, base_path, schema, partition_spec,
                                     properties)
        return cls(fs, base_path, handle)

    @classmethod
    def open(cls, fs, base_path: str, fmt: str) -> "LakeTable":
        return cls(fs, base_path, FORMATS[fmt].open(fs, base_path))

    @property
    def format(self) -> str:
        return self.handle.format

    def state(self, version: str | None = None) -> TableState:
        return self.handle.snapshot(version)

    def history(self) -> list[str]:
        return self.handle.versions()

    # ----------------------------------------------------------------- write
    def append(self, columns: Mapping[str, np.ndarray], *,
               rows_per_file: int | None = None) -> str:
        """Append rows; splits into partition-directory chunk files."""
        st = self.state()
        pcols = st.partition_spec.column_names()
        n = len(next(iter(columns.values())))
        groups: dict[tuple, np.ndarray] = {(): np.arange(n)}
        if pcols:
            keys = np.stack([np.asarray(columns[c]).astype(str) for c in pcols], 1)
            groups = {}
            for i, k in enumerate(map(tuple, keys)):
                groups.setdefault(k, []).append(i)
            groups = {k: np.array(v) for k, v in groups.items()}
        version = self.handle.current_version()
        files = []
        for key, idx in groups.items():
            pv = dict(zip(pcols, key))
            sub = {c: np.asarray(a)[idx] for c, a in columns.items()}
            splits = [sub] if not rows_per_file else [
                {c: a[i:i + rows_per_file] for c, a in sub.items()}
                for i in range(0, len(idx), rows_per_file)]
            for part in splits:
                fid = uuid.uuid4().hex[:12]
                pdir = st.partition_spec.path_for(pv) if pv else "data"
                files.append((f"{pdir}/{fid}_{version}.chunk", part, pv, None))
        # all chunk files of the commit flushed in one pipelined round; the
        # metadata commit below is what makes them visible
        adds = chunkfile.write_chunks(self.fs, self.base, files)
        return self.handle.commit(adds, operation="WRITE")

    def delete_where(self, pred: Predicate) -> str:
        """Copy-on-write delete (paper §2, Listing 1 line 3)."""
        st = self.state()
        version = self.handle.current_version()
        removes, rewrites = [], []
        for f in st.files.values():
            if not pred.may_match_file(f):
                continue
            cols, extra = chunkfile.read_chunk(self.fs, self.base, f.path)
            keep = ~pred.mask(cols[pred.column])
            if keep.all():
                continue
            removes.append(f.path)
            if keep.any():
                fid = uuid.uuid4().hex[:12]
                pdir = f.path.rsplit("/", 1)[0]
                rel = f"{pdir}/{fid}_{version}.chunk"
                rewrites.append((rel, {c: a[keep] for c, a in cols.items()},
                                 f.partition_values, extra))
        if not removes:
            return self.handle.current_version()
        # the copied (COW-rewritten) chunk files flush in one pipelined round
        adds = chunkfile.write_chunks(self.fs, self.base, rewrites)
        return self.handle.commit(adds, removes, operation="DELETE")

    def evolve_schema(self, new_schema: Schema) -> str:
        return self.handle.commit(schema=new_schema, operation="ALTER")

    # ------------------------------------------------------------------ read
    def scan(self, *predicates: Predicate,
             version: str | None = None,
             columns: list[str] | None = None) -> Iterator[dict]:
        """Yield per-file column dicts; files pruned via metadata stats.

        All surviving files are fetched in ONE pipelined batch round, and
        a ``columns`` projection is pushed below the round trip: only the
        requested + predicate columns' byte ranges are read through the
        CHK3 column index (CHK2 files fall back to full bodies in the
        same round) — the local-API scan gets the same economics as the
        read plane's.
        """
        st = self.state(version)
        plan = self.plan_files(st, predicates)
        paths = [f.path for f in plan]
        if columns:
            need = sorted({*columns, *(p.column for p in predicates)})
            bodies = [cols for cols, _nbytes in chunkfile.read_chunks_columns(
                self.fs, self.base, paths, need)]
        else:
            bodies = [cols for cols, _extra in chunkfile.read_chunks(
                self.fs, self.base, paths)]
        for f, cols in zip(plan, bodies):
            mask = np.ones(f.record_count, bool)
            for p in predicates:
                if p.column in cols:
                    mask &= p.mask(cols[p.column])
            if columns:
                cols = {c: cols[c] for c in columns if c in cols}
            yield {c: a[mask] if a.shape[:1] == mask.shape else a
                   for c, a in cols.items()}

    def plan_files(self, st: TableState,
                   predicates: tuple[Predicate, ...] = ()) -> list[DataFileMeta]:
        """Scan planning over metadata only — the Scenario-3 mechanism."""
        return [f for f in st.files.values()
                if all(p.may_match_file(f) for p in predicates)]

    def read_all(self, *predicates: Predicate, version: str | None = None,
                 columns: list[str] | None = None) -> dict:
        batches = list(self.scan(*predicates, version=version,
                                 columns=columns))
        if not batches:
            return {}
        return {c: np.concatenate([b[c] for b in batches])
                for c in batches[0]}

    def verify_stats(self, version: str | None = None) -> list[str]:
        """Cross-check the metadata layer against the data files' own
        stats footers; returns the paths that disagree.

        This is the integrity check behind metadata-only translation: every
        format's metadata must carry the same nrows/min/max/null counts the
        chunk footers do, or pruning gives wrong answers after a sync.  The
        footers are fetched with batched ranged reads
        (:func:`~repro.lst.chunkfile.read_chunks_stats`) — two pipelined
        rounds for the whole table, never touching column data.
        """
        st = self.state(version)
        metas = list(st.files.values())
        footers = chunkfile.read_chunks_stats(self.fs, self.base,
                                              [f.path for f in metas])

        def disagree(meta_stats: dict, footer_stats: dict) -> bool:
            # a format may carry no stats for a column (that only weakens
            # pruning); corruption is carrying DIFFERENT values
            for c, fstat in footer_stats.items():
                m = meta_stats.get(c)
                if m is not None and (m.min, m.max, m.nan_count) != \
                        (fstat.min, fstat.max, fstat.nan_count):
                    return True
            return False

        return [f.path for f, (nrows, stats) in zip(metas, footers)
                if f.record_count != nrows or disagree(f.column_stats, stats)]
