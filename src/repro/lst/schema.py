"""Logical table definition shared by the engine layer.

Each LST format keeps its *own on-disk encoding* of this information (Delta
schemaString / Iceberg field-id schema / Hudi Avro record schema — see the
format modules); these classes are the in-memory logical view an engine works
with, and the vocabulary the tests use to compare table states across formats.

Canonical types: int32 int64 float32 float64 string bool binary timestamp
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Mapping

CANONICAL_TYPES = ("int32", "int64", "float32", "float64", "string", "bool",
                   "binary", "timestamp")

NUMPY_TO_CANONICAL = {"<i4": "int32", "<i8": "int64", "<f4": "float32",
                      "<f8": "float64", "|b1": "bool"}


@dataclass(frozen=True)
class Field:
    name: str
    type: str
    nullable: bool = True
    field_id: int | None = None   # Iceberg needs stable column ids

    def __post_init__(self):
        if self.type not in CANONICAL_TYPES:
            raise ValueError(f"unknown type {self.type!r}")


@dataclass(frozen=True)
class Schema:
    fields: tuple[Field, ...]
    schema_id: int = 0

    def __init__(self, fields, schema_id: int = 0):
        object.__setattr__(self, "fields", tuple(fields))
        object.__setattr__(self, "schema_id", schema_id)

    def names(self) -> list[str]:
        return [f.name for f in self.fields]

    def field(self, name: str) -> Field:
        for f in self.fields:
            if f.name == name:
                return f
        raise KeyError(name)

    def with_ids(self) -> "Schema":
        """Assign sequential field ids where missing (Delta/Hudi -> Iceberg)."""
        used = [f.field_id for f in self.fields if f.field_id is not None]
        nxt = max(used, default=0) + 1
        out = []
        for f in self.fields:
            if f.field_id is None:
                f = replace(f, field_id=nxt)
                nxt += 1
            out.append(f)
        return Schema(out, self.schema_id)

    def add_field(self, f: Field) -> "Schema":
        return Schema(self.fields + (f,), self.schema_id + 1).with_ids()

    def logical_eq(self, other: "Schema") -> bool:
        """Equality up to field ids (ids are an Iceberg-only concept)."""
        return [(f.name, f.type, f.nullable) for f in self.fields] == \
               [(f.name, f.type, f.nullable) for f in other.fields]


@dataclass(frozen=True)
class PartitionField:
    source: str                  # source column name
    transform: str = "identity"  # identity | truncate[w] | bucket[n] (identity used)
    name: str | None = None

    @property
    def out_name(self) -> str:
        return self.name or self.source


@dataclass(frozen=True)
class PartitionSpec:
    fields: tuple[PartitionField, ...] = ()

    def __init__(self, fields=()):
        object.__setattr__(self, "fields", tuple(
            PartitionField(f) if isinstance(f, str) else f for f in fields))

    def column_names(self) -> list[str]:
        return [f.source for f in self.fields]

    def path_for(self, partition_values: Mapping) -> str:
        """Hive-style partition path: col=value/..."""
        return "/".join(f"{f.out_name}={partition_values[f.out_name]}"
                        for f in self.fields)


@dataclass(frozen=True)
class CommitEntry:
    """One commit of an LST log, as produced by a single-pass ``replay()``.

    The per-format handles emit these in commit order so the metadata cache
    can serve every ``snapshot(commit)`` / ``changes(commit)`` question from
    ONE scan of the log instead of re-replaying per commit.  ``schema`` /
    ``partition_spec`` / ``properties`` / ``timestamp_ms`` are *as of* this
    commit (i.e. what ``snapshot(version)`` would report).
    """
    version: str
    timestamp_ms: int
    operation: str
    adds: tuple                       # tuple[DataFileMeta]
    removes: tuple                    # tuple[str] — removed file paths
    schema: Schema
    partition_spec: PartitionSpec
    properties: dict
    info: dict                        # commit user-metadata (format-native)


@dataclass
class TableState:
    """A point-in-time logical snapshot of an LST (any format)."""
    format: str
    version: str                      # format-native commit/snapshot/instant id
    timestamp_ms: int
    schema: Schema
    partition_spec: PartitionSpec
    files: dict                       # rel path -> DataFileMeta (live files only)
    properties: dict = field(default_factory=dict)

    def total_records(self) -> int:
        return sum(f.record_count for f in self.files.values())

    def total_bytes(self) -> int:
        return sum(f.size_bytes for f in self.files.values())
