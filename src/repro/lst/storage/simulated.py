"""Simulated object store: latency + fault injection over any FileSystem.

Decorator that makes a backing store behave like a remote object store:

* every request pays a configurable round-trip time (``rtt_ms``);
* requests fail probabilistically with a 503-style
  :class:`~repro.lst.storage.base.TransientStorageError` — either *before*
  the operation applies (``fault_rate``, a rejected/throttled request) or,
  for writes, *after* it applied (``ambiguous_put_rate``, the response was
  lost on the wire) — the case a retry-safe put-if-absent must disambiguate;
* batch reads (``read_many`` / ``read_many_ranges``) and batch writes
  (``write_many``) are pipelined over ``pipeline_depth`` concurrent
  in-flight requests, so N independent metadata fetches or staged puts
  cost ~ceil(N / depth) RTTs instead of N.  ``pipeline_depth=1`` degrades
  to one round trip per object — the comparison arm of
  ``bench_object_store_sync`` / ``bench_write_pipeline``.

A :class:`CrashSchedule` (``arm_crash``) additionally injects deterministic
*process death* at an exact request index — :class:`~repro.lst.storage.base
.SimulatedCrash` rips through every retry/isolation layer like SIGKILL —
which is what the crash-recovery chaos campaign sweeps over a drain's whole
request stream.

Fault injection is seeded and lock-protected, so a test run is
reproducible; ``injected_faults`` / ``requests`` counters expose what the
simulation actually did, and ``serial_rounds()`` reports how many
*sequential* round-trip slots the request stream occupied (a batch of N
over depth d counts ceil(N / d), not N) — the number the write-pipelining
benchmarks report as "serial RTTs per commit".
"""

from __future__ import annotations

import random
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Sequence

from repro.lst.storage.base import (PutIfAbsentError, SimulatedCrash,
                                    TransientStorageError)

_MAX_POOL = 32


@dataclass(frozen=True)
class CrashSchedule:
    """Deterministic process-death injection: die at the Nth request.

    ``at_request`` is 1-based over the store's global request counter, so a
    schedule pins the crash to one exact point of a drain's request stream —
    sweeping N across the whole stream hits every interesting window (mid
    ``write_many`` pipeline, between a staged flush and its commit-point
    put, mid checkpoint save...).  Requests *after* the fatal one also die:
    the process is gone, nothing more lands.

    ``after_apply=True`` makes the fatal request a *torn write*: the PUT
    applies in the store before the crash (the response never reaches the
    caller) — the other half of the ambiguity a crash-safe commit protocol
    must survive.  Non-write requests don't mutate the store, so for them
    ``after_apply`` is indistinguishable from a pre-apply death.
    """
    at_request: int
    after_apply: bool = False

    def __post_init__(self):
        if self.at_request < 1:
            raise ValueError("at_request is 1-based and must be >= 1")


def _raise_first(settled: list) -> list[bytes]:
    for r in settled:
        if isinstance(r, Exception):
            raise r
    return settled


@dataclass(frozen=True)
class StorageProfile:
    """Behavior knobs for a SimulatedObjectStore."""
    rtt_ms: float = 0.0            # per-request round-trip time
    fault_rate: float = 0.0        # P(request rejected before applying)
    ambiguous_put_rate: float = 0.0  # P(write applies but the response is lost)
    pipeline_depth: int = 16       # concurrent in-flight batch reads (1 = serial)
    seed: int = 0


class SimulatedObjectStore:
    """Wrap ``inner`` with object-store latency/fault behavior."""

    def __init__(self, inner, profile: StorageProfile | None = None, **kw):
        self.inner = inner
        self.profile = profile or StorageProfile(**kw)
        if self.profile.pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")
        self._rng = random.Random(self.profile.seed)
        self._lock = threading.Lock()
        self._pool: ThreadPoolExecutor | None = None
        self.requests = 0
        self.injected_faults = 0
        self.batch_items = 0     # requests issued through a pipelined batch
        self.batch_rounds = 0    # sequential rounds those batches occupied
        self.crash_schedule: CrashSchedule | None = None
        self.crashed = False     # a schedule fired (at least once)

    def arm_crash(self, schedule: CrashSchedule | None) -> None:
        """Install (or, with ``None``, clear) a crash schedule.  The request
        counter keeps running from where it is — arm before the work whose
        stream the schedule indexes."""
        with self._lock:
            self.crash_schedule = schedule
            self.crashed = False

    @property
    def latency_bound(self) -> bool:
        """Advertises per-request round trips to ``storage.base
        .latency_bound`` (the executor widens its pool only when waiting
        on RTTs actually overlaps)."""
        return self.profile.rtt_ms > 0

    # -- simulation core ---------------------------------------------------
    def _roll(self, rate: float) -> bool:
        if rate <= 0.0:
            return False
        with self._lock:
            hit = self._rng.random() < rate
            if hit:
                self.injected_faults += 1
            return hit

    def _request(self, op: str) -> int:
        """One round trip: pay the RTT, maybe get throttled (pre-apply).
        Returns this request's 1-based index in the store's stream."""
        with self._lock:
            self.requests += 1
            n = self.requests
            cs = self.crash_schedule
            # the fatal PUT of an after-apply schedule passes through here
            # and dies in write_bytes AFTER the store applied it; a fatal
            # non-write has nothing to tear, so it dies pre-apply; every
            # request past the fatal one dies outright — the process is gone
            defer = cs is not None and cs.after_apply and op == "PUT"
            if cs is not None and (n > cs.at_request or
                                   (n == cs.at_request and not defer)):
                self.crashed = True
                raise SimulatedCrash(f"process died at request {n} ({op})")
        if self.profile.rtt_ms > 0:
            time.sleep(self.profile.rtt_ms / 1000.0)
        if self._roll(self.profile.fault_rate):
            raise TransientStorageError(f"503 SlowDown ({op})")
        return n

    def _batch_pool(self, n: int) -> ThreadPoolExecutor:
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=min(self.profile.pipeline_depth, _MAX_POOL),
                    thread_name_prefix="objstore-sim")
            return self._pool

    def close(self) -> None:
        """Release the batch-read thread pool (recreated lazily if the
        store is used again)."""
        with self._lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=False)

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    # -- reads ------------------------------------------------------------
    def read_bytes(self, path: str) -> bytes:
        self._request("GET")
        return self.inner.read_bytes(path)

    def read_bytes_range(self, path: str, offset: int, length: int) -> bytes:
        self._request("GET")
        return self.inner.read_bytes_range(path, offset, length)

    def read_many(self, paths: Sequence[str]) -> list[bytes]:
        return _raise_first(self.read_many_settled(paths))

    def read_many_ranges(
            self, requests: Sequence[tuple[str, int, int]]) -> list[bytes]:
        return _raise_first(self.read_many_ranges_settled(requests))

    # settled variants: per-item outcomes (bytes | TransientStorageError),
    # the contract a retry layer needs to refetch ONLY the throttled items
    # of a batch instead of replaying the whole fan-out
    def read_many_settled(self, paths: Sequence[str]) -> list:
        return self._fan_out([(p, None) for p in paths], self._read_one)

    def read_many_ranges_settled(
            self, requests: Sequence[tuple[str, int, int]]) -> list:
        return self._fan_out([(p, (off, ln)) for p, off, ln in requests],
                             self._read_one)

    def _fan_out(self, items: list, one) -> list:
        n = len(items)
        if n:
            with self._lock:
                self.batch_items += n
                self.batch_rounds += -(-n // self.profile.pipeline_depth)
        if self.profile.pipeline_depth <= 1 or n <= 1:
            return [one(it) for it in items]
        # each in-flight request pays its RTT on a pool thread, so the batch
        # costs ~ceil(N / depth) round trips of wall clock
        return list(self._batch_pool(n).map(one, items))

    def _read_one(self, item):
        path, rng = item
        try:
            if rng is None:
                return self.read_bytes(path)
            return self.read_bytes_range(path, *rng)
        except TransientStorageError as e:
            return e

    def serial_rounds(self) -> int:
        """Sequential round-trip slots the request stream occupied so far:
        every non-batched request is its own round; a pipelined batch of N
        counts ceil(N / pipeline_depth)."""
        with self._lock:
            return self.requests - self.batch_items + self.batch_rounds

    def exists(self, path: str) -> bool:
        self._request("HEAD")
        return self.inner.exists(path)

    def list_dir(self, path: str) -> list[str]:
        self._request("LIST")
        return self.inner.list_dir(path)

    def size(self, path: str) -> int:
        self._request("HEAD")
        return self.inner.size(path)

    # -- writes -----------------------------------------------------------
    def write_bytes(self, path: str, data: bytes, *, overwrite: bool = False) -> None:
        n = self._request("PUT")
        self.inner.write_bytes(path, data, overwrite=overwrite)
        cs = self.crash_schedule
        if cs is not None and cs.after_apply and n == cs.at_request:
            # torn write: the object landed, the process died before the
            # response came back
            with self._lock:
                self.crashed = True
            raise SimulatedCrash(f"process died after request {n} applied "
                                 f"(PUT {path})")
        if self._roll(self.profile.ambiguous_put_rate):
            # the write landed but the caller never hears about it
            raise TransientStorageError("timeout after apply (PUT)")

    def write_many(self, items: Sequence[tuple[str, bytes]], *,
                   overwrite: bool = False) -> None:
        _raise_first(self.write_many_settled(items, overwrite=overwrite))

    def write_many_settled(self, items: Sequence[tuple[str, bytes]], *,
                           overwrite: bool = False) -> list:
        """Pipelined batch puts with per-item *settled* outcomes: ``None``
        on success, :class:`TransientStorageError` (throttled, or applied
        with the response lost) or :class:`PutIfAbsentError` (lost the
        create race) per failed item — the contract the retry layer needs
        to re-put ONLY the failed items and run the ambiguous-put
        disambiguation per item instead of replaying the whole fan-out."""
        def one(item):
            path, data = item
            try:
                self.write_bytes(path, data, overwrite=overwrite)
                return None
            except (TransientStorageError, PutIfAbsentError) as e:
                return e

        return self._fan_out(list(items), one)

    def delete(self, path: str) -> None:
        self._request("DELETE")
        self.inner.delete(path)
