"""Instrumented FileSystem: request/byte/retry counters feeding Telemetry.

Outermost wrapper of the storage stack (see ``registry.build_fs``): it
counts *logical* storage requests — what the sync architecture asked the
store for, independent of how many physical attempts the retry layer made —
per category (get/put/list/head/delete) plus bytes moved, and mirrors the
totals into the run's :class:`~repro.core.telemetry.Telemetry` counters
(``storage.get``, ``storage.put``, ...).

Counters are also tracked **per thread**, and one sync unit runs entirely
on one executor thread, so ``scoped()`` gives the executor an exact
per-unit request census — the number the O(1)-target-reads /
O(new-commits)-source-reads guarantees are asserted against in tier-1.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Sequence

from repro.lst.storage.base import flush_many

COUNT_KEYS = ("get", "put", "list", "head", "delete",
              "bytes_read", "bytes_written")


class StorageStats:
    """A plain counter bag; ``requests`` sums the request categories."""

    __slots__ = COUNT_KEYS

    def __init__(self, **kw):
        for k in COUNT_KEYS:
            setattr(self, k, kw.get(k, 0))

    @property
    def requests(self) -> int:
        return self.get + self.put + self.list + self.head + self.delete

    def as_dict(self) -> dict:
        d = {k: getattr(self, k) for k in COUNT_KEYS}
        d["requests"] = self.requests
        return d

    def __repr__(self):
        return f"StorageStats({self.as_dict()})"


class InstrumentedFS:
    """Count every request (and the bytes it moved) on the way through."""

    def __init__(self, inner, telemetry=None):
        self.inner = inner
        self.telemetry = telemetry
        self._lock = threading.Lock()
        self._total = StorageStats()
        self._tls = threading.local()

    # -- counting core -----------------------------------------------------
    def _bump(self, key: str, n: int = 1) -> None:
        with self._lock:
            setattr(self._total, key, getattr(self._total, key) + n)
        scope = getattr(self._tls, "scope", None)
        if scope is not None:
            setattr(scope, key, getattr(scope, key) + n)
        if self.telemetry is not None:
            self.telemetry.bump(f"storage.{key}", n)

    def stats(self) -> StorageStats:
        with self._lock:
            snap = StorageStats(**{k: getattr(self._total, k)
                                   for k in COUNT_KEYS})
        return snap

    def retries(self) -> int:
        """Transient failures absorbed by a retry layer below, if any."""
        fs = self.inner
        while fs is not None:
            r = getattr(fs, "retries", None)
            if isinstance(r, int):
                return r
            fs = getattr(fs, "inner", None)
        return 0

    @contextmanager
    def scoped(self):
        """Collect this thread's requests for the duration of the block."""
        prev = getattr(self._tls, "scope", None)
        scope = StorageStats()
        self._tls.scope = scope
        try:
            yield scope
        finally:
            self._tls.scope = prev

    # -- reads ------------------------------------------------------------
    def read_bytes(self, path: str) -> bytes:
        self._bump("get")
        data = self.inner.read_bytes(path)
        self._bump("bytes_read", len(data))
        return data

    def read_bytes_range(self, path: str, offset: int, length: int) -> bytes:
        self._bump("get")
        data = self.inner.read_bytes_range(path, offset, length)
        self._bump("bytes_read", len(data))
        return data

    def read_many(self, paths: Sequence[str]) -> list[bytes]:
        paths = list(paths)
        self._bump("get", len(paths))
        out = self.inner.read_many(paths)
        self._bump("bytes_read", sum(len(b) for b in out))
        return out

    def read_many_ranges(
            self, requests: Sequence[tuple[str, int, int]]) -> list[bytes]:
        requests = list(requests)
        self._bump("get", len(requests))
        out = self.inner.read_many_ranges(requests)
        self._bump("bytes_read", sum(len(b) for b in out))
        return out

    def exists(self, path: str) -> bool:
        self._bump("head")
        return self.inner.exists(path)

    def list_dir(self, path: str) -> list[str]:
        self._bump("list")
        return self.inner.list_dir(path)

    def size(self, path: str) -> int:
        self._bump("head")
        return self.inner.size(path)

    # -- writes -----------------------------------------------------------
    def write_bytes(self, path: str, data: bytes, *, overwrite: bool = False) -> None:
        self._bump("put")
        self._bump("bytes_written", len(data))
        self.inner.write_bytes(path, data, overwrite=overwrite)

    def write_many(self, items: Sequence[tuple[str, bytes]], *,
                   overwrite: bool = False) -> None:
        items = list(items)
        self._bump("put", len(items))
        self._bump("bytes_written", sum(len(d) for _, d in items))
        flush_many(self.inner, items, overwrite=overwrite)

    def delete(self, path: str) -> None:
        self._bump("delete")
        self.inner.delete(path)
