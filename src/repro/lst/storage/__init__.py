"""Pluggable storage backends with object-store semantics.

The storage subsystem behind every LST handle (ROADMAP "Storage backends"):

* ``base``         — the widened ``FileSystem`` protocol (batch reads,
                     ranged reads, conditional puts) and the error taxonomy
                     (``PutIfAbsentError`` / ``TransientStorageError``).
* ``local``        — POSIX-backed ``LocalFS`` (atomic staged writes).
* ``memory``       — in-memory ``MemoryFS`` object store.
* ``simulated``    — ``SimulatedObjectStore`` decorator: per-request RTT,
                     probabilistic 503s, pipelined batch reads.
* ``retry``        — ``RetryPolicy`` / ``RetryingFS``: exponential backoff
                     with a retry-safe put-if-absent.
* ``instrumented`` — ``InstrumentedFS``: request/byte/retry counters feeding
                     ``Telemetry``, with per-thread scoping for per-unit
                     request censuses.
* ``registry``     — URI-scheme registry: ``make_fs``, ``resolve_uri``,
                     ``layer_fs`` stack composition.
"""

from repro.lst.storage.base import (FileSystem, PutIfAbsentError,
                                    SequentialBatchMixin, SimulatedCrash,
                                    StorageRetryExhausted,
                                    TransientStorageError, fetch_many,
                                    fetch_many_ranges, flush_many, join,
                                    latency_bound)
from repro.lst.storage.instrumented import InstrumentedFS, StorageStats
from repro.lst.storage.local import LocalFS
from repro.lst.storage.memory import MemoryFS
from repro.lst.storage.registry import (clear_shared_stores, layer_fs,
                                        make_fs, register_scheme,
                                        resolve_uri, scheme_of, shared_store,
                                        split_uri)
from repro.lst.storage.retry import RetryingFS, RetryPolicy
from repro.lst.storage.simulated import (CrashSchedule, SimulatedObjectStore,
                                         StorageProfile)

__all__ = [
    "FileSystem", "PutIfAbsentError", "TransientStorageError",
    "StorageRetryExhausted", "SimulatedCrash", "CrashSchedule",
    "SequentialBatchMixin", "fetch_many",
    "fetch_many_ranges", "flush_many", "join", "latency_bound", "LocalFS",
    "MemoryFS",
    "SimulatedObjectStore", "StorageProfile", "RetryingFS", "RetryPolicy",
    "InstrumentedFS", "StorageStats", "make_fs", "register_scheme",
    "resolve_uri", "scheme_of", "split_uri", "layer_fs", "shared_store",
    "clear_shared_stores",
]
