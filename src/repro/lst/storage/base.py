"""Widened FileSystem protocol + object-store error taxonomy.

The paper's XTable reaches data lakes through a pluggable file system (ABFS
in Listing 2).  Two properties of real object stores shape this protocol:

* **Atomic put-if-absent** — two writers racing to create the same object
  must see exactly one winner (ABFS ETag, S3 If-None-Match, GCS generation
  preconditions).  Every LST commit protocol is built on it; losing the
  race raises :class:`PutIfAbsentError`.
* **Per-request latency and transient throttling** — each call is a network
  round trip that may come back 503 (:class:`TransientStorageError`).
  Independent metadata fetches must therefore be *batched*
  (:meth:`FileSystem.read_many` / :meth:`FileSystem.read_many_ranges`) so a
  log replay is pipelined instead of one RTT per object, and writes must be
  retried with backoff (see ``retry.py``) in a way that distinguishes
  "lost the commit race" from "the store hiccuped".

Range semantics (object-store style, mirrors HTTP Range):

* ``offset < 0`` — suffix read: the last ``length`` bytes (``offset`` is
  ``-length`` by convention, only its sign matters).
* ``length < 0`` — read from ``offset`` to the end of the object.
"""

from __future__ import annotations

from typing import Protocol, Sequence, runtime_checkable


class PutIfAbsentError(FileExistsError):
    """Raised when an exclusive create loses the race (commit conflict)."""


class TransientStorageError(IOError):
    """A retryable request failure (503 SlowDown / throttle / timeout).

    The request may or may not have been applied by the store — callers
    retrying a put-if-absent must treat a subsequent ``PutIfAbsentError``
    as potentially their own earlier attempt having landed (see
    ``retry.RetryingFS``).
    """


class StorageRetryExhausted(IOError):
    """A transiently-failing request did not succeed within the policy."""


class SimulatedCrash(BaseException):
    """Process death injected by a chaos :class:`~repro.lst.storage
    .simulated.CrashSchedule` — the request (and every request after it)
    dies because *the caller's process* died, not because the store
    hiccuped.

    Deliberately NOT a :class:`TransientStorageError` (retry layers must
    not absorb it) and not even an :class:`Exception` (per-unit / per-table
    error isolation must not contain it): a crash rips straight through
    executor and daemon like ``SIGKILL`` would, which is exactly what the
    crash-recovery tests are simulating.
    """


@runtime_checkable
class FileSystem(Protocol):
    def read_bytes(self, path: str) -> bytes: ...
    def read_bytes_range(self, path: str, offset: int, length: int) -> bytes: ...
    def read_many(self, paths: Sequence[str]) -> list[bytes]: ...
    def read_many_ranges(
        self, requests: Sequence[tuple[str, int, int]]) -> list[bytes]: ...
    def write_bytes(self, path: str, data: bytes, *, overwrite: bool = False) -> None: ...
    def write_many(self, items: Sequence[tuple[str, bytes]], *,
                   overwrite: bool = False) -> None: ...
    def exists(self, path: str) -> bool: ...
    def list_dir(self, path: str) -> list[str]: ...
    def size(self, path: str) -> int: ...
    def delete(self, path: str) -> None: ...


class SequentialBatchMixin:
    """Default (unpipelined) batch reads/writes: one request per object.

    Concrete stores whose requests are local memory/disk operations inherit
    this; the :class:`~repro.lst.storage.simulated.SimulatedObjectStore`
    overrides these methods with a concurrent fan-out so a batch costs
    ~ceil(N / pipeline_depth) round trips instead of N.
    """

    def read_many(self, paths: Sequence[str]) -> list[bytes]:
        return [self.read_bytes(p) for p in paths]

    def read_many_ranges(
            self, requests: Sequence[tuple[str, int, int]]) -> list[bytes]:
        return [self.read_bytes_range(p, off, ln) for p, off, ln in requests]

    def write_many(self, items: Sequence[tuple[str, bytes]], *,
                   overwrite: bool = False) -> None:
        for p, data in items:
            self.write_bytes(p, data, overwrite=overwrite)


def fetch_many(fs, paths: Sequence[str]) -> list[bytes]:
    """``fs.read_many`` with a sequential fallback for minimal FS objects.

    The LST handles funnel every independent multi-object fetch through
    this helper, so any duck-typed FileSystem (test doubles subclassing
    nothing, foreign implementations) keeps working while batching-capable
    stores get the pipelined path.
    """
    paths = list(paths)
    if not paths:
        return []
    rm = getattr(fs, "read_many", None)
    if rm is not None:
        return rm(paths)
    return [fs.read_bytes(p) for p in paths]


def fetch_many_ranges(fs, requests: Sequence[tuple[str, int, int]]) -> list[bytes]:
    """``fs.read_many_ranges`` with a sequential fallback (see fetch_many)."""
    requests = list(requests)
    if not requests:
        return []
    rmr = getattr(fs, "read_many_ranges", None)
    if rmr is not None:
        return rmr(requests)
    return [fs.read_bytes_range(p, off, ln) for p, off, ln in requests]


def coalesce_ranges(requests: Sequence[tuple[str, int, int]], *,
                    max_gap: int = 0):
    """Merge per-path overlapping/adjacent byte ranges into one request each.

    ``requests`` are ``(path, offset, length)`` with non-negative offsets
    and positive lengths (suffix / to-EOF reads cannot be coalesced or
    sliced back without knowing the object size).  Two ranges of the same
    path merge when the gap between them is at most ``max_gap`` bytes —
    the columnar projection path uses ``0`` so adjacent column blobs
    become a single ranged GET without ever fetching an unrequested byte.

    Returns ``(merged, slices)``: ``merged`` is the deduplicated request
    list to hand to :func:`fetch_many_ranges`, and ``slices[i] =
    (merged_index, offset, length)`` locates original request ``i``
    inside its merged range (slice the reply with
    ``blob[offset - merged_offset:][:length]``).
    """
    by_path: dict[str, list[tuple[int, int, int]]] = {}
    for i, (path, off, ln) in enumerate(requests):
        if off < 0 or ln < 0:
            raise ValueError("coalesce_ranges needs explicit offset+length "
                             f"ranges, got ({path!r}, {off}, {ln})")
        by_path.setdefault(path, []).append((off, ln, i))
    merged: list[list] = []          # [path, offset, end]
    slices: list = [None] * len(requests)
    for path, items in by_path.items():
        items.sort()
        cur = -1
        for off, ln, i in items:
            if cur >= 0 and off <= merged[cur][2] + max_gap:
                merged[cur][2] = max(merged[cur][2], off + ln)
            else:
                merged.append([path, off, off + ln])
                cur = len(merged) - 1
            slices[i] = (cur, off, ln)
    return [(p, off, end - off) for p, off, end in merged], slices


def fetch_many_ranges_coalesced(
        fs, requests: Sequence[tuple[str, int, int]], *,
        max_gap: int = 0) -> list[bytes]:
    """:func:`fetch_many_ranges` with per-path range coalescing: adjacent
    requested ranges are fetched as single ranged reads (one pipelined
    batch round total) and sliced back per original request."""
    requests = list(requests)
    if not requests:
        return []
    merged, slices = coalesce_ranges(requests, max_gap=max_gap)
    blobs = fetch_many_ranges(fs, merged)
    out = []
    for mi, off, ln in slices:
        start = off - merged[mi][1]
        out.append(blobs[mi][start:start + ln])
    return out


def flush_many(fs, items: Sequence[tuple[str, bytes]], *,
               overwrite: bool = False) -> None:
    """``fs.write_many`` with a sequential fallback (the write-side twin of
    :func:`fetch_many`).

    Target transactions funnel every *staged* (non-commit-point) object —
    iceberg manifests and manifest-lists, hudi requested/inflight markers,
    chunk data files — through this helper, so a pipelining-capable store
    overlaps the puts while any duck-typed FileSystem keeps working.  Staged
    objects must be idempotent (uniquely named, content-deterministic):
    only the commit-point put is ordered, and it never goes through here.
    """
    items = list(items)
    if not items:
        return
    wm = getattr(fs, "write_many", None)
    if wm is not None:
        return wm(items, overwrite=overwrite)
    for p, data in items:
        fs.write_bytes(p, data, overwrite=overwrite)


def latency_bound(fs) -> bool:
    """True when some layer of the storage stack pays a per-request round
    trip (network-style object store), so callers should overlap requests
    with wide I/O concurrency; false for in-memory / local-disk stacks
    where extra threads only fight the GIL over CPU-bound work.

    Layers advertise themselves with a truthy ``latency_bound`` attribute
    (see :class:`~repro.lst.storage.simulated.SimulatedObjectStore`);
    wrappers are unwrapped through their ``inner`` chain.
    """
    hops = 0
    while fs is not None and hops < 16:
        if getattr(fs, "latency_bound", False):
            return True
        fs = getattr(fs, "inner", None)
        hops += 1
    return False


def join(*parts: str) -> str:
    """Join path segments with '/' (object-store style, no os.sep surprises)."""
    cleaned = [p.strip("/") if i else p.rstrip("/") for i, p in enumerate(parts) if p]
    return "/".join(cleaned)
