"""In-memory object store: a flat key -> bytes map with conditional puts.

The reference backend for the simulated-object-store stack: keys are
'/'-separated object names with no real directories (``list_dir`` is a
prefix scan returning immediate children, the way S3 ListObjectsV2 with a
delimiter behaves), every object is written in one shot, and put-if-absent
is atomic under one lock — the conditional-put primitive LST commits rely
on.  State survives across FileSystem *views* of the same store, which is
what lets crash/retry tests reopen "the bucket" after killing a writer.
"""

from __future__ import annotations

import threading

from repro.lst.storage.base import PutIfAbsentError, SequentialBatchMixin


def _norm(path: str) -> str:
    return path.strip("/")


class MemoryFS(SequentialBatchMixin):
    """Thread-safe in-memory object store with object-store semantics."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._objects: dict[str, bytes] = {}

    # -- reads ------------------------------------------------------------
    def read_bytes(self, path: str) -> bytes:
        with self._lock:
            data = self._objects.get(_norm(path))
        if data is None:
            raise FileNotFoundError(path)
        return data

    def read_bytes_range(self, path: str, offset: int, length: int) -> bytes:
        data = self.read_bytes(path)
        if offset < 0:                      # suffix read
            return data[max(0, len(data) - length):]
        if length < 0:                      # to end of object
            return data[offset:]
        return data[offset:offset + length]

    def exists(self, path: str) -> bool:
        key = _norm(path)
        with self._lock:
            if key in self._objects:
                return True
            prefix = key + "/"
            return any(k.startswith(prefix) for k in self._objects)

    def list_dir(self, path: str) -> list[str]:
        prefix = _norm(path) + "/"
        names = set()
        with self._lock:
            for k in self._objects:
                if k.startswith(prefix):
                    names.add(k[len(prefix):].split("/", 1)[0])
        return sorted(names)

    def size(self, path: str) -> int:
        return len(self.read_bytes(path))

    # -- writes -----------------------------------------------------------
    def write_bytes(self, path: str, data: bytes, *, overwrite: bool = False) -> None:
        key = _norm(path)
        with self._lock:
            if not overwrite and key in self._objects:
                raise PutIfAbsentError(path)
            self._objects[key] = bytes(data)

    def delete(self, path: str) -> None:
        with self._lock:
            self._objects.pop(_norm(path), None)

    def clone(self) -> "MemoryFS":
        """Independent snapshot copy of the whole store (objects are
        immutable bytes, so only the key map is copied).  Benchmarks and
        tests use it to run several arms from one identically-built
        starting state."""
        out = MemoryFS()
        with self._lock:
            out._objects = dict(self._objects)
        return out

    # -- introspection (tests / benchmarks) --------------------------------
    def object_count(self) -> int:
        with self._lock:
            return len(self._objects)

    def total_bytes(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._objects.values())
