"""Retry layer: exponential backoff + a retry-safe put-if-absent.

Transient object-store failures (503 SlowDown, dropped responses) are a
fact of life the "negligible overhead" claim has to survive.  Reads and
listings are idempotent — retrying them is trivially safe.  The subtle case
is the conditional put every LST commit is built on: after a transient
failure the request *may have applied* (the response was lost), so a retry
can come back ``PutIfAbsentError`` for one of two very different reasons:

1. **our own earlier attempt landed** — the commit SUCCEEDED; surfacing a
   conflict would make the writer re-commit the same change under a new
   version (duplicate commit);
2. **a concurrent writer actually won the race** — a genuine conflict the
   commit protocol must see so it can re-sync and take the next version.

``RetryingFS.write_bytes`` disambiguates by reading the object back: if the
content equals what we were writing, case 1 — report success; otherwise
case 2 — re-raise the conflict.  (Object payloads embed writer-unique data
— commit timestamps, snapshot UUIDs — so byte-equality identifies the
author, the same trick real lakehouse clients use with ETag comparison.)
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Sequence

from repro.lst.storage.base import (PutIfAbsentError, StorageRetryExhausted,
                                    TransientStorageError)


@dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff: delay(k) = min(max_delay, base * multiplier^k)."""
    max_attempts: int = 5
    base_delay_s: float = 0.01
    multiplier: float = 2.0
    max_delay_s: float = 1.0

    def delay(self, attempt: int) -> float:
        return min(self.max_delay_s,
                   self.base_delay_s * (self.multiplier ** attempt))


class RetryingFS:
    """Wrap a FileSystem so transient failures are retried with backoff.

    ``sleep`` is injectable so tests drive the policy without wall-clock
    waits.  ``retries`` counts the transient failures absorbed (the number
    the instrumented wrapper reports into telemetry).
    """

    def __init__(self, inner, policy: RetryPolicy | None = None,
                 *, sleep: Callable[[float], None] = time.sleep):
        self.inner = inner
        self.policy = policy or RetryPolicy()
        self._sleep = sleep
        self.retries = 0
        self._count_lock = threading.Lock()   # executor threads share this fs

    def _note_retries(self, n: int = 1) -> None:
        with self._count_lock:
            self.retries += n

    # -- core retry loop ---------------------------------------------------
    def _with_retries(self, op: str, fn):
        last: Exception | None = None
        for attempt in range(self.policy.max_attempts):
            try:
                return fn()
            except TransientStorageError as e:
                last = e
                self._note_retries()
                if attempt + 1 < self.policy.max_attempts:
                    self._sleep(self.policy.delay(attempt))
        raise StorageRetryExhausted(
            f"{op} failed after {self.policy.max_attempts} attempts") from last

    # -- reads (idempotent: plain retry) -----------------------------------
    def read_bytes(self, path: str) -> bytes:
        return self._with_retries("GET", lambda: self.inner.read_bytes(path))

    def read_bytes_range(self, path: str, offset: int, length: int) -> bytes:
        return self._with_retries(
            "GET", lambda: self.inner.read_bytes_range(path, offset, length))

    def read_many(self, paths: Sequence[str]) -> list[bytes]:
        return self._batch_with_retries(
            list(paths), getattr(self.inner, "read_many_settled", None),
            self.inner.read_many)

    def read_many_ranges(
            self, requests: Sequence[tuple[str, int, int]]) -> list[bytes]:
        return self._batch_with_retries(
            list(requests),
            getattr(self.inner, "read_many_ranges_settled", None),
            self.inner.read_many_ranges)

    def _batch_with_retries(self, items: list, settled_fn, plain_fn):
        """Batch reads with per-item retries.

        When the backend exposes a *settled* variant (per-item outcomes),
        only the transiently-failed items are refetched each round — a
        throttled 64-object fan-out retries its handful of 503s, not the
        whole batch (whose all-clean probability decays geometrically in
        batch size).  Otherwise the whole batch is retried.
        """
        if settled_fn is None:
            return self._with_retries("GET-batch", lambda: plain_fn(items))
        results: dict[int, bytes] = {}
        pending = list(range(len(items)))
        for attempt in range(self.policy.max_attempts):
            outcomes = settled_fn([items[i] for i in pending])
            still = []
            for i, r in zip(pending, outcomes):
                if isinstance(r, TransientStorageError):
                    still.append(i)
                elif isinstance(r, Exception):
                    raise r
                else:
                    results[i] = r
            if not still:
                return [results[i] for i in range(len(items))]
            self._note_retries(len(still))
            pending = still
            if attempt + 1 < self.policy.max_attempts:
                self._sleep(self.policy.delay(attempt))
        raise StorageRetryExhausted(
            f"GET-batch: {len(pending)} of {len(items)} items failed after "
            f"{self.policy.max_attempts} attempts")

    def exists(self, path: str) -> bool:
        return self._with_retries("HEAD", lambda: self.inner.exists(path))

    def list_dir(self, path: str) -> list[str]:
        return self._with_retries("LIST", lambda: self.inner.list_dir(path))

    def size(self, path: str) -> int:
        return self._with_retries("HEAD", lambda: self.inner.size(path))

    def delete(self, path: str) -> None:
        return self._with_retries("DELETE", lambda: self.inner.delete(path))

    # -- writes (retry-safe conditional put) -------------------------------
    def write_bytes(self, path: str, data: bytes, *, overwrite: bool = False) -> None:
        saw_transient = False
        last: Exception | None = None
        for attempt in range(self.policy.max_attempts):
            try:
                self.inner.write_bytes(path, data, overwrite=overwrite)
                return
            except TransientStorageError as e:
                last = e
                saw_transient = True
                self._note_retries()
                if attempt + 1 < self.policy.max_attempts:
                    self._sleep(self.policy.delay(attempt))
            except PutIfAbsentError:
                if saw_transient and not overwrite and \
                        self._we_already_won(path, data):
                    return          # our earlier (ambiguous) attempt landed
                raise               # a concurrent writer genuinely won
        # the final attempt may itself have applied before its response was
        # lost — same disambiguation before giving up
        if saw_transient and not overwrite and self._we_already_won(path, data):
            return
        raise StorageRetryExhausted(
            f"PUT {path} failed after {self.policy.max_attempts} attempts"
        ) from last

    def write_many(self, items: Sequence[tuple[str, bytes]], *,
                   overwrite: bool = False) -> None:
        """Batch puts with per-item retries.

        When the backend exposes ``write_many_settled`` (per-item
        outcomes), each round re-puts ONLY the failed items of the batch;
        an item that comes back :class:`PutIfAbsentError` after one of its
        own attempts failed transiently runs the same read-back
        disambiguation as ``write_bytes`` — *per item*, so one ambiguous
        put in a 32-object staged flush resolves without disturbing the
        other 31.  A genuine lost race still raises so the commit protocol
        sees the conflict.  Without a settled variant the items are written
        through the (individually retried) single-put path.
        """
        items = list(items)
        if not items:
            return
        settled_fn = getattr(self.inner, "write_many_settled", None)
        if settled_fn is None:
            for p, data in items:
                self.write_bytes(p, data, overwrite=overwrite)
            return
        saw_transient: set[int] = set()
        pending = list(range(len(items)))
        for attempt in range(self.policy.max_attempts):
            outcomes = settled_fn([items[i] for i in pending],
                                  overwrite=overwrite)
            still = []
            for i, r in zip(pending, outcomes):
                if r is None:
                    continue
                if isinstance(r, TransientStorageError):
                    saw_transient.add(i)
                    still.append(i)
                elif isinstance(r, PutIfAbsentError):
                    if i in saw_transient and not overwrite and \
                            self._we_already_won(*items[i]):
                        continue    # our earlier (ambiguous) attempt landed
                    raise r         # a concurrent writer genuinely won
                else:
                    raise r
            if not still:
                return
            self._note_retries(len(still))
            pending = still
            if attempt + 1 < self.policy.max_attempts:
                self._sleep(self.policy.delay(attempt))
        # final attempts may themselves have applied before their responses
        # were lost — same per-item disambiguation before giving up
        if not overwrite:
            pending = [i for i in pending
                       if not (i in saw_transient and
                               self._we_already_won(*items[i]))]
        if pending:
            raise StorageRetryExhausted(
                f"PUT-batch: {len(pending)} of {len(items)} items failed "
                f"after {self.policy.max_attempts} attempts")

    def _we_already_won(self, path: str, data: bytes) -> bool:
        try:
            return self._with_retries(
                "GET", lambda: self.inner.read_bytes(path)) == data
        except FileNotFoundError:
            return False
