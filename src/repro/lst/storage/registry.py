"""URI-scheme registry: ``make_fs("file://…" | "mem://…" | "s3sim://…")``.

One place maps URI schemes to FileSystem backends and resolves table URIs
to store-local paths.  Resolution keeps the **authority** (bucket /
container) as the leading path component for object-store schemes, so
``s3sim://bucket-a/sales`` and ``s3sim://bucket-b/sales`` are different
tables — the seed's ``strip_scheme`` discarded the authority and made two
buckets with the same key path collide.  ``file://`` is the exception: its
authority is a host (always localhost here) and its path is absolute on
the local filesystem.

``mem://`` and ``s3sim://`` resolve to process-shared in-memory stores (one
per scheme), so every FileSystem view built from the same URI sees the same
bucket namespace — which is what lets concurrent executors race commits and
crash tests reopen the store.
"""

from __future__ import annotations

import threading
from typing import Callable

from repro.lst.storage.base import FileSystem
from repro.lst.storage.instrumented import InstrumentedFS
from repro.lst.storage.local import LocalFS
from repro.lst.storage.memory import MemoryFS
from repro.lst.storage.retry import RetryingFS, RetryPolicy
from repro.lst.storage.simulated import SimulatedObjectStore, StorageProfile

_lock = threading.Lock()
_SCHEMES: dict[str, Callable[..., FileSystem]] = {}
_LOCAL_PATH_SCHEMES = {"file"}      # authority = host, path absolute locally
_SHARED_STORES: dict[str, MemoryFS] = {}


def register_scheme(scheme: str, factory: Callable[..., FileSystem],
                    *, local_path: bool = False) -> None:
    """Register ``scheme`` -> FileSystem factory (kwargs = backend options)."""
    with _lock:
        _SCHEMES[scheme] = factory
        if local_path:
            _LOCAL_PATH_SCHEMES.add(scheme)


def shared_store(scheme: str) -> MemoryFS:
    """The process-wide in-memory bucket namespace backing ``scheme``."""
    with _lock:
        store = _SHARED_STORES.get(scheme)
        if store is None:
            store = _SHARED_STORES[scheme] = MemoryFS()
        return store


def clear_shared_stores() -> None:
    """Drop every in-memory bucket namespace (test isolation)."""
    with _lock:
        _SHARED_STORES.clear()


# -- URI handling ----------------------------------------------------------
def split_uri(uri: str) -> tuple[str | None, str, str]:
    """``scheme://authority/path`` -> (scheme, authority, path).

    Plain paths come back as ``(None, "", path)``.
    """
    if "://" not in uri:
        return None, "", uri
    scheme, rest = uri.split("://", 1)
    if "/" in rest:
        authority, path = rest.split("/", 1)
    else:
        authority, path = rest, ""
    return scheme, authority, path


def scheme_of(uri: str) -> str | None:
    return split_uri(uri)[0]


def resolve_uri(uri: str) -> str:
    """URI -> store-local path, authority-qualified for bucket schemes."""
    scheme, authority, path = split_uri(uri)
    if scheme is None:
        return uri
    if scheme in _LOCAL_PATH_SCHEMES:
        return "/" + path.lstrip("/")
    if not authority:
        return path
    return f"{authority}/{path}" if path else authority


def make_fs(uri: str, **options) -> FileSystem:
    """Build the backend FileSystem for ``uri``'s scheme.

    Accepts a full URI (``s3sim://bucket/t``), a bare scheme (``s3sim``),
    or a plain path (-> LocalFS).  ``options`` are backend-specific: the
    simulated store takes :class:`StorageProfile` fields.
    """
    scheme = scheme_of(uri) if "://" in uri else (uri if uri in _SCHEMES
                                                  else None)
    if scheme is None:
        return LocalFS(**options)
    with _lock:
        factory = _SCHEMES.get(scheme)
    if factory is None:
        raise ValueError(f"unknown storage scheme {scheme!r}; "
                         f"registered: {sorted(_SCHEMES)}")
    return factory(**options)


def layer_fs(base: FileSystem, *, profile: StorageProfile | None = None,
             retry: RetryPolicy | None = None,
             telemetry=None, sleep=None) -> InstrumentedFS:
    """Compose the standard stack: Instrumented(Retrying(Simulated(base))).

    ``profile`` wraps any backend in latency/fault injection (skip to run
    against the backend's native behavior), ``retry`` adds backoff-retried
    requests, and the instrumented layer always sits outermost so counters
    see logical requests.  ``sleep`` replaces the retry layer's backoff
    sleeper (``time.sleep``) — the daemon threads its injected clock
    through here so retry backoff never wall-sleeps under a fake clock.
    """
    fs = base
    if profile is not None:
        fs = SimulatedObjectStore(fs, profile)
    if retry is not None:
        fs = RetryingFS(fs, retry) if sleep is None \
            else RetryingFS(fs, retry, sleep=sleep)
    return InstrumentedFS(fs, telemetry)


# -- built-in schemes ------------------------------------------------------
register_scheme("file", LocalFS, local_path=True)
register_scheme("mem", lambda **opt: shared_store("mem"))
register_scheme(
    "s3sim",
    lambda **opt: SimulatedObjectStore(shared_store("s3sim"),
                                       StorageProfile(**opt)))
