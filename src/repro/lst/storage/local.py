"""POSIX-backed FileSystem with object-store commit semantics."""

from __future__ import annotations

import os
import threading

from repro.lst.storage.base import PutIfAbsentError, SequentialBatchMixin


class LocalFS(SequentialBatchMixin):
    """POSIX-backed FileSystem with object-store commit semantics.

    Writes are *atomic at the object level*: data is staged to a temp file and
    linked into place, so readers never observe partial objects — mirroring
    object-store single-shot PUTs (this is what makes LST metadata commits
    atomic, per §2 of the paper).
    """

    def __init__(self, *, fsync: bool = True) -> None:
        """``fsync=False`` skips the per-object fsync: atomicity (staged
        temp file + atomic link) is unchanged, only crash durability is
        relaxed — the knob benchmarks use so metadata-translation work is
        measured instead of disk flushes (object stores own durability and
        expose no fsync)."""
        self._lock = threading.Lock()
        self._fsync = fsync

    # -- reads ------------------------------------------------------------
    def read_bytes(self, path: str) -> bytes:
        with open(path, "rb") as f:
            return f.read()

    def read_bytes_range(self, path: str, offset: int, length: int) -> bytes:
        """Ranged GET; ``offset < 0`` = suffix read of ``length`` bytes,
        ``length < 0`` = read to end of object (see storage.base)."""
        with open(path, "rb") as f:
            if offset < 0:
                f.seek(0, os.SEEK_END)
                f.seek(max(0, f.tell() - length))
            else:
                f.seek(offset)
            return f.read(None if length < 0 else length)

    def exists(self, path: str) -> bool:
        return os.path.exists(path)

    def list_dir(self, path: str) -> list[str]:
        try:
            return sorted(os.listdir(path))
        except FileNotFoundError:
            return []

    def size(self, path: str) -> int:
        return os.stat(path).st_size

    # -- writes -----------------------------------------------------------
    def write_bytes(self, path: str, data: bytes, *, overwrite: bool = False) -> None:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            if self._fsync:
                os.fsync(f.fileno())
        if overwrite:
            os.replace(tmp, path)  # atomic swap
            return
        # put-if-absent: hardlink fails with EEXIST if somebody else won.
        try:
            os.link(tmp, path)
        except FileExistsError:
            raise PutIfAbsentError(path)
        finally:
            os.unlink(tmp)

    def delete(self, path: str) -> None:
        try:
            os.unlink(path)
        except FileNotFoundError:
            pass
