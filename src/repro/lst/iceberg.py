"""Iceberg-style LST: snapshot -> manifest-list -> manifest metadata chain.

Faithful architectural reimplementation of the Iceberg table spec (v2):

* ``metadata/v{N}.metadata.json`` — table metadata: schemas (with *field ids*),
  partition specs (with transforms), properties, the snapshot list, and
  ``current-snapshot-id``; plus ``metadata/version-hint.text`` (Hadoop-catalog
  style pointer). Commit = put-if-absent of the next metadata file.
* ``metadata/snap-{id}.manifest-list.json`` — one manifest-list per snapshot.
* ``metadata/manifest-{id}-{k}.json`` — manifest files holding data-file
  entries with status ADDED(1)/EXISTING(0)/DELETED(2) and column bounds.
* Manifest *reuse*: a new snapshot's manifest list references untouched
  manifests from the parent snapshot as-is — only new/affected manifests are
  written. This is the property that makes Iceberg commits (and XTable's
  incremental translation into Iceberg) O(change), not O(table).
"""

from __future__ import annotations

import json
import time
import uuid

from repro.lst.chunkfile import ColumnStats, DataFileMeta
from repro.lst.storage import PutIfAbsentError, fetch_many, flush_many, join
from repro.lst.schema import (CommitEntry, Field, PartitionField,
                              PartitionSpec, Schema, TableState)

FORMAT = "iceberg"
META_DIR = "metadata"
ADDED, EXISTING, DELETED = 1, 0, 2

_TYPES_TO_ICE = {"int32": "int", "int64": "long", "float32": "float",
                 "float64": "double", "string": "string", "bool": "boolean",
                 "binary": "binary", "timestamp": "timestamptz"}
_ICE_TO_TYPES = {v: k for k, v in _TYPES_TO_ICE.items()}


def schema_to_ice(schema: Schema) -> dict:
    schema = schema.with_ids()
    return {"type": "struct", "schema-id": schema.schema_id,
            "fields": [{"id": f.field_id, "name": f.name,
                        "required": not f.nullable,
                        "type": _TYPES_TO_ICE[f.type]} for f in schema.fields]}


def schema_from_ice(d: dict) -> Schema:
    return Schema([Field(f["name"], _ICE_TO_TYPES[f["type"]],
                         not f["required"], f["id"]) for f in d["fields"]],
                  d.get("schema-id", 0))


def spec_to_ice(spec: PartitionSpec, schema: Schema) -> dict:
    schema = schema.with_ids()
    ids = {f.name: f.field_id for f in schema.fields}
    return {"spec-id": 0, "fields": [
        {"source-id": ids[f.source], "field-id": 1000 + i,
         "transform": f.transform, "name": f.out_name}
        for i, f in enumerate(spec.fields)]}


def spec_from_ice(d: dict, schema: Schema) -> PartitionSpec:
    names = {f.field_id: f.name for f in schema.fields}
    return PartitionSpec([PartitionField(names[f["source-id"]], f["transform"],
                                         f["name"]) for f in d["fields"]])


def _file_to_entry(f: DataFileMeta, status: int, snapshot_id: int) -> dict:
    return {"status": status, "snapshot-id": snapshot_id, "data-file": {
        "file-path": f.path, "file-format": "CHUNKFILE",
        "partition": {k: v for k, v in f.partition_values.items()},
        "record-count": f.record_count, "file-size-in-bytes": f.size_bytes,
        "lower-bounds": {k: s.min for k, s in f.column_stats.items()},
        "upper-bounds": {k: s.max for k, s in f.column_stats.items()},
        "null-value-counts": {k: s.nan_count for k, s in f.column_stats.items()},
        "value-counts": {k: s.count for k, s in f.column_stats.items()},
        "extra": f.extra or {}}}


def _file_from_entry(e: dict) -> DataFileMeta:
    df = e["data-file"]
    cols = set(df.get("lower-bounds", {})) | set(df.get("upper-bounds", {})) | \
        set(df.get("null-value-counts", {}))
    stats = {c: ColumnStats(df.get("lower-bounds", {}).get(c),
                            df.get("upper-bounds", {}).get(c),
                            df.get("value-counts", {}).get(c, 0),
                            df.get("null-value-counts", {}).get(c, 0))
             for c in cols}
    return DataFileMeta(path=df["file-path"], size_bytes=df["file-size-in-bytes"],
                        record_count=df["record-count"],
                        partition_values=dict(df.get("partition", {})),
                        column_stats=stats, extra=dict(df.get("extra", {})))


class CommitConflict(RuntimeError):
    pass


class IcebergTable:
    format = FORMAT

    def __init__(self, fs, base_path: str):
        self.fs = fs
        self.base = base_path

    # ------------------------------------------------------------- lifecycle
    @classmethod
    def exists(cls, fs, base_path: str) -> bool:
        return any(n.endswith(".metadata.json")
                   for n in fs.list_dir(join(base_path, META_DIR)))

    @classmethod
    def create(cls, fs, base_path: str, schema: Schema,
               partition_spec: PartitionSpec = PartitionSpec(),
               properties: dict | None = None) -> "IcebergTable":
        t = cls(fs, base_path)
        schema = schema.with_ids()
        meta = {
            "format-version": 2, "table-uuid": str(uuid.uuid4()),
            "location": base_path, "last-sequence-number": 0,
            "last-updated-ms": _now_ms(),
            "last-column-id": max((f.field_id or 0) for f in schema.fields),
            "schemas": [schema_to_ice(schema)], "current-schema-id": schema.schema_id,
            "partition-specs": [spec_to_ice(partition_spec, schema)],
            "default-spec-id": 0,
            "properties": {k: str(v) for k, v in (properties or {}).items()},
            "current-snapshot-id": -1, "snapshots": [], "snapshot-log": [],
        }
        t._write_metadata(1, meta)
        return t

    @classmethod
    def open(cls, fs, base_path: str) -> "IcebergTable":
        if not cls.exists(fs, base_path):
            raise FileNotFoundError(f"no iceberg table at {base_path}")
        return cls(fs, base_path)

    # ------------------------------------------------------------- metadata
    def _meta_path(self, n: int) -> str:
        return join(self.base, META_DIR, f"v{n}.metadata.json")

    def _hint_path(self) -> str:
        return join(self.base, META_DIR, "version-hint.text")

    def _current_meta_version(self) -> int:
        try:
            # read the hint directly (no exists() pre-flight — one fewer
            # round trip; a missing hint is the rare foreign-table case)
            n = int(self.fs.read_bytes(self._hint_path()).decode().strip())
        except FileNotFoundError:
            versions = [int(x[1:-len(".metadata.json")])
                        for x in self.fs.list_dir(join(self.base, META_DIR))
                        if x.startswith("v") and x.endswith(".metadata.json")]
            if not versions:
                raise FileNotFoundError("no iceberg metadata") from None
            return max(versions)
        # the hint may lag a concurrent commit; roll forward
        while self.fs.exists(self._meta_path(n + 1)):
            n += 1
        return n

    def _read_metadata(self, n: int | None = None) -> tuple[int, dict]:
        n = n if n is not None else self._current_meta_version()
        return n, json.loads(self.fs.read_bytes(self._meta_path(n)))

    def _write_metadata(self, n: int, meta: dict) -> None:
        try:
            self.fs.write_bytes(self._meta_path(n), json.dumps(meta).encode())
        except PutIfAbsentError as e:
            raise CommitConflict(f"iceberg metadata v{n} exists") from e
        self.fs.write_bytes(self._hint_path(), str(n).encode(), overwrite=True)

    # ------------------------------------------------------------ manifests
    def _read_manifest(self, path: str) -> list[dict]:
        return json.loads(self.fs.read_bytes(join(self.base, path)))["entries"]

    def _read_manifests_many(self, paths: list[str]) -> dict[str, list[dict]]:
        """Batched manifest fetch: independent GETs pipelined via
        ``read_many`` (one round of round trips, not one per manifest)."""
        blobs = fetch_many(self.fs, [join(self.base, p) for p in paths])
        return {p: json.loads(raw)["entries"] for p, raw in zip(paths, blobs)}

    def _write_manifest(self, name: str, entries: list[dict]) -> str:
        rel = join(META_DIR, name)
        self.fs.write_bytes(join(self.base, rel),
                            json.dumps({"entries": entries}).encode())
        return rel

    def _stage_manifest(self, name: str, entries: list[dict],
                        staged: list[tuple[str, bytes]]) -> str:
        """Append a manifest to a staged-write batch instead of putting it
        immediately; the caller flushes the batch in one pipelined round
        before the commit-point metadata put."""
        rel = join(META_DIR, name)
        staged.append((join(self.base, rel),
                       json.dumps({"entries": entries}).encode()))
        return rel

    def _read_manifest_list(self, path: str) -> list[dict]:
        return json.loads(self.fs.read_bytes(join(self.base, path)))["manifests"]

    # ----------------------------------------------------------------- state
    def current_version(self) -> str:
        _, meta = self._read_metadata()
        return str(meta["current-snapshot-id"])

    def head(self) -> str:
        """The head snapshot id (reads the current metadata JSON)."""
        return self.current_version()

    def head_token(self) -> str:
        """O(1) change-detection probe: an opaque token that moves iff the
        table advanced.  One GET of ``version-hint.text`` — every commit
        (every transaction *flush*, including one that aborts after landing
        a prefix) rewrites the hint right after its last metadata put, so
        the hint number moves with the head and no ``v{N}.metadata.json``
        is parsed.  A writer crashing inside the hint window leaves the
        token lagging until the next successful commit — readers roll the
        hint forward, only change *detection* waits.  Falls back to listing
        the metadata dir when the hint is missing (foreign writer); an
        absent table yields ``""``.

        The token is the *metadata file* version, not the snapshot id: two
        different tokens can name the same snapshot (e.g. a properties-only
        commit), which at worst causes one spurious replan — never a missed
        change.
        """
        return self.head_probe()[0]

    def head_probe(self) -> tuple[str, int | None]:
        """``(head_token, probe_state)`` in ONE storage request (plus the
        listing fallback for hint-less foreign tables).

        The probe state is the metadata-file version the token names, which
        ``replay(probe=...)`` / ``_read_metadata(n)`` can consume within the
        same daemon cycle to open ``v{N}.metadata.json`` directly instead of
        re-running the hint-read + roll-forward discovery dance.
        """
        try:
            n = int(self.fs.read_bytes(self._hint_path()).decode().strip())
            return f"hint:{n}", n
        except FileNotFoundError:
            versions = [int(x[1:-len(".metadata.json")])
                        for x in self.fs.list_dir(join(self.base, META_DIR))
                        if x.startswith("v") and x.endswith(".metadata.json")]
            if not versions:
                return "", None
            return f"list:{max(versions)}", max(versions)

    def versions(self) -> list[str]:
        _, meta = self._read_metadata()
        return [str(s["snapshot-id"]) for s in
                sorted(meta["snapshots"], key=lambda s: s["sequence-number"])]

    def _snapshot_rec(self, meta: dict, snapshot_id: int) -> dict:
        for s in meta["snapshots"]:
            if s["snapshot-id"] == snapshot_id:
                return s
        raise KeyError(f"snapshot {snapshot_id} not found")

    def _live_files(self, meta: dict, snap: dict) -> dict:
        files: dict[str, DataFileMeta] = {}
        manifests = self._read_manifest_list(snap["manifest-list"])
        by_path = self._read_manifests_many(
            [m["manifest-path"] for m in manifests])
        for m in manifests:
            for e in by_path[m["manifest-path"]]:
                if e["status"] != DELETED:
                    f = _file_from_entry(e)
                    files[f.path] = f
        return files

    def snapshot(self, version: str | None = None) -> TableState:
        _, meta = self._read_metadata()
        sid = int(version) if version is not None else meta["current-snapshot-id"]
        schema = self._schema_of(meta, meta["current-schema-id"])
        spec = spec_from_ice(meta["partition-specs"][meta["default-spec-id"]], schema)
        if sid == -1:  # empty table
            return TableState(FORMAT, "-1", meta["last-updated-ms"], schema, spec,
                              {}, dict(meta["properties"]))
        snap = self._snapshot_rec(meta, sid)
        schema = self._schema_of(meta, snap.get("schema-id",
                                                meta["current-schema-id"]))
        return TableState(FORMAT, str(sid), snap["timestamp-ms"], schema, spec,
                          self._live_files(meta, snap), dict(meta["properties"]))

    def _schema_of(self, meta: dict, schema_id: int) -> Schema:
        for s in meta["schemas"]:
            if s.get("schema-id", 0) == schema_id:
                return schema_from_ice(s)
        return schema_from_ice(meta["schemas"][-1])

    def changes(self, version: str) -> tuple[list[DataFileMeta], list[str], str, dict]:
        _, meta = self._read_metadata()
        snap = self._snapshot_rec(meta, int(version))
        adds, removes = [], []
        manifests = self._read_manifest_list(snap["manifest-list"])
        by_path = self._read_manifests_many(
            [m["manifest-path"] for m in manifests])
        for m in manifests:
            for e in by_path[m["manifest-path"]]:
                if e["snapshot-id"] != int(version):
                    continue
                if e["status"] == ADDED:
                    adds.append(_file_from_entry(e))
                elif e["status"] == DELETED:
                    removes.append(e["data-file"]["file-path"])
        return adds, removes, snap["summary"].get("operation", "unknown"), \
            dict(snap["summary"])

    def replay(self, since: str | None = None,
               seed: CommitEntry | None = None,
               probe: int | None = None
               ) -> tuple[TableState | None, list[CommitEntry]]:
        """Single-pass scan of the snapshot chain -> per-commit entries.

        Manifest files are read once each even though manifest *reuse* makes
        them appear in many snapshots' manifest lists, so the whole history
        costs one read per metadata object, not one per (snapshot, manifest).
        The base state is the empty pre-first-snapshot table (version "-1").

        With ``since`` set, only snapshots AFTER that id are scanned
        (tail-only refresh, ``base`` is ``None``); a snapshot's changes live
        exclusively in manifests it added itself (``added-snapshot-id``), so
        the tail never touches carried-forward manifests from older
        snapshots.  Raises ``KeyError`` if ``since`` is not in the chain.

        ``probe`` — the metadata-file version from a same-cycle
        ``head_probe()`` — opens ``v{N}.metadata.json`` directly, skipping
        the hint-read + roll-forward head discovery.
        """
        _, meta = self._read_metadata(probe)
        cur_schema = self._schema_of(meta, meta["current-schema-id"])
        spec = spec_from_ice(meta["partition-specs"][meta["default-spec-id"]],
                             cur_schema)
        props = dict(meta["properties"])
        snaps = sorted(meta["snapshots"], key=lambda s: s["sequence-number"])
        base: TableState | None = TableState(
            FORMAT, "-1", meta["last-updated-ms"], cur_schema, spec, {}, props)
        tail_only = since is not None and since != "-1"
        if tail_only:
            known = {str(s["snapshot-id"]) for s in snaps}
            if since not in known:
                raise KeyError(f"snapshot {since} not in iceberg chain")
            snaps = [s for s in snaps if s["sequence-number"] >
                     next(x["sequence-number"] for x in snaps
                          if str(x["snapshot-id"]) == since)]
            base = None
        elif since is not None:   # since == "-1": tail == whole chain
            base = None

        # two pipelined fetch rounds instead of one RTT per metadata object:
        # all manifest-lists at once, then every unique manifest exactly once
        # (manifest *reuse* makes the same manifest appear in many lists)
        ml_blobs = fetch_many(
            self.fs, [join(self.base, s["manifest-list"]) for s in snaps])
        ml_by_snap = {s["snapshot-id"]: json.loads(raw)["manifests"]
                      for s, raw in zip(snaps, ml_blobs)}
        needed: dict[str, None] = {}
        for snap in snaps:
            sid = snap["snapshot-id"]
            for m in ml_by_snap[sid]:
                # a snapshot's ADDED/DELETED entries only live in manifests
                # written at that snapshot; skip reused ones on tail scans
                if tail_only and m.get("added-snapshot-id") != sid:
                    continue
                needed[m["manifest-path"]] = None
        manifest_memo = self._read_manifests_many(list(needed))

        entries = []
        for snap in snaps:
            sid = snap["snapshot-id"]
            adds, removes = [], []
            for m in ml_by_snap[sid]:
                if tail_only and m.get("added-snapshot-id") != sid:
                    continue
                for e in manifest_memo[m["manifest-path"]]:
                    if e["snapshot-id"] != sid:
                        continue
                    if e["status"] == ADDED:
                        adds.append(_file_from_entry(e))
                    elif e["status"] == DELETED:
                        removes.append(e["data-file"]["file-path"])
            schema = self._schema_of(meta, snap.get("schema-id",
                                                    meta["current-schema-id"]))
            entries.append(CommitEntry(
                str(sid), snap["timestamp-ms"],
                snap["summary"].get("operation", "unknown"), tuple(adds),
                tuple(removes), schema, spec, props, dict(snap["summary"])))
        return base, entries

    def properties(self) -> dict:
        _, meta = self._read_metadata()
        return dict(meta["properties"])

    def current_schema(self) -> Schema:
        """Schema from the metadata JSON alone (no manifest reads)."""
        _, meta = self._read_metadata()
        return self._schema_of(meta, meta["current-schema-id"])

    def read_metadata(self) -> tuple[int, dict]:
        """One read of the current ``(metadata version, metadata dict)`` —
        the public accessor for callers that answer several questions
        (properties, schema, transaction seed) from a single fetch; hand
        the tuple to ``transaction(meta=...)`` to make begin free."""
        return self._read_metadata()

    def schema_from_metadata(self, meta: dict) -> Schema:
        """The current schema carried by an already-read metadata dict."""
        return self._schema_of(meta, meta["current-schema-id"])

    # --------------------------------------------------------------- commits
    def commit(self, adds: list[DataFileMeta] = (), removes: list[str] = (), *,
               schema: Schema | None = None, properties: dict | None = None,
               operation: str = "append", extra_meta: dict | None = None,
               max_retries: int = 5) -> str:
        for _ in range(max_retries):
            try:
                return self._commit_once(adds, removes, schema, properties,
                                         operation, extra_meta)
            except CommitConflict:
                continue
        raise CommitConflict("iceberg commit retries exhausted")

    def _commit_once(self, adds, removes, schema, properties, operation,
                     extra_meta) -> str:
        n, meta = self._read_metadata()
        seq = meta["last-sequence-number"] + 1
        sid = seq  # deterministic, ordered snapshot ids
        ts = _now_ms()
        removes = set(removes)

        # -- carry forward manifests, rewriting only those touching removes;
        #    new manifests + the manifest list are STAGED and flushed in one
        #    pipelined round — only the metadata put below is ordered
        staged: list[tuple[str, bytes]] = []
        manifests: list[dict] = []
        if meta["current-snapshot-id"] != -1:
            parent = self._snapshot_rec(meta, meta["current-snapshot-id"])
            parent_list = self._read_manifest_list(parent["manifest-list"])
            by_path = self._read_manifests_many(
                [m["manifest-path"] for m in parent_list])
            for m in parent_list:
                entries = [e for e in by_path[m["manifest-path"]]
                           if e["status"] != DELETED]
                if removes and any(e["data-file"]["file-path"] in removes
                                   for e in entries):
                    new_entries = []
                    for e in entries:
                        p = e["data-file"]["file-path"]
                        if p in removes:
                            new_entries.append({**e, "status": DELETED,
                                                "snapshot-id": sid})
                        else:
                            new_entries.append({**e, "status": EXISTING})
                    rel = self._stage_manifest(
                        f"manifest-{sid}-rw{len(manifests)}.json", new_entries,
                        staged)
                    manifests.append(_mf_entry(rel, sid, new_entries))
                elif entries:
                    manifests.append({**m, "added-files-count": 0,
                                      "existing-files-count":
                                          m.get("added-files-count", 0) +
                                          m.get("existing-files-count", 0),
                                      "deleted-files-count": 0})
        if adds:
            entries = [_file_to_entry(f, ADDED, sid) for f in adds]
            rel = self._stage_manifest(f"manifest-{sid}-add.json", entries,
                                       staged)
            manifests.append(_mf_entry(rel, sid, entries))

        ml_rel = join(META_DIR, f"snap-{sid}.manifest-list.json")
        staged.append((join(self.base, ml_rel),
                       json.dumps({"manifests": manifests}).encode()))
        flush_many(self.fs, staged)

        summary = {"operation": operation,
                   "added-data-files": str(len(adds)),
                   "deleted-data-files": str(len(removes))}
        if extra_meta:
            summary.update({f"xtable.{k}": json.dumps(v) if not
                            isinstance(v, str) else v
                            for k, v in extra_meta.items()})

        new_meta = dict(meta)
        if schema is not None:
            ice = schema_to_ice(Schema(schema.fields,
                                       meta["current-schema-id"] + 1))
            new_meta["schemas"] = meta["schemas"] + [ice]
            new_meta["current-schema-id"] = ice["schema-id"]
            new_meta["last-column-id"] = max(f["id"] for f in ice["fields"])
        if properties:
            new_meta["properties"] = {**meta["properties"],
                                      **{k: str(v) for k, v in properties.items()}}
        new_meta.update({
            "last-sequence-number": seq, "last-updated-ms": ts,
            "current-snapshot-id": sid,
            "snapshots": meta["snapshots"] + [{
                "snapshot-id": sid,
                "parent-snapshot-id": meta["current-snapshot-id"],
                "sequence-number": seq, "timestamp-ms": ts,
                "manifest-list": ml_rel, "summary": summary,
                "schema-id": new_meta["current-schema-id"]}],
            "snapshot-log": meta["snapshot-log"] + [
                {"timestamp-ms": ts, "snapshot-id": sid}],
        })
        self._write_metadata(n + 1, new_meta)
        return str(sid)

    # ----------------------------------------------------------- transaction
    def transaction(self, *, schema: Schema | None = None,
                    manifest_compaction_threshold: int | None = None,
                    meta: tuple[int, dict] | None = None
                    ) -> "IcebergTransaction":
        """Multi-commit transaction: parse ``v{N}.metadata.json`` ONCE and
        thread the metadata dict + manifest-list through every commit in
        memory.  Commits are *buffered*: every non-commit-point object (new
        manifests, manifest-lists) across the whole chain is staged and
        flushed in one pipelined ``write_many`` round at ``flush()``/
        ``close()``; only the per-commit metadata puts stay serial, so an
        N-commit drain costs ~N+O(1) serial round trips instead of ~4N.
        ``manifest_compaction_threshold`` folds the manifest list into one
        manifest whenever a commit would leave more than that many; ``meta``
        — an already-read ``(version, metadata dict)`` — makes begin cost
        zero requests (a stale caller races like any concurrent writer:
        the conflict surfaces at flush and the chain re-materializes)."""
        return IcebergTransaction(
            self, manifest_compaction_threshold=manifest_compaction_threshold,
            meta=meta)


class IcebergTransaction:
    """Buffered writer state for an N-commit sync unit (single writer).

    Begin cost: one metadata-JSON read; the parent manifest-list is read
    lazily at the first flush.  ``commit()`` only *buffers*: the snapshot id
    is predicted from the in-memory sequence counter (the transaction is the
    single writer; a foreign commit surfaces as a conflict at flush and the
    chain is re-materialized with fresh ids).  ``flush()`` then

    1. materializes every pending commit in memory,
    2. flushes ALL staged non-commit objects — new manifests and
       manifest-lists, uniquely named per snapshot id, hence idempotent —
       in one pipelined ``write_many`` round,
    3. issues the per-commit ``v{N}.metadata.json`` puts serially (the
       ordered atomic commit points), and
    4. moves ``version-hint.text`` once.

    A crash anywhere leaves a valid prefix: staged objects are unreferenced
    until their commit point lands, and every landed commit references only
    already-flushed objects.  A commit with removes must locate the removed
    entries, which opens the live parent manifests — at most ONCE EACH per
    transaction (memoized; staged manifests enter the memo at materialize
    time).  With a ``manifest_compaction_threshold``, a commit that would
    carry more than that many manifests folds them all into one, bounding
    the O(manifests) read amplification of long incremental chains.
    """

    def __init__(self, table: IcebergTable, *,
                 manifest_compaction_threshold: int | None = None,
                 meta: tuple[int, dict] | None = None):
        self.t = table
        self.n, self.meta = meta if meta is not None \
            else table._read_metadata()
        if manifest_compaction_threshold is not None \
                and manifest_compaction_threshold < 1:
            raise ValueError("manifest_compaction_threshold must be >= 1")
        self.compaction_threshold = manifest_compaction_threshold
        self.compactions = 0                         # folds performed
        self._manifests: list[dict] | None = None    # current manifest list
        self._manifest_memo: dict[str, list[dict]] = {}
        self._pending: list[tuple] = []              # buffered commit args
        self._max_retries = 5

    @property
    def version(self) -> str:
        """Head snapshot id including buffered (not yet flushed) commits."""
        if self._pending:
            return str(self.meta["last-sequence-number"] + len(self._pending))
        return str(self.meta["current-snapshot-id"])

    def _read_manifest(self, path: str) -> list[dict]:
        if path not in self._manifest_memo:
            self._manifest_memo[path] = self.t._read_manifest(path)
        return self._manifest_memo[path]

    def _parent_manifests(self) -> list[dict]:
        if self._manifests is None:
            if self.meta["current-snapshot-id"] == -1:
                self._manifests = []
            else:
                parent = self.t._snapshot_rec(self.meta,
                                              self.meta["current-snapshot-id"])
                self._manifests = self.t._read_manifest_list(
                    parent["manifest-list"])
        return self._manifests

    def commit(self, adds: list[DataFileMeta] = (), removes: list[str] = (), *,
               schema: Schema | None = None, properties: dict | None = None,
               operation: str = "append", extra_meta: dict | None = None,
               max_retries: int = 5) -> str:
        """Buffer one commit; it lands at the next ``flush()``/``close()``.
        Returns the predicted snapshot id (exact unless a foreign writer
        races the flush, which re-materializes the chain)."""
        self._max_retries = max(self._max_retries, max_retries)
        self._pending.append((list(adds), list(removes), schema, properties,
                              operation, extra_meta))
        return str(self.meta["last-sequence-number"] + len(self._pending))

    # ---------------------------------------------------------------- flush
    def flush(self) -> None:
        """Land every buffered commit (see class docstring for the order)."""
        if not self._pending:
            return
        landed = False
        try:
            for _ in range(self._max_retries):
                staged, commits = self._materialize()
                applied = 0
                try:
                    flush_many(self.t.fs, staged)
                    for path, payload, n1, new_meta, new_manifests in commits:
                        self.t.fs.write_bytes(path, payload)
                        applied += 1
                        landed = True
                        self.n, self.meta = n1, new_meta
                        self._manifests = new_manifests
                except PutIfAbsentError:
                    # a concurrent writer advanced the table (a stale
                    # snapshot id collides at a staged name or at the
                    # metadata put): keep the prefix that landed, re-read,
                    # and re-materialize the remaining commits with fresh
                    # sequence numbers
                    del self._pending[:applied]
                    self.n, self.meta = self.t._read_metadata()
                    self._manifests = None
                    continue
                del self._pending[:applied]
                break
            else:
                raise CommitConflict(
                    "iceberg transactional commit retries exhausted")
        except BaseException:
            if landed:
                # commits DID land before the failure: still move the
                # advisory hint over them so ``head_token`` keeps tracking
                # the head (a change-detection probe must not miss the
                # landed prefix); a secondary hint failure must not mask
                # the original error
                try:
                    self.t.fs.write_bytes(self.t._hint_path(),
                                          str(self.n).encode(),
                                          overwrite=True)
                except Exception:
                    pass
            raise
        # move the hint ONCE per flush, after the last commit point — it is
        # advisory (readers roll forward), so deferring it drops N-1 serial
        # round trips from an N-commit drain
        self.t.fs.write_bytes(self.t._hint_path(), str(self.n).encode(),
                              overwrite=True)

    def _materialize(self) -> tuple[list, list]:
        """Pending commits -> (staged objects, ordered commit-point puts).

        Pure in-memory except for reads: the parent manifest list (lazy,
        once) and — only for commits with removes or a compaction fold —
        the not-yet-memoized live manifests, fetched in one batched round.
        """
        staged: list[tuple[str, bytes]] = []
        commits: list[tuple] = []
        meta, n = self.meta, self.n
        manifests = list(self._parent_manifests())
        # staged names carry a writer-unique token (the way real Iceberg
        # embeds a UUID in manifest names): a crashed writer's orphans and
        # a racing writer's staged objects can never collide with ours, so
        # staged puts are conflict-free and only the metadata put races
        self._tok = uuid.uuid4().hex[:8]
        for adds, removes, schema, properties, operation, extra_meta \
                in self._pending:
            meta, manifests = self._materialize_one(
                meta, manifests, adds, removes, schema, properties,
                operation, extra_meta, staged)
            n += 1
            commits.append((self.t._meta_path(n), json.dumps(meta).encode(),
                            n, meta, manifests))
        return staged, commits

    def _ensure_memo(self, manifests: list[dict]) -> None:
        """Batch-open every live, not-yet-memoized manifest of ``manifests``."""
        missing = [m["manifest-path"] for m in manifests
                   if (m.get("added-files-count", 0) +
                       m.get("existing-files-count", 0))
                   and m["manifest-path"] not in self._manifest_memo]
        self._manifest_memo.update(self.t._read_manifests_many(missing))

    def _materialize_one(self, meta, parent_manifests, adds, removes, schema,
                         properties, operation, extra_meta,
                         staged) -> tuple[dict, list[dict]]:
        seq = meta["last-sequence-number"] + 1
        sid = seq
        ts = _now_ms()
        removes = set(removes)

        # -- carry forward the in-memory manifest list; only manifests that
        #    contain a removed path are opened (memoized) and rewritten
        if removes:
            self._ensure_memo(parent_manifests)
        manifests: list[dict] = []
        for m in parent_manifests:
            live = (m.get("added-files-count", 0) +
                    m.get("existing-files-count", 0))
            if not live:
                continue
            if removes:
                entries = [e for e in self._read_manifest(m["manifest-path"])
                           if e["status"] != DELETED]
                if any(e["data-file"]["file-path"] in removes
                       for e in entries):
                    new_entries = []
                    for e in entries:
                        if e["data-file"]["file-path"] in removes:
                            new_entries.append({**e, "status": DELETED,
                                                "snapshot-id": sid})
                        else:
                            new_entries.append({**e, "status": EXISTING})
                    rel = self._stage(
                        f"manifest-{sid}-rw{len(manifests)}.{self._tok}.json",
                        new_entries, staged)
                    manifests.append(_mf_entry(rel, sid, new_entries))
                    continue
            manifests.append({**m, "added-files-count": 0,
                              "existing-files-count": live,
                              "deleted-files-count": 0})
        if adds:
            entries = [_file_to_entry(f, ADDED, sid) for f in adds]
            rel = self._stage(f"manifest-{sid}-add.{self._tok}.json",
                              entries, staged)
            manifests.append(_mf_entry(rel, sid, entries))

        if self.compaction_threshold is not None \
                and len(manifests) > self.compaction_threshold:
            manifests = [self._compact(manifests, sid, staged)]
            self.compactions += 1

        ml_rel = join(META_DIR,
                      f"snap-{sid}.{self._tok}.manifest-list.json")
        staged.append((join(self.t.base, ml_rel),
                       json.dumps({"manifests": manifests}).encode()))

        summary = {"operation": operation,
                   "added-data-files": str(len(adds)),
                   "deleted-data-files": str(len(removes))}
        if extra_meta:
            summary.update({f"xtable.{k}": json.dumps(v) if not
                            isinstance(v, str) else v
                            for k, v in extra_meta.items()})

        new_meta = dict(meta)
        if schema is not None:
            ice = schema_to_ice(Schema(schema.fields,
                                       meta["current-schema-id"] + 1))
            new_meta["schemas"] = meta["schemas"] + [ice]
            new_meta["current-schema-id"] = ice["schema-id"]
            new_meta["last-column-id"] = max(f["id"] for f in ice["fields"])
        if properties:
            new_meta["properties"] = {**meta["properties"],
                                      **{k: str(v) for k, v in
                                         properties.items()}}
        new_meta.update({
            "last-sequence-number": seq, "last-updated-ms": ts,
            "current-snapshot-id": sid,
            "snapshots": meta["snapshots"] + [{
                "snapshot-id": sid,
                "parent-snapshot-id": meta["current-snapshot-id"],
                "sequence-number": seq, "timestamp-ms": ts,
                "manifest-list": ml_rel, "summary": summary,
                "schema-id": new_meta["current-schema-id"]}],
            "snapshot-log": meta["snapshot-log"] + [
                {"timestamp-ms": ts, "snapshot-id": sid}],
        })
        return new_meta, manifests

    def _stage(self, name: str, entries: list[dict], staged: list) -> str:
        rel = self.t._stage_manifest(name, entries, staged)
        self._manifest_memo[rel] = entries
        return rel

    def _compact(self, manifests: list[dict], sid: int,
                 staged: list) -> dict:
        """Fold the whole manifest list into ONE staged manifest.

        Long incremental chains grow one small manifest per commit; folding
        at the threshold bounds snapshot-read amplification.  Entries of the
        current snapshot keep their ADDED/DELETED status (so ``changes()``
        and tail replays still see this commit's delta); older entries
        become EXISTING with their original snapshot-id, and historical
        tombstones are dropped (older snapshots read their own, untouched
        manifest lists).
        """
        self._ensure_memo(manifests)
        folded: list[dict] = []
        for m in manifests:
            if not (m.get("added-files-count", 0) +
                    m.get("existing-files-count", 0) +
                    m.get("deleted-files-count", 0)):
                continue
            for e in self._read_manifest(m["manifest-path"]):
                if e["snapshot-id"] == sid:
                    folded.append(e)             # this commit's own delta
                elif e["status"] != DELETED:
                    folded.append({**e, "status": EXISTING})
        rel = self._stage(f"manifest-{sid}-compact.{self._tok}.json",
                          folded, staged)
        return _mf_entry(rel, sid, folded)

    def close(self) -> None:
        self.flush()


def _mf_entry(rel: str, sid: int, entries: list[dict]) -> dict:
    return {"manifest-path": rel, "added-snapshot-id": sid,
            "added-files-count": sum(1 for e in entries if e["status"] == ADDED),
            "existing-files-count": sum(1 for e in entries
                                        if e["status"] == EXISTING),
            "deleted-files-count": sum(1 for e in entries
                                       if e["status"] == DELETED)}


def _now_ms() -> int:
    return time.time_ns() // 1_000_000
