"""Log-Structured Table (LST) substrate.

Implements the storage layer the paper's translator operates over:

* ``storage``   — pluggable storage backends with object-store semantics
                  (put-if-absent atomic creates are the commit primitive, as
                  on ABFS/S3/GCS; batched metadata fetch; latency/fault
                  simulation; retry policy; scheme registry).  ``fs`` is the
                  back-compat shim over it.
* ``chunkfile`` — the immutable columnar data-file format (plays the role Parquet
                  plays in the paper: column chunks + footer statistics).
* ``delta``     — Delta-Lake-style JSON action log (``_delta_log/NNNN.json``).
* ``iceberg``   — Iceberg-style snapshot / manifest-list / manifest chain.
* ``hudi``      — Hudi-style timeline of instants (``.hoodie/<ts>.commit``).
* ``table``     — the "engine" role: scan with stats-based file pruning, append,
                  copy-on-write delete, time travel, over any of the formats.
"""

from repro.lst.storage import (FileSystem, LocalFS, MemoryFS, RetryingFS,
                               RetryPolicy, SimulatedObjectStore,
                               StorageProfile, make_fs)
from repro.lst.chunkfile import (write_chunk, read_chunk, read_chunk_stats,
                                 read_chunks_stats, read_chunks_footers,
                                 read_chunks_columns, ChunkFooter,
                                 DataFileMeta)
from repro.lst import delta, iceberg, hudi
from repro.lst.table import LakeTable, FORMATS

__all__ = [
    "LocalFS", "MemoryFS", "SimulatedObjectStore", "StorageProfile",
    "RetryingFS", "RetryPolicy", "FileSystem", "make_fs",
    "write_chunk", "read_chunk", "read_chunk_stats", "read_chunks_stats",
    "read_chunks_footers", "read_chunks_columns", "ChunkFooter",
    "DataFileMeta", "delta", "iceberg", "hudi", "LakeTable", "FORMATS",
]
