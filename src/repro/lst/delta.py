"""Delta-Lake-style LST: an ordered JSON action log under ``_delta_log/``.

Faithful architectural reimplementation of the Delta transaction-log protocol:

* ``_delta_log/{version:020d}.json`` — newline-delimited JSON *actions*
  (``protocol``, ``metaData``, ``add``, ``remove``, ``commitInfo``).
* Version = the integer in the file name; commit = put-if-absent of the next
  version file (optimistic concurrency, exactly Delta's protocol on object
  stores with conditional writes).
* Checkpoints: every ``delta.checkpointInterval`` commits an aggregated
  ``{version:020d}.checkpoint.json`` plus a ``_last_checkpoint`` pointer, so
  state reconstruction replays O(interval) log files, not O(history).
* Per-file statistics ride in ``add.stats`` as a JSON string
  (``numRecords/minValues/maxValues/nullCount``) — Delta's layout.
"""

from __future__ import annotations

import json
import time

from repro.lst.chunkfile import ColumnStats, DataFileMeta
from repro.lst.storage import PutIfAbsentError, fetch_many, join
from repro.lst.schema import (CommitEntry, Field, PartitionSpec, Schema,
                              TableState)

FORMAT = "delta"
LOG_DIR = "_delta_log"
CHECKPOINT_INTERVAL_KEY = "delta.checkpointInterval"
DEFAULT_CHECKPOINT_INTERVAL = 10

_TYPES_TO_DELTA = {"int32": "integer", "int64": "long", "float32": "float",
                   "float64": "double", "string": "string", "bool": "boolean",
                   "binary": "binary", "timestamp": "timestamp"}
_DELTA_TO_TYPES = {v: k for k, v in _TYPES_TO_DELTA.items()}


def schema_to_delta(schema: Schema) -> str:
    return json.dumps({"type": "struct", "fields": [
        {"name": f.name, "type": _TYPES_TO_DELTA[f.type], "nullable": f.nullable,
         "metadata": ({"delta.columnMapping.id": f.field_id}
                      if f.field_id is not None else {})}
        for f in schema.fields]})


def schema_from_delta(s: str, schema_id: int = 0) -> Schema:
    d = json.loads(s)
    return Schema([Field(f["name"], _DELTA_TO_TYPES[f["type"]], f["nullable"],
                         f.get("metadata", {}).get("delta.columnMapping.id"))
                   for f in d["fields"]], schema_id)


def _stats_to_delta(column_stats: dict) -> str:
    num = max((s.count for s in column_stats.values()), default=0)
    return json.dumps({
        "numRecords": num,
        "minValues": {k: s.min for k, s in column_stats.items() if s.min is not None},
        "maxValues": {k: s.max for k, s in column_stats.items() if s.max is not None},
        "nullCount": {k: s.nan_count for k, s in column_stats.items()},
    })


def _stats_from_delta(s: str | None) -> dict:
    if not s:
        return {}
    d = json.loads(s)
    cols = set(d.get("minValues", {})) | set(d.get("maxValues", {})) | \
        set(d.get("nullCount", {}))
    return {c: ColumnStats(d.get("minValues", {}).get(c),
                           d.get("maxValues", {}).get(c),
                           d.get("numRecords", 0),
                           d.get("nullCount", {}).get(c, 0)) for c in cols}


def _add_action(f: DataFileMeta, ts: int) -> dict:
    return {"add": {"path": f.path, "partitionValues": {k: str(v) for k, v in
                                                        f.partition_values.items()},
                    "size": f.size_bytes, "modificationTime": ts, "dataChange": True,
                    "stats": _stats_to_delta(f.column_stats),
                    "tags": f.extra or {}}}


def _file_from_add(a: dict) -> DataFileMeta:
    st = _stats_from_delta(a.get("stats"))
    num = json.loads(a["stats"])["numRecords"] if a.get("stats") else 0
    return DataFileMeta(path=a["path"], size_bytes=a["size"], record_count=num,
                        partition_values=dict(a.get("partitionValues", {})),
                        column_stats=st, extra=dict(a.get("tags", {})))


class DeltaTable:
    format = FORMAT

    def __init__(self, fs, base_path: str):
        self.fs = fs
        self.base = base_path

    # ------------------------------------------------------------- lifecycle
    @classmethod
    def exists(cls, fs, base_path: str) -> bool:
        return bool(fs.list_dir(join(base_path, LOG_DIR)))

    @classmethod
    def create(cls, fs, base_path: str, schema: Schema,
               partition_spec: PartitionSpec = PartitionSpec(),
               properties: dict | None = None) -> "DeltaTable":
        t = cls(fs, base_path)
        ts = _now_ms()
        actions = [
            {"protocol": {"minReaderVersion": 1, "minWriterVersion": 2}},
            _metadata_action(schema, partition_spec, properties or {}, ts),
            {"commitInfo": {"timestamp": ts, "operation": "CREATE TABLE",
                            "operationParameters": {}}},
        ]
        t._write_commit(0, actions)
        return t

    @classmethod
    def open(cls, fs, base_path: str) -> "DeltaTable":
        if not cls.exists(fs, base_path):
            raise FileNotFoundError(f"no delta table at {base_path}")
        return cls(fs, base_path)

    # ------------------------------------------------------------------ log
    def _log_path(self, version: int, checkpoint: bool = False) -> str:
        suffix = ".checkpoint.json" if checkpoint else ".json"
        return join(self.base, LOG_DIR, f"{version:020d}{suffix}")

    def _list_versions(self) -> list[int]:
        names = self.fs.list_dir(join(self.base, LOG_DIR))
        return sorted(int(n[:20]) for n in names
                      if n.endswith(".json") and not n.endswith(".checkpoint.json")
                      and n[:20].isdigit())

    def _read_actions(self, version: int) -> list[dict]:
        raw = self.fs.read_bytes(self._log_path(version)).decode()
        return [json.loads(line) for line in raw.splitlines() if line.strip()]

    def _read_actions_many(self, versions: list[int]) -> dict[int, list[dict]]:
        """Batched fetch of many log segments: the independent GETs go
        through ``read_many`` so a replay over a high-RTT object store is
        pipelined instead of one round trip per commit file."""
        blobs = fetch_many(self.fs, [self._log_path(v) for v in versions])
        return {v: [json.loads(line) for line in raw.decode().splitlines()
                    if line.strip()]
                for v, raw in zip(versions, blobs)}

    def _last_checkpoint(self) -> int | None:
        p = join(self.base, LOG_DIR, "_last_checkpoint")
        if not self.fs.exists(p):
            return None
        return json.loads(self.fs.read_bytes(p))["version"]

    def _write_commit(self, version: int, actions: list[dict]) -> None:
        payload = "\n".join(json.dumps(a) for a in actions).encode()
        try:
            self.fs.write_bytes(self._log_path(version), payload)
        except PutIfAbsentError as e:
            raise CommitConflict(f"delta version {version} already committed") from e

    # ----------------------------------------------------------------- state
    def current_version(self) -> str:
        vs = self._list_versions()
        if not vs:
            raise FileNotFoundError("empty delta log")
        return str(vs[-1])

    def head(self) -> str:
        """The head commit id — one log-tail listing, no action reads."""
        return self.current_version()

    def head_token(self) -> str:
        """O(1) change-detection probe: an opaque token that moves iff the
        table head moved.  One ``list_dir`` of ``_delta_log/`` — no log
        replay, no action reads — so an always-on watcher polling every
        table each cycle pays exactly one storage request per quiet table.
        An absent/empty log yields ``""`` (the "no table yet" token).
        """
        return self.head_probe()[0]

    def head_probe(self) -> tuple[str, int | None]:
        """``(head_token, probe_state)`` in ONE storage request.

        The probe state (the head version number) can be handed back to
        ``replay(probe=...)`` within the same daemon cycle so the tail
        refresh constructs the new log-segment names directly — delta
        versions are dense integers — instead of re-listing the log.
        """
        vs = self._list_versions()
        return (str(vs[-1]), vs[-1]) if vs else ("", None)

    def versions(self) -> list[str]:
        return [str(v) for v in self._list_versions()]

    def snapshot(self, version: str | None = None) -> TableState:
        versions = self._list_versions()
        if version is None and not versions:
            raise FileNotFoundError("empty delta log")
        target = int(version) if version is not None else versions[-1]
        files: dict[str, DataFileMeta] = {}
        schema, pspec, props, ts = None, PartitionSpec(), {}, 0
        start = 0
        cp = self._last_checkpoint()
        if cp is not None and cp <= target:
            for a in self._read_checkpoint(cp):
                schema, pspec, props, files, ts = _apply(a, schema, pspec, props,
                                                         files, ts)
            start = cp + 1
        live = [v for v in versions if start <= v <= target]
        actions_by_v = self._read_actions_many(live)
        for v in live:
            for a in actions_by_v[v]:
                schema, pspec, props, files, ts = _apply(a, schema, pspec, props,
                                                         files, ts)
        if schema is None:
            raise ValueError(f"no metaData action found up to version {target}")
        return TableState(FORMAT, str(target), ts, schema, pspec, files, props)

    def tail_state(self) -> tuple[str, Schema, PartitionSpec, dict]:
        """(head version, schema, partition spec, configuration) from the log
        *tail*: scan backwards until a ``metaData`` action, falling back to
        the checkpoint.  A sync-maintained target writes a metaData action on
        every commit (the sync token lives in the configuration), so this is
        one read regardless of history length — the O(1) way to answer "where
        is this target?" without replaying the log.
        """
        versions = self._list_versions()
        if not versions:
            raise FileNotFoundError("empty delta log")
        head = str(versions[-1])
        cp = self._last_checkpoint()
        for v in reversed(versions):
            if cp is not None and v <= cp:
                break
            for a in reversed(self._read_actions(v)):
                if "metaData" in a:
                    return (head, *_unpack_metadata(a["metaData"]))
        if cp is not None:
            for a in self._read_checkpoint(cp):
                if "metaData" in a:
                    return (head, *_unpack_metadata(a["metaData"]))
        raise ValueError("no metaData action in delta log")

    def changes(self, version: str) -> tuple[list[DataFileMeta], list[str], str, dict]:
        """(adds, removed paths, operation, commit-info) for one commit."""
        adds, removes, op, info = [], [], "unknown", {}
        for a in self._read_actions(int(version)):
            if "add" in a:
                adds.append(_file_from_add(a["add"]))
            elif "remove" in a:
                removes.append(a["remove"]["path"])
            elif "commitInfo" in a:
                op = a["commitInfo"].get("operation", "unknown")
                info = a["commitInfo"]
        return adds, removes, op, info

    def replay(self, since: str | None = None,
               seed: CommitEntry | None = None,
               probe: int | None = None
               ) -> tuple[TableState | None, list[CommitEntry]]:
        """Single-pass scan of the log -> per-commit entries.

        Returns ``(base, entries)``.  ``entries`` is one ``CommitEntry`` per
        surviving log version, in order; folding their adds/removes on top of
        ``base`` reproduces ``snapshot(v)`` for any listed version.  ``base``
        is ``None`` in the normal case (fold from the empty table); it is the
        checkpoint state when early log files were vacuumed behind a
        checkpoint and per-commit history below it no longer exists.

        With ``since`` set, only commits strictly AFTER that version are
        scanned (tail-only refresh); ``base`` is then always ``None``.
        ``seed`` (the caller's ``CommitEntry`` for ``since``) supplies the
        as-of schema/spec/properties so the tail costs O(new commits) reads;
        without it the metaData is recovered from the tail/checkpoint scan.
        Raises ``KeyError`` if ``since`` is no longer in the log (vacuumed) —
        callers fall back to a full replay.

        ``probe`` — the head version from a same-cycle ``head_probe()`` —
        lets a seeded tail replay skip the log listing entirely: delta
        versions are dense integers, so the segment names for
        ``since+1 .. probe`` are constructed directly (a vacuumed segment
        surfaces as ``FileNotFoundError`` and callers rebuild).
        """
        schema, pspec, props, ts = None, PartitionSpec(), {}, 0
        base = None
        start_after = -1
        if since is not None and seed is not None and probe is not None:
            # probe-assisted tail: zero head-discovery requests
            sv = int(since)
            if int(probe) < sv:
                # the head moved BEHIND the anchor (restore / divergent
                # rewrite): an empty constructed range would silently hide
                # it — surface it like the unhinted membership check does
                raise KeyError(f"head {probe} behind anchor {since} "
                               f"(divergent rewrite)")
            schema, pspec, props = (seed.schema, seed.partition_spec,
                                    dict(seed.properties))
            ts = seed.timestamp_ms
            tail = list(range(sv + 1, int(probe) + 1))
            actions_by_v = self._read_actions_many(tail)
            entries = []
            for v in tail:
                schema, pspec, props, ts, e = self._entry_of(
                    v, actions_by_v[v], schema, pspec, props, ts)
                entries.append(e)
            return None, entries
        versions = self._list_versions()
        cp = self._last_checkpoint()
        if since is not None:
            sv = int(since)
            if sv not in versions and (cp is None or sv != cp):
                raise KeyError(f"version {since} not in delta log")
            if seed is not None:
                schema, pspec, props = (seed.schema, seed.partition_spec,
                                        dict(seed.properties))
                ts = seed.timestamp_ms
            elif cp is not None and sv == cp:
                # resuming right at the checkpoint base: its metaData seeds
                for a in self._read_checkpoint(cp):
                    if "metaData" in a:
                        schema, pspec, props = _unpack_metadata(a["metaData"])
            else:
                raise KeyError(f"no seed state for version {since}")
            tail = [v for v in versions if v > sv]
            actions_by_v = self._read_actions_many(tail)
            entries = []
            for v in tail:
                schema, pspec, props, ts, e = self._entry_of(
                    v, actions_by_v[v], schema, pspec, props, ts)
                entries.append(e)
            return None, entries
        if cp is not None and (not versions or versions[0] > 0):
            files: dict[str, DataFileMeta] = {}
            for a in self._read_checkpoint(cp):
                schema, pspec, props, files, ts = _apply(a, schema, pspec,
                                                         props, files, ts)
            base = TableState(FORMAT, str(cp), ts, schema, pspec, files, props)
            start_after = cp
        scan = [v for v in versions if v > start_after]
        actions_by_v = self._read_actions_many(scan)
        entries = []
        for v in scan:
            schema, pspec, props, ts, e = self._entry_of(
                v, actions_by_v[v], schema, pspec, props, ts)
            entries.append(e)
        return base, entries

    def _entry_of(self, v: int, actions: list[dict], schema, pspec, props, ts):
        """Fold one log file's (prefetched) actions -> updated running state
        + its CommitEntry."""
        adds, removes, op, info = [], [], "unknown", {}
        for a in actions:
            if "metaData" in a:
                schema, pspec, props = _unpack_metadata(a["metaData"])
            elif "add" in a:
                adds.append(_file_from_add(a["add"]))
                ts = max(ts, a["add"].get("modificationTime", 0))
            elif "remove" in a:
                removes.append(a["remove"]["path"])
                ts = max(ts, a["remove"].get("deletionTimestamp", 0))
            elif "commitInfo" in a:
                op = a["commitInfo"].get("operation", "unknown")
                info = a["commitInfo"]
                ts = max(ts, a["commitInfo"].get("timestamp", 0))
        return schema, pspec, props, ts, CommitEntry(
            str(v), ts, op, tuple(adds), tuple(removes), schema, pspec,
            dict(props), info)

    def properties(self) -> dict:
        return self.snapshot().properties

    # --------------------------------------------------------------- commits
    def commit(self, adds: list[DataFileMeta] = (), removes: list[str] = (), *,
               schema: Schema | None = None, properties: dict | None = None,
               operation: str = "WRITE", extra_meta: dict | None = None,
               max_retries: int = 5) -> str:
        for _ in range(max_retries):
            try:
                return self._commit_once(adds, removes, schema, properties,
                                         operation, extra_meta)
            except CommitConflict:
                continue
        raise CommitConflict("delta commit retries exhausted")

    def _commit_once(self, adds, removes, schema, properties, operation,
                     extra_meta) -> str:
        cur = self.snapshot()
        version = int(cur.version) + 1
        ts = _now_ms()
        actions: list[dict] = []
        if schema is not None or properties:
            new_schema = schema or cur.schema
            props = dict(cur.properties)
            props.update(properties or {})
            actions.append(_metadata_action(new_schema, cur.partition_spec, props, ts))
        for p in removes:
            actions.append({"remove": {"path": p, "deletionTimestamp": ts,
                                       "dataChange": True}})
        for f in adds:
            actions.append(_add_action(f, ts))
        ci = {"timestamp": ts, "operation": operation, "operationParameters": {}}
        if extra_meta:
            ci["xtable"] = extra_meta
        actions.append({"commitInfo": ci})
        self._write_commit(version, actions)
        self._maybe_checkpoint(version)
        return str(version)

    # ------------------------------------------------------------ checkpoint
    def _checkpoint_interval(self) -> int:
        try:
            return int(self.snapshot().properties.get(
                CHECKPOINT_INTERVAL_KEY, DEFAULT_CHECKPOINT_INTERVAL))
        except Exception:
            return DEFAULT_CHECKPOINT_INTERVAL

    def _maybe_checkpoint(self, version: int) -> None:
        if version == 0 or version % self._checkpoint_interval():
            return
        st = self.snapshot(str(version))
        actions = [{"protocol": {"minReaderVersion": 1, "minWriterVersion": 2}},
                   _metadata_action(st.schema, st.partition_spec, st.properties,
                                    st.timestamp_ms)]
        actions += [_add_action(f, st.timestamp_ms) for f in st.files.values()]
        try:
            self.fs.write_bytes(self._log_path(version, checkpoint=True),
                                "\n".join(json.dumps(a) for a in actions).encode())
        except PutIfAbsentError:
            return  # concurrent checkpointer won; fine
        self.fs.write_bytes(join(self.base, LOG_DIR, "_last_checkpoint"),
                            json.dumps({"version": version}).encode(),
                            overwrite=True)

    def _read_checkpoint(self, version: int) -> list[dict]:
        raw = self.fs.read_bytes(self._log_path(version, checkpoint=True)).decode()
        return [json.loads(line) for line in raw.splitlines() if line.strip()]

    # ----------------------------------------------------------- transaction
    def transaction(self, *, schema: Schema | None = None) -> "DeltaTransaction":
        """Multi-commit transaction: read the log tail ONCE, then thread the
        (version counter, schema, spec, configuration) through every commit
        in memory — each flush is one put-if-absent log write with zero
        re-reads, instead of the full-snapshot-per-commit of ``commit()``."""
        return DeltaTransaction(self, schema=schema)


class DeltaTransaction:
    """Buffered writer state for an N-commit sync unit (single writer).

    Begin cost: one ``list_dir`` + the tail metaData read.  Per commit: one
    put-if-absent write, no reads.  The file list is only materialized if a
    checkpoint boundary is crossed (bounded by the checkpoint interval, not
    the table history), then kept up to date in memory.
    """

    def __init__(self, table: DeltaTable, *, schema: Schema | None = None):
        self.t = table
        head, tail_schema, pspec, props = table.tail_state()
        self._version = int(head)
        self._schema = schema or tail_schema
        self._pspec = pspec
        self._props = props
        self._files: dict[str, DataFileMeta] | None = None   # lazy (checkpoint)

    @property
    def version(self) -> str:
        return str(self._version)

    def commit(self, adds: list[DataFileMeta] = (), removes: list[str] = (), *,
               schema: Schema | None = None, properties: dict | None = None,
               operation: str = "WRITE", extra_meta: dict | None = None,
               max_retries: int = 5) -> str:
        for _ in range(max_retries):
            version = self._version + 1
            ts = _now_ms()
            new_schema = schema or self._schema
            new_props = dict(self._props)
            new_props.update({k: str(v) for k, v in (properties or {}).items()})
            actions: list[dict] = []
            if schema is not None or properties:
                actions.append(_metadata_action(new_schema, self._pspec,
                                                new_props, ts))
            for p in removes:
                actions.append({"remove": {"path": p, "deletionTimestamp": ts,
                                           "dataChange": True}})
            for f in adds:
                actions.append(_add_action(f, ts))
            ci = {"timestamp": ts, "operation": operation,
                  "operationParameters": {}}
            if extra_meta:
                ci["xtable"] = extra_meta
            actions.append({"commitInfo": ci})
            try:
                self.t._write_commit(version, actions)
            except CommitConflict:
                # a concurrent writer took this version: re-sync the counter
                # and config from the tail and try the next slot
                head, self._schema, self._pspec, self._props = \
                    self.t.tail_state()
                self._version = int(head)
                self._files = None
                continue
            self._version = version
            self._schema = new_schema
            self._props = new_props
            if self._files is not None:
                for p in removes:
                    self._files.pop(p, None)
                for f in adds:
                    self._files[f.path] = f
            self._maybe_checkpoint(version, ts)
            return str(version)
        raise CommitConflict("delta transactional commit retries exhausted")

    def _maybe_checkpoint(self, version: int, ts: int) -> None:
        try:
            interval = int(self._props.get(CHECKPOINT_INTERVAL_KEY,
                                           DEFAULT_CHECKPOINT_INTERVAL))
        except (TypeError, ValueError):
            interval = DEFAULT_CHECKPOINT_INTERVAL
        if version == 0 or version % interval:
            return
        if self._files is None:   # one bounded read-back, then tracked
            self._files = dict(self.t.snapshot(str(version)).files)
        actions = [{"protocol": {"minReaderVersion": 1, "minWriterVersion": 2}},
                   _metadata_action(self._schema, self._pspec, self._props, ts)]
        actions += [_add_action(f, ts) for f in self._files.values()]
        try:
            self.t.fs.write_bytes(
                self.t._log_path(version, checkpoint=True),
                "\n".join(json.dumps(a) for a in actions).encode())
        except PutIfAbsentError:
            return  # concurrent checkpointer won; fine
        self.t.fs.write_bytes(join(self.t.base, LOG_DIR, "_last_checkpoint"),
                              json.dumps({"version": version}).encode(),
                              overwrite=True)

    def close(self) -> None:
        pass


class CommitConflict(RuntimeError):
    pass


def _unpack_metadata(m: dict) -> tuple[Schema, PartitionSpec, dict]:
    return (schema_from_delta(m["schemaString"]),
            PartitionSpec(m.get("partitionColumns", [])),
            dict(m.get("configuration", {})))


def _metadata_action(schema: Schema, pspec: PartitionSpec, props: dict,
                     ts: int) -> dict:
    return {"metaData": {
        "id": props.get("delta.tableId", "tbl"),
        "format": {"provider": "chunkfile", "options": {}},
        "schemaString": schema_to_delta(schema),
        "partitionColumns": pspec.column_names(),
        "configuration": {k: str(v) for k, v in props.items()},
        "createdTime": ts}}


def _apply(action: dict, schema, pspec, props, files, ts):
    if "metaData" in action:
        m = action["metaData"]
        schema = schema_from_delta(m["schemaString"])
        pspec = PartitionSpec(m.get("partitionColumns", []))
        props = dict(m.get("configuration", {}))
    elif "add" in action:
        f = _file_from_add(action["add"])
        files[f.path] = f
        ts = max(ts, action["add"].get("modificationTime", 0))
    elif "remove" in action:
        files.pop(action["remove"]["path"], None)
        ts = max(ts, action["remove"].get("deletionTimestamp", 0))
    elif "commitInfo" in action:
        ts = max(ts, action["commitInfo"].get("timestamp", 0))
    return schema, pspec, props, files, ts


def _now_ms() -> int:
    return time.time_ns() // 1_000_000
