"""Delta-Lake-style LST: an ordered JSON action log under ``_delta_log/``.

Faithful architectural reimplementation of the Delta transaction-log protocol:

* ``_delta_log/{version:020d}.json`` — newline-delimited JSON *actions*
  (``protocol``, ``metaData``, ``add``, ``remove``, ``commitInfo``).
* Version = the integer in the file name; commit = put-if-absent of the next
  version file (optimistic concurrency, exactly Delta's protocol on object
  stores with conditional writes).
* Checkpoints: every ``delta.checkpointInterval`` commits an aggregated
  ``{version:020d}.checkpoint.json`` plus a ``_last_checkpoint`` pointer, so
  state reconstruction replays O(interval) log files, not O(history).
* Per-file statistics ride in ``add.stats`` as a JSON string
  (``numRecords/minValues/maxValues/nullCount``) — Delta's layout.
"""

from __future__ import annotations

import json
import time

from repro.lst.chunkfile import ColumnStats, DataFileMeta
from repro.lst.fs import PutIfAbsentError, join
from repro.lst.schema import (CommitEntry, Field, PartitionSpec, Schema,
                              TableState)

FORMAT = "delta"
LOG_DIR = "_delta_log"
CHECKPOINT_INTERVAL_KEY = "delta.checkpointInterval"
DEFAULT_CHECKPOINT_INTERVAL = 10

_TYPES_TO_DELTA = {"int32": "integer", "int64": "long", "float32": "float",
                   "float64": "double", "string": "string", "bool": "boolean",
                   "binary": "binary", "timestamp": "timestamp"}
_DELTA_TO_TYPES = {v: k for k, v in _TYPES_TO_DELTA.items()}


def schema_to_delta(schema: Schema) -> str:
    return json.dumps({"type": "struct", "fields": [
        {"name": f.name, "type": _TYPES_TO_DELTA[f.type], "nullable": f.nullable,
         "metadata": ({"delta.columnMapping.id": f.field_id}
                      if f.field_id is not None else {})}
        for f in schema.fields]})


def schema_from_delta(s: str, schema_id: int = 0) -> Schema:
    d = json.loads(s)
    return Schema([Field(f["name"], _DELTA_TO_TYPES[f["type"]], f["nullable"],
                         f.get("metadata", {}).get("delta.columnMapping.id"))
                   for f in d["fields"]], schema_id)


def _stats_to_delta(column_stats: dict) -> str:
    num = max((s.count for s in column_stats.values()), default=0)
    return json.dumps({
        "numRecords": num,
        "minValues": {k: s.min for k, s in column_stats.items() if s.min is not None},
        "maxValues": {k: s.max for k, s in column_stats.items() if s.max is not None},
        "nullCount": {k: s.nan_count for k, s in column_stats.items()},
    })


def _stats_from_delta(s: str | None) -> dict:
    if not s:
        return {}
    d = json.loads(s)
    cols = set(d.get("minValues", {})) | set(d.get("maxValues", {})) | \
        set(d.get("nullCount", {}))
    return {c: ColumnStats(d.get("minValues", {}).get(c),
                           d.get("maxValues", {}).get(c),
                           d.get("numRecords", 0),
                           d.get("nullCount", {}).get(c, 0)) for c in cols}


def _add_action(f: DataFileMeta, ts: int) -> dict:
    return {"add": {"path": f.path, "partitionValues": {k: str(v) for k, v in
                                                        f.partition_values.items()},
                    "size": f.size_bytes, "modificationTime": ts, "dataChange": True,
                    "stats": _stats_to_delta(f.column_stats),
                    "tags": f.extra or {}}}


def _file_from_add(a: dict) -> DataFileMeta:
    st = _stats_from_delta(a.get("stats"))
    num = json.loads(a["stats"])["numRecords"] if a.get("stats") else 0
    return DataFileMeta(path=a["path"], size_bytes=a["size"], record_count=num,
                        partition_values=dict(a.get("partitionValues", {})),
                        column_stats=st, extra=dict(a.get("tags", {})))


class DeltaTable:
    format = FORMAT

    def __init__(self, fs, base_path: str):
        self.fs = fs
        self.base = base_path

    # ------------------------------------------------------------- lifecycle
    @classmethod
    def exists(cls, fs, base_path: str) -> bool:
        return bool(fs.list_dir(join(base_path, LOG_DIR)))

    @classmethod
    def create(cls, fs, base_path: str, schema: Schema,
               partition_spec: PartitionSpec = PartitionSpec(),
               properties: dict | None = None) -> "DeltaTable":
        t = cls(fs, base_path)
        ts = _now_ms()
        actions = [
            {"protocol": {"minReaderVersion": 1, "minWriterVersion": 2}},
            _metadata_action(schema, partition_spec, properties or {}, ts),
            {"commitInfo": {"timestamp": ts, "operation": "CREATE TABLE",
                            "operationParameters": {}}},
        ]
        t._write_commit(0, actions)
        return t

    @classmethod
    def open(cls, fs, base_path: str) -> "DeltaTable":
        if not cls.exists(fs, base_path):
            raise FileNotFoundError(f"no delta table at {base_path}")
        return cls(fs, base_path)

    # ------------------------------------------------------------------ log
    def _log_path(self, version: int, checkpoint: bool = False) -> str:
        suffix = ".checkpoint.json" if checkpoint else ".json"
        return join(self.base, LOG_DIR, f"{version:020d}{suffix}")

    def _list_versions(self) -> list[int]:
        names = self.fs.list_dir(join(self.base, LOG_DIR))
        return sorted(int(n[:20]) for n in names
                      if n.endswith(".json") and not n.endswith(".checkpoint.json")
                      and n[:20].isdigit())

    def _read_actions(self, version: int) -> list[dict]:
        raw = self.fs.read_bytes(self._log_path(version)).decode()
        return [json.loads(line) for line in raw.splitlines() if line.strip()]

    def _last_checkpoint(self) -> int | None:
        p = join(self.base, LOG_DIR, "_last_checkpoint")
        if not self.fs.exists(p):
            return None
        return json.loads(self.fs.read_bytes(p))["version"]

    def _write_commit(self, version: int, actions: list[dict]) -> None:
        payload = "\n".join(json.dumps(a) for a in actions).encode()
        try:
            self.fs.write_bytes(self._log_path(version), payload)
        except PutIfAbsentError as e:
            raise CommitConflict(f"delta version {version} already committed") from e

    # ----------------------------------------------------------------- state
    def current_version(self) -> str:
        vs = self._list_versions()
        if not vs:
            raise FileNotFoundError("empty delta log")
        return str(vs[-1])

    def versions(self) -> list[str]:
        return [str(v) for v in self._list_versions()]

    def snapshot(self, version: str | None = None) -> TableState:
        target = int(version) if version is not None else int(self.current_version())
        files: dict[str, DataFileMeta] = {}
        schema, pspec, props, ts = None, PartitionSpec(), {}, 0
        start = 0
        cp = self._last_checkpoint()
        if cp is not None and cp <= target:
            for a in self._read_checkpoint(cp):
                schema, pspec, props, files, ts = _apply(a, schema, pspec, props,
                                                         files, ts)
            start = cp + 1
        for v in range(start, target + 1):
            if not self.fs.exists(self._log_path(v)):
                continue
            for a in self._read_actions(v):
                schema, pspec, props, files, ts = _apply(a, schema, pspec, props,
                                                         files, ts)
        if schema is None:
            raise ValueError(f"no metaData action found up to version {target}")
        return TableState(FORMAT, str(target), ts, schema, pspec, files, props)

    def changes(self, version: str) -> tuple[list[DataFileMeta], list[str], str, dict]:
        """(adds, removed paths, operation, commit-info) for one commit."""
        adds, removes, op, info = [], [], "unknown", {}
        for a in self._read_actions(int(version)):
            if "add" in a:
                adds.append(_file_from_add(a["add"]))
            elif "remove" in a:
                removes.append(a["remove"]["path"])
            elif "commitInfo" in a:
                op = a["commitInfo"].get("operation", "unknown")
                info = a["commitInfo"]
        return adds, removes, op, info

    def replay(self) -> tuple[TableState | None, list[CommitEntry]]:
        """Single-pass scan of the whole log -> per-commit entries.

        Returns ``(base, entries)``.  ``entries`` is one ``CommitEntry`` per
        surviving log version, in order; folding their adds/removes on top of
        ``base`` reproduces ``snapshot(v)`` for any listed version.  ``base``
        is ``None`` in the normal case (fold from the empty table); it is the
        checkpoint state when early log files were vacuumed behind a
        checkpoint and per-commit history below it no longer exists.
        """
        versions = self._list_versions()
        schema, pspec, props, ts = None, PartitionSpec(), {}, 0
        base = None
        start_after = -1
        cp = self._last_checkpoint()
        if cp is not None and (not versions or versions[0] > 0):
            files: dict[str, DataFileMeta] = {}
            for a in self._read_checkpoint(cp):
                schema, pspec, props, files, ts = _apply(a, schema, pspec,
                                                         props, files, ts)
            base = TableState(FORMAT, str(cp), ts, schema, pspec, files, props)
            start_after = cp
        entries = []
        for v in versions:
            if v <= start_after:
                continue
            adds, removes, op, info = [], [], "unknown", {}
            for a in self._read_actions(v):
                if "metaData" in a:
                    m = a["metaData"]
                    schema = schema_from_delta(m["schemaString"])
                    pspec = PartitionSpec(m.get("partitionColumns", []))
                    props = dict(m.get("configuration", {}))
                elif "add" in a:
                    adds.append(_file_from_add(a["add"]))
                    ts = max(ts, a["add"].get("modificationTime", 0))
                elif "remove" in a:
                    removes.append(a["remove"]["path"])
                    ts = max(ts, a["remove"].get("deletionTimestamp", 0))
                elif "commitInfo" in a:
                    op = a["commitInfo"].get("operation", "unknown")
                    info = a["commitInfo"]
                    ts = max(ts, a["commitInfo"].get("timestamp", 0))
            entries.append(CommitEntry(str(v), ts, op, tuple(adds),
                                       tuple(removes), schema, pspec,
                                       dict(props), info))
        return base, entries

    def properties(self) -> dict:
        return self.snapshot().properties

    # --------------------------------------------------------------- commits
    def commit(self, adds: list[DataFileMeta] = (), removes: list[str] = (), *,
               schema: Schema | None = None, properties: dict | None = None,
               operation: str = "WRITE", extra_meta: dict | None = None,
               max_retries: int = 5) -> str:
        for _ in range(max_retries):
            try:
                return self._commit_once(adds, removes, schema, properties,
                                         operation, extra_meta)
            except CommitConflict:
                continue
        raise CommitConflict("delta commit retries exhausted")

    def _commit_once(self, adds, removes, schema, properties, operation,
                     extra_meta) -> str:
        cur = self.snapshot()
        version = int(cur.version) + 1
        ts = _now_ms()
        actions: list[dict] = []
        if schema is not None or properties:
            new_schema = schema or cur.schema
            props = dict(cur.properties)
            props.update(properties or {})
            actions.append(_metadata_action(new_schema, cur.partition_spec, props, ts))
        for p in removes:
            actions.append({"remove": {"path": p, "deletionTimestamp": ts,
                                       "dataChange": True}})
        for f in adds:
            actions.append(_add_action(f, ts))
        ci = {"timestamp": ts, "operation": operation, "operationParameters": {}}
        if extra_meta:
            ci["xtable"] = extra_meta
        actions.append({"commitInfo": ci})
        self._write_commit(version, actions)
        self._maybe_checkpoint(version)
        return str(version)

    # ------------------------------------------------------------ checkpoint
    def _checkpoint_interval(self) -> int:
        try:
            return int(self.snapshot().properties.get(
                CHECKPOINT_INTERVAL_KEY, DEFAULT_CHECKPOINT_INTERVAL))
        except Exception:
            return DEFAULT_CHECKPOINT_INTERVAL

    def _maybe_checkpoint(self, version: int) -> None:
        if version == 0 or version % self._checkpoint_interval():
            return
        st = self.snapshot(str(version))
        actions = [{"protocol": {"minReaderVersion": 1, "minWriterVersion": 2}},
                   _metadata_action(st.schema, st.partition_spec, st.properties,
                                    st.timestamp_ms)]
        actions += [_add_action(f, st.timestamp_ms) for f in st.files.values()]
        try:
            self.fs.write_bytes(self._log_path(version, checkpoint=True),
                                "\n".join(json.dumps(a) for a in actions).encode())
        except PutIfAbsentError:
            return  # concurrent checkpointer won; fine
        self.fs.write_bytes(join(self.base, LOG_DIR, "_last_checkpoint"),
                            json.dumps({"version": version}).encode(),
                            overwrite=True)

    def _read_checkpoint(self, version: int) -> list[dict]:
        raw = self.fs.read_bytes(self._log_path(version, checkpoint=True)).decode()
        return [json.loads(line) for line in raw.splitlines() if line.strip()]


class CommitConflict(RuntimeError):
    pass


def _metadata_action(schema: Schema, pspec: PartitionSpec, props: dict,
                     ts: int) -> dict:
    return {"metaData": {
        "id": props.get("delta.tableId", "tbl"),
        "format": {"provider": "chunkfile", "options": {}},
        "schemaString": schema_to_delta(schema),
        "partitionColumns": pspec.column_names(),
        "configuration": {k: str(v) for k, v in props.items()},
        "createdTime": ts}}


def _apply(action: dict, schema, pspec, props, files, ts):
    if "metaData" in action:
        m = action["metaData"]
        schema = schema_from_delta(m["schemaString"])
        pspec = PartitionSpec(m.get("partitionColumns", []))
        props = dict(m.get("configuration", {}))
    elif "add" in action:
        f = _file_from_add(action["add"])
        files[f.path] = f
        ts = max(ts, action["add"].get("modificationTime", 0))
    elif "remove" in action:
        files.pop(action["remove"]["path"], None)
        ts = max(ts, action["remove"].get("deletionTimestamp", 0))
    elif "commitInfo" in action:
        ts = max(ts, action["commitInfo"].get("timestamp", 0))
    return schema, pspec, props, files, ts


def _now_ms() -> int:
    return time.time_ns() // 1_000_000
