"""Pluggable filesystem with object-store semantics.

The paper's XTable connects to data lakes through a pluggable file system
(ABFS in Listing 2).  The property every LST commit protocol relies on is an
*atomic put-if-absent*: two writers racing to create the same object must see
exactly one winner.  ``LocalFS`` provides that via ``O_CREAT|O_EXCL``; any
object store with conditional puts (ABFS ETag, S3 If-None-Match, GCS
generation preconditions) can implement the same five methods.
"""

from __future__ import annotations

import os
import threading
from typing import Iterable, Protocol, runtime_checkable


class PutIfAbsentError(FileExistsError):
    """Raised when an exclusive create loses the race (commit conflict)."""


@runtime_checkable
class FileSystem(Protocol):
    def read_bytes(self, path: str) -> bytes: ...
    def read_bytes_range(self, path: str, offset: int, length: int) -> bytes: ...
    def write_bytes(self, path: str, data: bytes, *, overwrite: bool = False) -> None: ...
    def exists(self, path: str) -> bool: ...
    def list_dir(self, path: str) -> list[str]: ...
    def size(self, path: str) -> int: ...
    def delete(self, path: str) -> None: ...


def join(*parts: str) -> str:
    """Join path segments with '/' (object-store style, no os.sep surprises)."""
    cleaned = [p.strip("/") if i else p.rstrip("/") for i, p in enumerate(parts) if p]
    return "/".join(cleaned)


class LocalFS:
    """POSIX-backed FileSystem with object-store commit semantics.

    Writes are *atomic at the object level*: data is staged to a temp file and
    linked into place, so readers never observe partial objects — mirroring
    object-store single-shot PUTs (this is what makes LST metadata commits
    atomic, per §2 of the paper).
    """

    def __init__(self, *, fsync: bool = True) -> None:
        """``fsync=False`` skips the per-object fsync: atomicity (staged
        temp file + atomic link) is unchanged, only crash durability is
        relaxed — the knob benchmarks use so metadata-translation work is
        measured instead of disk flushes (object stores own durability and
        expose no fsync)."""
        self._lock = threading.Lock()
        self._fsync = fsync

    # -- reads ------------------------------------------------------------
    def read_bytes(self, path: str) -> bytes:
        with open(path, "rb") as f:
            return f.read()

    def read_bytes_range(self, path: str, offset: int, length: int) -> bytes:
        """Ranged GET (object-store style): ``length`` bytes from ``offset``."""
        with open(path, "rb") as f:
            f.seek(offset)
            return f.read(length)

    def exists(self, path: str) -> bool:
        return os.path.exists(path)

    def list_dir(self, path: str) -> list[str]:
        try:
            return sorted(os.listdir(path))
        except FileNotFoundError:
            return []

    def size(self, path: str) -> int:
        return os.stat(path).st_size

    # -- writes -----------------------------------------------------------
    def write_bytes(self, path: str, data: bytes, *, overwrite: bool = False) -> None:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            if self._fsync:
                os.fsync(f.fileno())
        if overwrite:
            os.replace(tmp, path)  # atomic swap
            return
        # put-if-absent: hardlink fails with EEXIST if somebody else won.
        try:
            os.link(tmp, path)
        except FileExistsError:
            raise PutIfAbsentError(path)
        finally:
            os.unlink(tmp)

    def delete(self, path: str) -> None:
        try:
            os.unlink(path)
        except FileNotFoundError:
            pass


def strip_scheme(path: str) -> str:
    """Accept abfs://c@a.dfs.core.windows.net/p, file:///p, or plain paths."""
    if "://" in path:
        rest = path.split("://", 1)[1]
        # drop the authority component for URI-style paths
        if "/" in rest:
            rest = rest.split("/", 1)[1]
        return "/" + rest.lstrip("/")
    return path
