"""Back-compat shim over :mod:`repro.lst.storage`.

The storage layer grew from this single module into the ``lst/storage/``
subsystem (protocol + local / memory / simulated backends, retry policy,
instrumentation, URI-scheme registry).  Existing imports keep working from
here; new code should import from ``repro.lst.storage``.
"""

from __future__ import annotations

from repro.lst.storage import (FileSystem, InstrumentedFS, LocalFS, MemoryFS,
                               PutIfAbsentError, RetryingFS, RetryPolicy,
                               SequentialBatchMixin, SimulatedObjectStore,
                               StorageProfile, StorageRetryExhausted,
                               TransientStorageError, fetch_many,
                               fetch_many_ranges, flush_many, join, make_fs,
                               resolve_uri, scheme_of, split_uri)

__all__ = [
    "FileSystem", "LocalFS", "MemoryFS", "SimulatedObjectStore",
    "StorageProfile", "RetryingFS", "RetryPolicy", "InstrumentedFS",
    "PutIfAbsentError", "TransientStorageError", "StorageRetryExhausted",
    "SequentialBatchMixin", "fetch_many", "fetch_many_ranges", "flush_many",
    "join", "make_fs", "resolve_uri", "scheme_of", "split_uri",
    "strip_scheme",
]


def strip_scheme(path: str) -> str:
    """Deprecated alias of :func:`repro.lst.storage.resolve_uri`.

    The old implementation dropped the authority for every scheme, so two
    buckets with the same key path collided; resolution now goes through
    the scheme registry, which keeps the bucket/container as the leading
    path component for object-store schemes.
    """
    return resolve_uri(path)
