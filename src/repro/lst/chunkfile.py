"""Immutable columnar data-file format ("chunkfile") with a statistics footer.

Plays the role Parquet/ORC play in the paper: a write-once columnar container
holding the table's records (or, in the checkpoint integration, a tensor
shard), carrying per-column min/max/count statistics that engines use for
scan planning (Scenario 3 of the paper: Trino exploiting Iceberg column
statistics).

Layout (single object, written atomically):

    [4-byte magic "CHK2"] [msgpack body] [msgpack footer]
    [8-byte LE footer offset] [4-byte magic]

The body is a msgpack map:
    schema:   [{name, dtype, shape}]          column declarations
    nrows:    int
    columns:  {name: raw little-endian bytes (optionally zlib)}
    extra:    arbitrary user metadata (tensor shard coords, tokenizer id, ...)

The footer is a msgpack map ``{nrows, stats}`` with
``stats: {name: {min, max, count, nan_count}}``; the trailing 8-byte
little-endian integer is the footer's byte offset from the start of the
object, so ``read_chunk_stats`` needs two ranged reads (tail + footer) and
never fetches the column data — the Parquet-footer access pattern.

Statistics live in the same object but are *also* duplicated into every
format's metadata layer by the commit path, which is what makes
metadata-only translation carry pruning power across formats.
"""

from __future__ import annotations

import struct
import threading
import zlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Mapping

import msgpack
import numpy as np

MAGIC = b"CHK2"       # v2: stats footer + trailing footer offset
_MAGIC_V1 = b"CHK1"   # v1 had stats inline in the body and no footer
_STR_KIND = "U"


def _check_magic(tag: bytes) -> None:
    if tag == _MAGIC_V1:
        raise ValueError("chunkfile v1 (CHK1, no stats footer) is "
                         "unsupported; rewrite the data file")
    if tag != MAGIC:
        raise ValueError("not a chunkfile (bad magic)")


@dataclass(frozen=True)
class ColumnStats:
    min: Any = None
    max: Any = None
    count: int = 0
    nan_count: int = 0

    def to_dict(self) -> dict:
        return {"min": self.min, "max": self.max, "count": self.count,
                "nan_count": self.nan_count}

    @staticmethod
    def from_dict(d: Mapping) -> "ColumnStats":
        return ColumnStats(d.get("min"), d.get("max"), d.get("count", 0),
                           d.get("nan_count", 0))


@dataclass(frozen=True)
class DataFileMeta:
    """What the metadata layer records about one immutable data file."""
    path: str                      # RELATIVE to the table base path
    size_bytes: int
    record_count: int
    partition_values: dict = field(default_factory=dict)
    column_stats: dict = field(default_factory=dict)   # name -> ColumnStats
    extra: dict = field(default_factory=dict)

    def stats_dict(self) -> dict:
        return {k: (v.to_dict() if isinstance(v, ColumnStats) else v)
                for k, v in self.column_stats.items()}


def _scalar(x):
    """Make numpy scalars msgpack-serializable."""
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, np.str_):
        return str(x)
    return x


def _column_stats(arr: np.ndarray) -> ColumnStats:
    count = int(arr.shape[0]) if arr.ndim else 1
    if arr.dtype.kind in "iuf" and arr.size:
        flat = arr.reshape(-1)
        if arr.dtype.kind == "f":
            nan = int(np.isnan(flat).sum())
            ok = flat[~np.isnan(flat)] if nan else flat
            if ok.size == 0:
                return ColumnStats(None, None, count, nan)
            return ColumnStats(_scalar(ok.min()), _scalar(ok.max()), count, nan)
        return ColumnStats(_scalar(flat.min()), _scalar(flat.max()), count, 0)
    if arr.dtype.kind == _STR_KIND and arr.size:
        # tolist() already yields Python str for U dtype (no per-string
        # conversion pass needed), and builtin min/max order by code point
        # exactly like numpy's U comparisons
        vals = arr.reshape(-1).tolist()
        return ColumnStats(min(vals), max(vals), count, 0)
    if arr.dtype.kind == "S" and arr.size:
        vals = [str(v) for v in arr.reshape(-1).tolist()]
        return ColumnStats(min(vals), max(vals), count, 0)
    return ColumnStats(None, None, count, 0)


def _encode_str_legacy(arr: np.ndarray) -> bytes:
    """The pre-fleet string encoding: a per-string Python loop into a
    msgpack list.  Kept for decode back-compat tests and as the
    benchmark's comparison arm — new files always use the vectorized
    fixed-width path below."""
    return msgpack.packb([str(s) for s in arr.reshape(-1)])


def _encode_array(arr: np.ndarray, compress: bool) -> tuple[dict, bytes]:
    if arr.dtype.kind == _STR_KIND:
        # unicode -> fixed-width columns via C-level casts, instead of the
        # legacy per-string Python listcomp into msgpack (that loop held
        # the GIL for the whole column — the convoy that made concurrent
        # CPU-bound bootstraps slower than serial).  ASCII columns cast to
        # 1-byte-per-char S dtype in one shot; anything else ships the
        # array's native fixed-width UCS4 buffer (a plain memcpy).
        # Trailing NULs are not representable in numpy's U dtype to begin
        # with, so fixed-width padding loses nothing.
        flat = np.ascontiguousarray(arr.reshape(-1))
        width = max(1, flat.dtype.itemsize // 4)
        decl = {"dtype": "str", "shape": list(arr.shape), "width": width}
        try:
            raw = flat.astype(f"S{width}").tobytes()
            decl["enc"] = "ascii"
        except UnicodeEncodeError:
            raw = flat.tobytes()
            decl["enc"] = "ucs4"
            decl["udtype"] = flat.dtype.str   # preserves byte order
    else:
        raw = np.ascontiguousarray(arr).tobytes()
        decl = {"dtype": arr.dtype.str, "shape": list(arr.shape)}
    if compress:
        raw = zlib.compress(raw, level=1)
        decl["codec"] = "zlib"
    return decl, raw


def _decode_array(decl: Mapping, raw: bytes) -> np.ndarray:
    if decl.get("codec") == "zlib":
        raw = zlib.decompress(raw)
    shape = tuple(decl["shape"])
    if decl["dtype"] == "str":
        enc = decl.get("enc")
        if enc == "ascii":
            w = decl["width"]
            return np.frombuffer(raw, dtype=f"S{w}") \
                .astype(f"U{w}").reshape(shape)
        if enc == "ucs4":
            return np.frombuffer(
                raw, dtype=np.dtype(decl["udtype"])).reshape(shape)
        # legacy files: length-delimited msgpack list of strings
        return np.array(msgpack.unpackb(raw), dtype=np.str_).reshape(shape)
    return np.frombuffer(raw, dtype=np.dtype(decl["dtype"])).reshape(shape)


def serialize_chunk(columns: Mapping[str, np.ndarray], *, extra: dict | None = None,
                    compress: bool = False) -> tuple[bytes, int, dict]:
    """Encode columns -> (payload bytes, nrows, stats dict)."""
    nrows = None
    decls, blobs, stats = [], {}, {}
    for name, arr in columns.items():
        arr = np.asarray(arr)
        if nrows is None:
            nrows = int(arr.shape[0]) if arr.ndim else 1
        decl, raw = _encode_array(arr, compress)
        decl["name"] = name
        decls.append(decl)
        blobs[name] = raw
        stats[name] = _column_stats(arr)
    body = {
        "schema": decls,
        "nrows": nrows or 0,
        "columns": blobs,
        "extra": extra or {},
    }
    footer = {"nrows": nrows or 0,
              "stats": {k: v.to_dict() for k, v in stats.items()}}
    body_packed = msgpack.packb(body)
    footer_off = len(MAGIC) + len(body_packed)
    payload = (MAGIC + body_packed + msgpack.packb(footer) +
               struct.pack("<Q", footer_off) + MAGIC)
    return payload, nrows or 0, stats


def write_chunk(fs, base_path: str, rel_path: str,
                columns: Mapping[str, np.ndarray], *,
                partition_values: dict | None = None,
                extra: dict | None = None, compress: bool = False) -> DataFileMeta:
    """Write one immutable data file; returns its metadata-layer description."""
    payload, nrows, stats = serialize_chunk(columns, extra=extra, compress=compress)
    full = f"{base_path}/{rel_path}"
    fs.write_bytes(full, payload)  # put-if-absent: data files are write-once
    return DataFileMeta(path=rel_path, size_bytes=len(payload), record_count=nrows,
                        partition_values=dict(partition_values or {}),
                        column_stats=stats, extra=dict(extra or {}))


def write_chunks(fs, base_path: str,
                 files: list[tuple[str, Mapping[str, np.ndarray], dict, dict]],
                 *, compress: bool = False) -> list[DataFileMeta]:
    """Batched ``write_chunk``: serialize every file, then flush all payloads
    in ONE pipelined ``write_many`` round (put-if-absent — data files are
    write-once), instead of one round trip per file.

    ``files`` is ``[(rel_path, columns, partition_values, extra)]``.  Data
    files are commit-*staged* objects: unreferenced until the metadata
    commit that names them lands, so pipelining them cannot tear a table.
    """
    from repro.lst.storage.base import flush_many

    metas, staged = [], []
    for rel_path, columns, partition_values, extra in files:
        payload, nrows, stats = serialize_chunk(columns, extra=extra,
                                                compress=compress)
        staged.append((f"{base_path}/{rel_path}", payload))
        metas.append(DataFileMeta(
            path=rel_path, size_bytes=len(payload), record_count=nrows,
            partition_values=dict(partition_values or {}),
            column_stats=stats, extra=dict(extra or {})))
    flush_many(fs, staged)
    return metas


_TRAILER_LEN = 8 + len(MAGIC)   # footer offset + closing magic


def _unpack(data: bytes) -> tuple[dict, dict]:
    """Full-object parse -> (body, footer)."""
    _check_magic(data[:4])
    _check_magic(data[-4:])
    (footer_off,) = struct.unpack("<Q", data[-_TRAILER_LEN:-len(MAGIC)])
    if not len(MAGIC) <= footer_off <= len(data) - _TRAILER_LEN:
        raise ValueError("not a chunkfile (bad footer offset)")
    body = msgpack.unpackb(data[len(MAGIC):footer_off], strict_map_key=False)
    footer = msgpack.unpackb(data[footer_off:-_TRAILER_LEN],
                             strict_map_key=False)
    return body, footer


def read_chunk(fs, base_path: str, rel_path: str) -> tuple[dict, dict]:
    """Read columns + extra metadata of a data file."""
    body, _ = _unpack(fs.read_bytes(f"{base_path}/{rel_path}"))
    cols = {d["name"]: _decode_array(d, body["columns"][d["name"]])
            for d in body["schema"]}
    return cols, body.get("extra", {})


def read_chunks(fs, base_path: str,
                rel_paths: list[str]) -> list[tuple[dict, dict]]:
    """Batched ``read_chunk``: all surviving bodies fetched in ONE
    pipelined ``read_many`` round instead of a round trip per file — the
    read plane's scan path is RTT-bound exactly like the write path was."""
    from repro.lst.storage.base import fetch_many

    blobs = fetch_many(fs, [f"{base_path}/{p}" for p in rel_paths])
    out = []
    for blob in blobs:
        body, _ = _unpack(blob)
        out.append(({d["name"]: _decode_array(d, body["columns"][d["name"]])
                     for d in body["schema"]}, body.get("extra", {})))
    return out


def read_chunks_stats(fs, base_path: str,
                      rel_paths: list[str]) -> list[tuple[int, dict]]:
    """Batched ``read_chunk_stats`` over many files: two pipelined rounds of
    ranged reads (all trailers, then all footers) via the FileSystem's batch
    API, instead of (size + 2 ranged reads) sequential round trips per file.

    Round 1 suffix-reads each trailer (no ``size`` request needed); round 2
    reads from each footer offset to end-of-object and strips the trailer —
    so N files cost ~2 batch round trips on a pipelined object store.
    """
    from repro.lst.storage.base import fetch_many_ranges

    fulls = [f"{base_path}/{p}" for p in rel_paths]
    tails = fetch_many_ranges(
        fs, [(f, -_TRAILER_LEN, _TRAILER_LEN) for f in fulls])
    footer_offs = []
    for p, tail in zip(fulls, tails):
        if len(tail) < _TRAILER_LEN:
            raise ValueError(f"not a chunkfile (truncated): {p}")
        _check_magic(tail[-4:])
        (off,) = struct.unpack("<Q", tail[:8])
        footer_offs.append(off)
    blobs = fetch_many_ranges(
        fs, [(f, off, -1) for f, off in zip(fulls, footer_offs)])
    out = []
    for p, blob in zip(fulls, blobs):
        if len(blob) <= _TRAILER_LEN:
            raise ValueError(f"not a chunkfile (bad footer offset): {p}")
        footer = msgpack.unpackb(blob[:-_TRAILER_LEN], strict_map_key=False)
        out.append((footer["nrows"],
                    {k: ColumnStats.from_dict(v)
                     for k, v in footer["stats"].items()}))
    return out


def read_chunk_stats(fs, base_path: str, rel_path: str) -> tuple[int, dict]:
    """Read only nrows + stats via two ranged reads (trailer, then footer);
    the column data is never fetched."""
    full = f"{base_path}/{rel_path}"
    size = fs.size(full)
    if size < 2 * len(MAGIC) + _TRAILER_LEN:
        raise ValueError("not a chunkfile (truncated)")
    tail = fs.read_bytes_range(full, size - _TRAILER_LEN, _TRAILER_LEN)
    _check_magic(tail[-4:])
    (footer_off,) = struct.unpack("<Q", tail[:8])
    if not len(MAGIC) <= footer_off <= size - _TRAILER_LEN:
        raise ValueError("not a chunkfile (bad footer offset)")
    footer = msgpack.unpackb(
        fs.read_bytes_range(full, footer_off, size - _TRAILER_LEN - footer_off),
        strict_map_key=False)
    return footer["nrows"], {k: ColumnStats.from_dict(v)
                             for k, v in footer["stats"].items()}


def stats_refute(stats: Mapping[str, ColumnStats], column: str, op: str,
                 value) -> bool:
    """True only when the footer stats PROVE no row of the chunk matches
    ``column <op> value`` — the predicate-pushdown primitive behind the
    read plane's pruned ``scan()``.

    Strictly conservative: a column with no stats entry, a None min/max
    (all-NaN or non-comparable dtype), an unknown op, or a type-mismatched
    comparison all answer False (keep the chunk).  NaN rows never satisfy
    a comparison predicate, and min/max are computed over the non-NaN
    values, so refuting by min/max stays sound for float columns with any
    ``nan_count``.
    """
    st = stats.get(column)
    if st is None or st.min is None or st.max is None:
        return False
    try:
        if op == "==":
            return bool(value < st.min or value > st.max)
        if op == "<":
            return bool(st.min >= value)
        if op == "<=":
            return bool(st.min > value)
        if op == ">":
            return bool(st.max <= value)
        if op == ">=":
            return bool(st.max < value)
    except TypeError:
        return False
    return False


def _stats_cost(stats: Mapping[str, ColumnStats], path: str) -> int:
    """Approximate retained bytes of one cached footer entry."""
    cost = 96 + len(path)
    for name, st in stats.items():
        cost += 64 + len(name)
        for v in (st.min, st.max):
            cost += len(v) * 4 if isinstance(v, str) else 8
    return cost


class ChunkStatsCache:
    """Byte-budgeted LRU of chunk stats footers, keyed by full chunk path.

    Chunk files are write-once and uniquely named, so a cached footer is
    valid forever — the cache only ever *evicts* (over budget), never
    invalidates.  ``get_many`` serves hits from memory and fetches all
    misses through :func:`read_chunks_stats`'s two pipelined ranged-read
    rounds, so a scan over N files costs at most 2 batch round trips on
    its first pass and ZERO footer requests on every later pass.

    Thread-safe; concurrent misses on the same path may fetch twice, but
    both fetch the same immutable bytes, so last-insert-wins is correct.
    """

    def __init__(self, max_bytes: int = 16 * 2**20):
        self.max_bytes = int(max_bytes)
        self._lock = threading.Lock()
        # path -> (nrows, stats, cost); OrderedDict end = most recent
        self._entries: OrderedDict[str, tuple[int, dict, int]] = OrderedDict()
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get_many(self, fs, base_path: str,
                 rel_paths: list[str]) -> list[tuple[int, dict]]:
        """``[(nrows, {column: ColumnStats})]`` aligned with ``rel_paths``."""
        fulls = [f"{base_path}/{p}" for p in rel_paths]
        out: list = [None] * len(fulls)
        missing: list[int] = []
        with self._lock:
            for i, full in enumerate(fulls):
                ent = self._entries.get(full)
                if ent is not None:
                    self._entries.move_to_end(full)
                    self.hits += 1
                    out[i] = (ent[0], ent[1])
                else:
                    missing.append(i)
        if not missing:
            return out
        fetched = read_chunks_stats(fs, base_path,
                                    [rel_paths[i] for i in missing])
        with self._lock:
            self.misses += len(missing)
            for i, (nrows, stats) in zip(missing, fetched):
                out[i] = (nrows, stats)
                full = fulls[i]
                if full not in self._entries:
                    cost = _stats_cost(stats, full)
                    self._entries[full] = (nrows, stats, cost)
                    self._bytes += cost
            while self._bytes > self.max_bytes and len(self._entries) > 1:
                _, (_, _, cost) = self._entries.popitem(last=False)
                self._bytes -= cost
                self.evictions += 1
        return out
