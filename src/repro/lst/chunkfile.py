"""Immutable columnar data-file format ("chunkfile") with a statistics footer.

Plays the role Parquet/ORC play in the paper: a write-once columnar container
holding the table's records (or, in the checkpoint integration, a tensor
shard), carrying per-column min/max/count statistics that engines use for
scan planning (Scenario 3 of the paper: Trino exploiting Iceberg column
statistics).

Layout v3 (single object, written atomically):

    [4-byte magic "CHK3"] [msgpack header] [column blobs, concatenated]
    [msgpack footer] [8-byte LE footer offset] [4-byte magic]

The header is a msgpack map ``{schema, nrows, extra}`` (``schema`` is the
column declaration list ``[{name, dtype, shape, ...}]``, ``extra`` arbitrary
user metadata — tensor shard coords, tokenizer id, ...).  Each column's
encoded bytes are laid out *outside* the header, one contiguous blob per
column in schema order, so any column is addressable by a byte range.

The footer is a msgpack map

    {nrows, stats, hdr_end, cols, schema}

with ``stats: {name: {min, max, count, nan_count}}``, ``cols: [[name,
offset, length], ...]`` — the **column-offset index** (absolute byte range
of every column blob) — and ``schema`` duplicating the header's column
declarations, so a reader holding only the footer can decode any subset of
columns from ranged reads without ever touching the header or the other
columns' bytes.  The trailing 8-byte little-endian integer is the footer's
byte offset from the start of the object; ``read_chunk_stats`` therefore
needs two ranged reads (suffix trailer + footer, no ``size`` request) and
never fetches column data — the Parquet-footer access pattern — while
:func:`read_chunks_columns` turns the index into *projection pushdown*:
only the requested columns' ranges are fetched (adjacent ranges coalesced
into single ranged GETs, all files in one pipelined batch round).

Layout v2 ("CHK2", still readable) kept the columns inside one msgpack
body map and its footer carried only ``{nrows, stats}``: no column index,
so projected reads of v2 files transparently fall back to full-body
fetches.  New files always write v3.

Statistics live in the same object but are *also* duplicated into every
format's metadata layer by the commit path, which is what makes
metadata-only translation carry pruning power across formats.
"""

from __future__ import annotations

import struct
import threading
import zlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Iterator, Mapping

import msgpack
import numpy as np

MAGIC = b"CHK3"       # v3: column-offset index in the footer
MAGIC_V2 = b"CHK2"    # v2: stats footer, columns inline in the msgpack body
_MAGIC_V1 = b"CHK1"   # v1 had stats inline in the body and no footer
_STR_KIND = "U"


def _magic_version(tag: bytes) -> int:
    if tag == MAGIC:
        return 3
    if tag == MAGIC_V2:
        return 2
    if tag == _MAGIC_V1:
        raise ValueError("chunkfile v1 (CHK1, no stats footer) is "
                         "unsupported; rewrite the data file")
    raise ValueError("not a chunkfile (bad magic)")


@dataclass(frozen=True)
class ColumnStats:
    min: Any = None
    max: Any = None
    count: int = 0
    nan_count: int = 0

    def to_dict(self) -> dict:
        return {"min": self.min, "max": self.max, "count": self.count,
                "nan_count": self.nan_count}

    @staticmethod
    def from_dict(d: Mapping) -> "ColumnStats":
        return ColumnStats(d.get("min"), d.get("max"), d.get("count", 0),
                           d.get("nan_count", 0))


@dataclass(frozen=True)
class DataFileMeta:
    """What the metadata layer records about one immutable data file."""
    path: str                      # RELATIVE to the table base path
    size_bytes: int
    record_count: int
    partition_values: dict = field(default_factory=dict)
    column_stats: dict = field(default_factory=dict)   # name -> ColumnStats
    extra: dict = field(default_factory=dict)

    def stats_dict(self) -> dict:
        return {k: (v.to_dict() if isinstance(v, ColumnStats) else v)
                for k, v in self.column_stats.items()}


@dataclass(frozen=True)
class ChunkFooter:
    """One file's parsed stats footer (+ the v3 column-offset index).

    ``columns`` is the ordered ``(name, offset, length)`` index of the
    column blobs (absolute object byte ranges) and ``schema`` maps each
    column name to its decode declaration — both ``None`` for v2 files,
    which carry no index (projected reads fall back to full bodies).

    Iterating yields ``(nrows, stats)`` so the footer unpacks exactly like
    the pre-v3 ``read_chunk_stats`` tuple.
    """
    nrows: int
    stats: dict                             # name -> ColumnStats
    columns: tuple | None = None            # ((name, offset, length), ...)
    schema: Mapping | None = None           # name -> decl

    def __iter__(self) -> Iterator:
        return iter((self.nrows, self.stats))

    @property
    def projectable(self) -> bool:
        return self.columns is not None


def _scalar(x):
    """Make numpy scalars msgpack-serializable."""
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, np.str_):
        return str(x)
    return x


def _column_stats(arr: np.ndarray) -> ColumnStats:
    count = int(arr.shape[0]) if arr.ndim else 1
    if arr.dtype.kind in "iuf" and arr.size:
        flat = arr.reshape(-1)
        if arr.dtype.kind == "f":
            nan = int(np.isnan(flat).sum())
            ok = flat[~np.isnan(flat)] if nan else flat
            if ok.size == 0:
                return ColumnStats(None, None, count, nan)
            return ColumnStats(_scalar(ok.min()), _scalar(ok.max()), count, nan)
        return ColumnStats(_scalar(flat.min()), _scalar(flat.max()), count, 0)
    if arr.dtype.kind == _STR_KIND and arr.size:
        # tolist() already yields Python str for U dtype (no per-string
        # conversion pass needed), and builtin min/max order by code point
        # exactly like numpy's U comparisons
        vals = arr.reshape(-1).tolist()
        return ColumnStats(min(vals), max(vals), count, 0)
    if arr.dtype.kind == "S" and arr.size:
        vals = [str(v) for v in arr.reshape(-1).tolist()]
        return ColumnStats(min(vals), max(vals), count, 0)
    return ColumnStats(None, None, count, 0)


def _encode_str_legacy(arr: np.ndarray) -> bytes:
    """The pre-fleet string encoding: a per-string Python loop into a
    msgpack list.  Kept for decode back-compat tests and as the
    benchmark's comparison arm — new files always use the vectorized
    fixed-width path below."""
    return msgpack.packb([str(s) for s in arr.reshape(-1)])


def _encode_array(arr: np.ndarray, compress: bool) -> tuple[dict, bytes]:
    if arr.dtype.kind == _STR_KIND:
        # unicode -> fixed-width columns via C-level casts, instead of the
        # legacy per-string Python listcomp into msgpack (that loop held
        # the GIL for the whole column — the convoy that made concurrent
        # CPU-bound bootstraps slower than serial).  ASCII columns cast to
        # 1-byte-per-char S dtype in one shot; anything else ships the
        # array's native fixed-width UCS4 buffer (a plain memcpy).
        # Trailing NULs are not representable in numpy's U dtype to begin
        # with, so fixed-width padding loses nothing.
        flat = np.ascontiguousarray(arr.reshape(-1))
        width = max(1, flat.dtype.itemsize // 4)
        decl = {"dtype": "str", "shape": list(arr.shape), "width": width}
        try:
            raw = flat.astype(f"S{width}").tobytes()
            decl["enc"] = "ascii"
        except UnicodeEncodeError:
            raw = flat.tobytes()
            decl["enc"] = "ucs4"
            decl["udtype"] = flat.dtype.str   # preserves byte order
    else:
        raw = np.ascontiguousarray(arr).tobytes()
        decl = {"dtype": arr.dtype.str, "shape": list(arr.shape)}
    if compress:
        raw = zlib.compress(raw, level=1)
        decl["codec"] = "zlib"
    return decl, raw


def _decode_array(decl: Mapping, raw: bytes) -> np.ndarray:
    if decl.get("codec") == "zlib":
        raw = zlib.decompress(raw)
    shape = tuple(decl["shape"])
    if decl["dtype"] == "str":
        enc = decl.get("enc")
        if enc == "ascii":
            w = decl["width"]
            return np.frombuffer(raw, dtype=f"S{w}") \
                .astype(f"U{w}").reshape(shape)
        if enc == "ucs4":
            return np.frombuffer(
                raw, dtype=np.dtype(decl["udtype"])).reshape(shape)
        # legacy files: length-delimited msgpack list of strings
        return np.array(msgpack.unpackb(raw), dtype=np.str_).reshape(shape)
    return np.frombuffer(raw, dtype=np.dtype(decl["dtype"])).reshape(shape)


def empty_column(decl: Mapping) -> np.ndarray:
    """A zero-row array with the dtype/trailing shape ``decl`` decodes to —
    exactly what an all-False row mask leaves of the column, synthesized
    without fetching a byte of it (the late-materialized scan's dropped
    chunks still contribute dtype-exact empties to concatenation)."""
    shape = (0,) + tuple(decl["shape"][1:])
    if decl["dtype"] == "str":
        if decl.get("enc") == "ucs4":
            return np.empty(shape, dtype=np.dtype(decl["udtype"]))
        return np.empty(shape, dtype=f"U{decl.get('width', 1)}")
    return np.empty(shape, dtype=np.dtype(decl["dtype"]))


def serialize_chunk(columns: Mapping[str, np.ndarray], *, extra: dict | None = None,
                    compress: bool = False,
                    version: int = 3) -> tuple[bytes, int, dict]:
    """Encode columns -> (payload bytes, nrows, stats dict).

    ``version=2`` writes the legacy CHK2 layout (columns inside the msgpack
    body, no column index) — kept so back-compat tests can mint old files;
    production writers always emit v3.
    """
    nrows = None
    decls, blobs, stats = [], {}, {}
    for name, arr in columns.items():
        arr = np.asarray(arr)
        if nrows is None:
            nrows = int(arr.shape[0]) if arr.ndim else 1
        decl, raw = _encode_array(arr, compress)
        decl["name"] = name
        decls.append(decl)
        blobs[name] = raw
        stats[name] = _column_stats(arr)
    stats_packed = {k: v.to_dict() for k, v in stats.items()}
    if version == 2:
        body = {"schema": decls, "nrows": nrows or 0, "columns": blobs,
                "extra": extra or {}}
        body_packed = msgpack.packb(body)
        footer = {"nrows": nrows or 0, "stats": stats_packed}
        footer_off = len(MAGIC_V2) + len(body_packed)
        payload = (MAGIC_V2 + body_packed + msgpack.packb(footer) +
                   struct.pack("<Q", footer_off) + MAGIC_V2)
        return payload, nrows or 0, stats
    if version != 3:
        raise ValueError(f"unsupported chunkfile version: {version}")
    header = msgpack.packb({"schema": decls, "nrows": nrows or 0,
                            "extra": extra or {}})
    hdr_end = len(MAGIC) + len(header)
    off = hdr_end
    cols_index = []
    for d in decls:
        raw = blobs[d["name"]]
        cols_index.append([d["name"], off, len(raw)])
        off += len(raw)
    footer = {"nrows": nrows or 0, "stats": stats_packed,
              "hdr_end": hdr_end, "cols": cols_index, "schema": decls}
    payload = (MAGIC + header + b"".join(blobs[d["name"]] for d in decls) +
               msgpack.packb(footer) + struct.pack("<Q", off) + MAGIC)
    return payload, nrows or 0, stats


def write_chunk(fs, base_path: str, rel_path: str,
                columns: Mapping[str, np.ndarray], *,
                partition_values: dict | None = None,
                extra: dict | None = None, compress: bool = False,
                version: int = 3) -> DataFileMeta:
    """Write one immutable data file; returns its metadata-layer description."""
    payload, nrows, stats = serialize_chunk(columns, extra=extra,
                                            compress=compress, version=version)
    full = f"{base_path}/{rel_path}"
    fs.write_bytes(full, payload)  # put-if-absent: data files are write-once
    return DataFileMeta(path=rel_path, size_bytes=len(payload), record_count=nrows,
                        partition_values=dict(partition_values or {}),
                        column_stats=stats, extra=dict(extra or {}))


def write_chunks(fs, base_path: str,
                 files: list[tuple[str, Mapping[str, np.ndarray], dict, dict]],
                 *, compress: bool = False) -> list[DataFileMeta]:
    """Batched ``write_chunk``: serialize every file, then flush all payloads
    in ONE pipelined ``write_many`` round (put-if-absent — data files are
    write-once), instead of one round trip per file.

    ``files`` is ``[(rel_path, columns, partition_values, extra)]``.  Data
    files are commit-*staged* objects: unreferenced until the metadata
    commit that names them lands, so pipelining them cannot tear a table.
    """
    from repro.lst.storage.base import flush_many

    metas, staged = [], []
    for rel_path, columns, partition_values, extra in files:
        payload, nrows, stats = serialize_chunk(columns, extra=extra,
                                                compress=compress)
        staged.append((f"{base_path}/{rel_path}", payload))
        metas.append(DataFileMeta(
            path=rel_path, size_bytes=len(payload), record_count=nrows,
            partition_values=dict(partition_values or {}),
            column_stats=stats, extra=dict(extra or {})))
    flush_many(fs, staged)
    return metas


_TRAILER_LEN = 8 + len(MAGIC)   # footer offset + closing magic


def _parse_full(data: bytes) -> tuple[dict, dict]:
    """Full-object parse -> (decoded columns, extra) for either version."""
    version = _magic_version(data[:4])
    _magic_version(data[-4:])
    (footer_off,) = struct.unpack("<Q", data[-_TRAILER_LEN:-len(MAGIC)])
    if not len(MAGIC) <= footer_off <= len(data) - _TRAILER_LEN:
        raise ValueError("not a chunkfile (bad footer offset)")
    if version == 2:
        body = msgpack.unpackb(data[len(MAGIC):footer_off],
                               strict_map_key=False)
        cols = {d["name"]: _decode_array(d, body["columns"][d["name"]])
                for d in body["schema"]}
        return cols, body.get("extra", {})
    footer = msgpack.unpackb(data[footer_off:-_TRAILER_LEN],
                             strict_map_key=False)
    header = msgpack.unpackb(data[len(MAGIC):footer["hdr_end"]],
                             strict_map_key=False)
    decls = {d["name"]: d for d in footer["schema"]}
    cols = {name: _decode_array(decls[name], data[off:off + ln])
            for name, off, ln in footer["cols"]}
    return cols, header.get("extra", {})


def read_chunk(fs, base_path: str, rel_path: str) -> tuple[dict, dict]:
    """Read columns + extra metadata of a data file."""
    return _parse_full(fs.read_bytes(f"{base_path}/{rel_path}"))


def read_chunks(fs, base_path: str,
                rel_paths: list[str]) -> list[tuple[dict, dict]]:
    """Batched ``read_chunk``: all surviving bodies fetched in ONE
    pipelined ``read_many`` round instead of a round trip per file — the
    read plane's scan path is RTT-bound exactly like the write path was."""
    from repro.lst.storage.base import fetch_many

    blobs = fetch_many(fs, [f"{base_path}/{p}" for p in rel_paths])
    return [_parse_full(blob) for blob in blobs]


def _parse_footer(blob: bytes, version: int, path: str) -> ChunkFooter:
    if len(blob) <= _TRAILER_LEN:
        raise ValueError(f"not a chunkfile (bad footer offset): {path}")
    footer = msgpack.unpackb(blob[:-_TRAILER_LEN], strict_map_key=False)
    stats = {k: ColumnStats.from_dict(v) for k, v in footer["stats"].items()}
    if version == 2 or "cols" not in footer:
        return ChunkFooter(footer["nrows"], stats)
    return ChunkFooter(footer["nrows"], stats,
                       tuple((c[0], c[1], c[2]) for c in footer["cols"]),
                       {d["name"]: d for d in footer["schema"]})


def read_chunks_footers(fs, base_path: str,
                        rel_paths: list[str]) -> list[ChunkFooter]:
    """Batched footer fetch over many files: two pipelined rounds of
    ranged reads (all trailers, then all footers) via the FileSystem's
    batch API, instead of (size + 2 ranged reads) sequential round trips
    per file.

    Round 1 suffix-reads each trailer (no ``size`` request needed); round 2
    reads from each footer offset to end-of-object and strips the trailer —
    so N files cost ~2 batch round trips on a pipelined object store.  The
    returned :class:`ChunkFooter` carries nrows + stats for both versions
    and, for v3 files, the column-offset index that powers
    :func:`read_chunks_columns`.
    """
    from repro.lst.storage.base import fetch_many_ranges

    fulls = [f"{base_path}/{p}" for p in rel_paths]
    tails = fetch_many_ranges(
        fs, [(f, -_TRAILER_LEN, _TRAILER_LEN) for f in fulls])
    versions, footer_offs = [], []
    for p, tail in zip(fulls, tails):
        if len(tail) < _TRAILER_LEN:
            raise ValueError(f"not a chunkfile (truncated): {p}")
        versions.append(_magic_version(tail[-4:]))
        (off,) = struct.unpack("<Q", tail[:8])
        footer_offs.append(off)
    blobs = fetch_many_ranges(
        fs, [(f, off, -1) for f, off in zip(fulls, footer_offs)])
    return [_parse_footer(blob, ver, p)
            for p, ver, blob in zip(fulls, versions, blobs)]


def read_chunks_stats(fs, base_path: str,
                      rel_paths: list[str]) -> list[tuple[int, dict]]:
    """Batched ``read_chunk_stats``: ``[(nrows, stats)]`` per file via the
    two-round footer fetch of :func:`read_chunks_footers`."""
    return [(f.nrows, f.stats)
            for f in read_chunks_footers(fs, base_path, rel_paths)]


def read_chunk_stats(fs, base_path: str, rel_path: str) -> tuple[int, dict]:
    """Read only nrows + stats via two ranged reads (suffix trailer, then
    footer-to-EOF); no ``size`` request, and the column data is never
    fetched."""
    footer = read_chunks_footers(fs, base_path, [rel_path])[0]
    return footer.nrows, footer.stats


def read_chunks_columns(fs, base_path: str, rel_paths: list[str],
                        columns: list[str] | None = None, *,
                        footers: list[ChunkFooter] | None = None,
                        exclude: frozenset | set | None = None,
                        ) -> list[tuple[dict, int]]:
    """Projection pushdown: fetch only the requested ``columns`` of each
    file through the v3 column-offset index.

    Per file, the requested columns' byte ranges are looked up in its
    footer index, adjacent ranges are coalesced into single ranged reads,
    and every file's ranges go out in ONE pipelined ``read_many_ranges``
    round — a scan projecting k of N columns moves O(k/N) of the bytes a
    full-body fetch would.  ``columns=None`` selects every column (still
    ranged: the header/footer bytes are skipped); ``exclude`` removes
    columns from the selection *after* that (the two-phase scan uses it to
    avoid refetching predicate columns it already holds).

    v2 files carry no index and transparently fall back to a full-body
    read **in the same batch round** (a to-EOF range); every column of
    such a file comes back, whatever was requested — callers project
    after the fact.

    ``footers`` (aligned with ``rel_paths``) reuses already-fetched
    footers — e.g. the read plane's :class:`ChunkStatsCache` entries —
    otherwise they are fetched first via :func:`read_chunks_footers`
    (two extra batch rounds).

    Returns ``[(columns dict, bytes fetched)]`` aligned with
    ``rel_paths``; decoded columns keep the file's schema order.
    """
    from repro.lst.storage.base import coalesce_ranges, fetch_many_ranges

    if footers is None:
        footers = read_chunks_footers(fs, base_path, rel_paths)
    fulls = [f"{base_path}/{p}" for p in rel_paths]
    want = None if columns is None else set(columns)
    drop = frozenset(exclude or ())
    plans: list = []            # per file: list of index entries | "full"
    range_reqs: list[tuple[str, int, int]] = []
    range_owner: list[tuple[int, str]] = []   # (file idx, column name)
    full_files: list[int] = []
    for i, (full, ftr) in enumerate(zip(fulls, footers)):
        if ftr.columns is None:               # v2: no index, whole body
            plans.append("full")
            full_files.append(i)
            continue
        entries = [e for e in ftr.columns
                   if (want is None or e[0] in want) and e[0] not in drop]
        plans.append(entries)
        for name, off, ln in entries:
            range_reqs.append((full, off, ln))
            range_owner.append((i, name))
    merged, slices = coalesce_ranges(range_reqs)
    batch = merged + [(fulls[i], 0, -1) for i in full_files]
    blobs = fetch_many_ranges(fs, batch)

    out: list = [None] * len(fulls)
    pieces: dict[tuple[int, str], bytes] = {}
    for (owner, (mi, off, ln)) in zip(range_owner, slices):
        start = off - merged[mi][1]
        pieces[owner] = blobs[mi][start:start + ln]
    for i, ftr in enumerate(footers):
        if plans[i] == "full":
            continue
        cols = {name: _decode_array(ftr.schema[name], pieces[(i, name)])
                for name, _off, _ln in plans[i]}
        out[i] = (cols, sum(ln for _n, _o, ln in plans[i]))
    for j, i in enumerate(full_files):
        blob = blobs[len(merged) + j]
        cols, _extra = _parse_full(blob)
        out[i] = (cols, len(blob))
    return out


def stats_refute(stats: Mapping[str, ColumnStats], column: str, op: str,
                 value) -> bool:
    """True only when the footer stats PROVE no row of the chunk matches
    ``column <op> value`` — the predicate-pushdown primitive behind the
    read plane's pruned ``scan()``.

    Strictly conservative: a column with no stats entry, a None min/max
    (all-NaN or non-comparable dtype), an unknown op, or a type-mismatched
    comparison all answer False (keep the chunk).  NaN rows never satisfy
    a comparison predicate, and min/max are computed over the non-NaN
    values, so refuting by min/max stays sound for float columns with any
    ``nan_count``.
    """
    st = stats.get(column)
    if st is None or st.min is None or st.max is None:
        return False
    try:
        if op == "==":
            return bool(value < st.min or value > st.max)
        if op == "<":
            return bool(st.min >= value)
        if op == "<=":
            return bool(st.min > value)
        if op == ">":
            return bool(st.max <= value)
        if op == ">=":
            return bool(st.max < value)
    except TypeError:
        return False
    return False


def _footer_cost(footer: ChunkFooter, path: str) -> int:
    """Approximate retained bytes of one cached footer entry."""
    cost = 96 + len(path)
    for name, st in footer.stats.items():
        cost += 64 + len(name)
        for v in (st.min, st.max):
            cost += len(v) * 4 if isinstance(v, str) else 8
    if footer.columns is not None:
        # column-offset index + decode decls ride along in the entry
        cost += sum(88 + 2 * len(name) for name, _o, _l in footer.columns)
    return cost


class ChunkStatsCache:
    """Byte-budgeted LRU of chunk footers, keyed by full chunk path.

    Chunk files are write-once and uniquely named, so a cached footer is
    valid forever — the cache only ever *evicts* (over budget), never
    invalidates.  ``get_many`` serves hits from memory and fetches all
    misses through :func:`read_chunks_footers`'s two pipelined ranged-read
    rounds, so a scan over N files costs at most 2 batch round trips on
    its first pass and ZERO footer requests on every later pass.  Each
    entry is a full :class:`ChunkFooter` — for v3 files the column-offset
    index rides along for free, which is what lets a warm projected scan
    go straight to its single column-range round.

    Thread-safe; concurrent misses on the same path may fetch twice, but
    both fetch the same immutable bytes, so last-insert-wins is correct.
    """

    def __init__(self, max_bytes: int = 16 * 2**20):
        self.max_bytes = int(max_bytes)
        self._lock = threading.Lock()
        # path -> (footer, cost); OrderedDict end = most recent
        self._entries: OrderedDict[str, tuple[ChunkFooter, int]] = OrderedDict()
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get_many(self, fs, base_path: str,
                 rel_paths: list[str]) -> list[ChunkFooter]:
        """:class:`ChunkFooter` per path, aligned with ``rel_paths``."""
        fulls = [f"{base_path}/{p}" for p in rel_paths]
        out: list = [None] * len(fulls)
        missing: list[int] = []
        with self._lock:
            for i, full in enumerate(fulls):
                ent = self._entries.get(full)
                if ent is not None:
                    self._entries.move_to_end(full)
                    self.hits += 1
                    out[i] = ent[0]
                else:
                    missing.append(i)
        if not missing:
            return out
        fetched = read_chunks_footers(fs, base_path,
                                      [rel_paths[i] for i in missing])
        with self._lock:
            self.misses += len(missing)
            for i, footer in zip(missing, fetched):
                out[i] = footer
                full = fulls[i]
                if full not in self._entries:
                    cost = _footer_cost(footer, full)
                    self._entries[full] = (footer, cost)
                    self._bytes += cost
            while self._bytes > self.max_bytes and len(self._entries) > 1:
                _, (_, cost) = self._entries.popitem(last=False)
                self._bytes -= cost
                self.evictions += 1
        return out
