"""Hudi-style LST: a timeline of instants under ``.hoodie/``.

Faithful architectural reimplementation of the Hudi (copy-on-write) timeline:

* ``.hoodie/hoodie.properties`` — table name/type/version, create schema
  (Avro record JSON), partition fields.
* Timeline instants ``.hoodie/{ts}.{action}`` with the three-phase state
  machine ``{ts}.{action}.requested`` -> ``{ts}.{action}.inflight`` ->
  ``{ts}.{action}`` (completed). Only the *completed* file makes the commit
  visible — put-if-absent of the completed instant is the atomic commit point.
* Actions: ``commit`` (insert/upsert) and ``replacecommit`` (COW delete /
  clustering), with ``partitionToWriteStats`` (per-file write statistics) and
  ``partitionToReplacedFilePaths`` payloads, schema + arbitrary key/values in
  ``extraMetadata`` (where XTable's real Hudi target stores its sync state).
* Data files are named ``{fileId}_{instant}.chunk`` inside partition dirs —
  Hudi's file-group/file-slice naming.
"""

from __future__ import annotations

import json
import threading
import time

from repro.lst.chunkfile import ColumnStats, DataFileMeta
from repro.lst.storage import PutIfAbsentError, fetch_many, flush_many, join
from repro.lst.schema import (CommitEntry, Field, PartitionSpec, Schema,
                              TableState)

FORMAT = "hudi"
HOODIE_DIR = ".hoodie"

_TYPES_TO_AVRO = {"int32": "int", "int64": "long", "float32": "float",
                  "float64": "double", "string": "string", "bool": "boolean",
                  "binary": "bytes",
                  "timestamp": {"type": "long", "logicalType": "timestamp-micros"}}


def schema_to_avro(schema: Schema, name: str = "record") -> str:
    fields = []
    for f in schema.fields:
        t = _TYPES_TO_AVRO[f.type]
        fields.append({"name": f.name, "type": ["null", t] if f.nullable else t})
    return json.dumps({"type": "record", "name": name, "fields": fields})


def schema_from_avro(s: str) -> Schema:
    d = json.loads(s)
    rev = {}
    for k, v in _TYPES_TO_AVRO.items():
        rev[json.dumps(v, sort_keys=True)] = k
    out = []
    for f in d["fields"]:
        t = f["type"]
        nullable = isinstance(t, list) and "null" in t
        if nullable:
            t = [x for x in t if x != "null"][0]
        out.append(Field(f["name"], rev[json.dumps(t, sort_keys=True)], nullable))
    return Schema(out)


# -- extraMetadata value codec ---------------------------------------------
# Every non-reserved value in a completed instant's ``extraMetadata`` is
# JSON-encoded on write and JSON-decoded on read, by this one pair — the
# commit path and every reader share it, so there is no "does it look
# quoted?" guessing (a string value that happens to start with '"' round-
# trips exactly).  ``schema`` is reserved: it is already an Avro JSON
# document and is stored/consumed verbatim by ``snapshot``/``replay``.
_EM_RAW_KEYS = frozenset({"schema"})


def encode_extra_metadata(extra: dict) -> dict:
    return {k: v if k in _EM_RAW_KEYS else json.dumps(v)
            for k, v in extra.items()}


def decode_extra_metadata(extra: dict) -> dict:
    out = {}
    for k, v in extra.items():
        if k in _EM_RAW_KEYS:
            out[k] = v
            continue
        try:
            out[k] = json.loads(v)
        except (TypeError, ValueError):
            # foreign writer storing a raw non-JSON string; NOTE a raw
            # string that parses as a JSON scalar ("7", "true") is
            # indistinguishable from the codec's encoding of that scalar —
            # consumers needing a string must coerce (see targets.py)
            out[k] = v
    return out


_instant_lock = threading.Lock()
_last_instant = [0]


def new_instant() -> str:
    """Monotonic Hudi-style instant timestamp (yyyyMMddHHmmssSSS-like)."""
    with _instant_lock:
        t = time.time_ns() // 1_000_000
        if t <= _last_instant[0]:
            t = _last_instant[0] + 1
        _last_instant[0] = t
        return time.strftime("%Y%m%d%H%M%S", time.gmtime(t / 1000)) + f"{t % 1000:03d}"


def _stat_entry(f: DataFileMeta) -> dict:
    return {"path": f.path, "fileId": f.extra.get("fileId", f.path.split("/")[-1]
                                                  .split("_")[0]),
            "numWrites": f.record_count, "fileSizeInBytes": f.size_bytes,
            "partitionPath": "/".join(f"{k}={v}" for k, v in
                                      f.partition_values.items()),
            "partitionValues": {k: v for k, v in f.partition_values.items()},
            "minValues": {k: s.min for k, s in f.column_stats.items()},
            "maxValues": {k: s.max for k, s in f.column_stats.items()},
            "nullCounts": {k: s.nan_count for k, s in f.column_stats.items()},
            "valueCounts": {k: s.count for k, s in f.column_stats.items()},
            "tags": f.extra or {}}


def _file_from_stat(w: dict) -> DataFileMeta:
    cols = set(w.get("minValues", {})) | set(w.get("maxValues", {})) | \
        set(w.get("nullCounts", {}))
    stats = {c: ColumnStats(w.get("minValues", {}).get(c),
                            w.get("maxValues", {}).get(c),
                            w.get("valueCounts", {}).get(c, 0),
                            w.get("nullCounts", {}).get(c, 0)) for c in cols}
    return DataFileMeta(path=w["path"], size_bytes=w["fileSizeInBytes"],
                        record_count=w["numWrites"],
                        partition_values=dict(w.get("partitionValues", {})),
                        column_stats=stats, extra=dict(w.get("tags", {})))


class CommitConflict(RuntimeError):
    pass


class HudiTable:
    format = FORMAT

    def __init__(self, fs, base_path: str):
        self.fs = fs
        self.base = base_path

    # ------------------------------------------------------------- lifecycle
    @classmethod
    def exists(cls, fs, base_path: str) -> bool:
        return fs.exists(join(base_path, HOODIE_DIR, "hoodie.properties"))

    @classmethod
    def create(cls, fs, base_path: str, schema: Schema,
               partition_spec: PartitionSpec = PartitionSpec(),
               properties: dict | None = None) -> "HudiTable":
        t = cls(fs, base_path)
        props = {"hoodie.table.name": (properties or {}).get("name", "table"),
                 "hoodie.table.type": "COPY_ON_WRITE",
                 "hoodie.table.version": "6",
                 "hoodie.table.create.schema": schema_to_avro(schema),
                 "hoodie.table.partition.fields":
                     ",".join(partition_spec.column_names())}
        props.update({k: str(v) for k, v in (properties or {}).items()})
        t._write_props(props, overwrite=False)
        return t

    @classmethod
    def open(cls, fs, base_path: str) -> "HudiTable":
        if not cls.exists(fs, base_path):
            raise FileNotFoundError(f"no hudi table at {base_path}")
        return cls(fs, base_path)

    # -------------------------------------------------------------- timeline
    def _props_path(self) -> str:
        return join(self.base, HOODIE_DIR, "hoodie.properties")

    def _write_props(self, props: dict, overwrite: bool = True) -> None:
        body = "\n".join(f"{k}={v}" for k, v in sorted(props.items())).encode()
        self.fs.write_bytes(self._props_path(), body, overwrite=overwrite)

    def _read_props(self) -> dict:
        out = {}
        for line in self.fs.read_bytes(self._props_path()).decode().splitlines():
            if "=" in line:
                k, v = line.split("=", 1)
                out[k] = v
        return out

    @staticmethod
    def _completed_instants(names: list[str]) -> list[tuple[str, str]]:
        """``.hoodie/`` entries -> [(ts, action)] of COMPLETED instants, in
        timeline order.  The one place that knows which filenames are
        visible commits (requested/inflight markers are not) — shared by
        the full timeline scan and the daemon's ``head_token`` probe so the
        visibility rule cannot drift between them."""
        out = []
        for n in names:
            parts = n.split(".")
            if len(parts) == 2 and parts[0].isdigit() and \
                    parts[1] in ("commit", "replacecommit"):
                out.append((parts[0], parts[1]))
        return sorted(out)

    def _timeline(self) -> list[tuple[str, str]]:
        """Completed instants: [(ts, action)] in timeline order."""
        return self._completed_instants(
            self.fs.list_dir(join(self.base, HOODIE_DIR)))

    def _instant_payload(self, ts: str, action: str) -> dict:
        return json.loads(self.fs.read_bytes(
            join(self.base, HOODIE_DIR, f"{ts}.{action}")))

    def _instant_payloads_many(
            self, instants: list[tuple[str, str]]) -> dict[tuple, dict]:
        """Batched fetch of completed-instant payloads keyed by
        (timestamp, action): the independent GETs go through ``read_many``
        so a timeline replay on a high-RTT object store is pipelined, not
        one RTT per instant."""
        blobs = fetch_many(
            self.fs, [join(self.base, HOODIE_DIR, f"{ts}.{a}")
                      for ts, a in instants])
        return {key: json.loads(raw) for key, raw in zip(instants, blobs)}

    # ----------------------------------------------------------------- state
    def current_version(self) -> str:
        tl = self._timeline()
        return tl[-1][0] if tl else "0"

    def head(self) -> str:
        """The newest completed instant — one timeline listing."""
        return self.current_version()

    def head_token(self) -> str:
        """O(1) change-detection probe: an opaque token that moves iff a
        new instant completed.  One ``list_dir`` of ``.hoodie/`` — only
        *completed* instants count (requested/inflight markers are not yet
        visible commits), so the token moves exactly when the atomic commit
        point lands.  An absent table yields ``""``; an empty-but-created
        timeline yields ``"0"`` (the pre-first-instant version).
        """
        return self.head_probe()[0]

    def head_probe(self) -> tuple[str, list | None]:
        """``(head_token, probe_state)`` in ONE storage request.

        The probe state is the parsed completed-instant timeline, which
        ``replay(probe=...)`` can consume within the same daemon cycle so
        the tail refresh never re-lists ``.hoodie/`` (instant timestamps
        are not dense, so unlike delta the listing itself is the memo).
        """
        names = self.fs.list_dir(join(self.base, HOODIE_DIR))
        if not names:
            return "", None
        completed = self._completed_instants(names)
        return (completed[-1][0] if completed else "0"), completed

    def versions(self) -> list[str]:
        return [ts for ts, _ in self._timeline()]

    def snapshot(self, version: str | None = None) -> TableState:
        props = self._read_props()
        target = version if version is not None else self.current_version()
        files: dict[str, DataFileMeta] = {}
        schema = schema_from_avro(props["hoodie.table.create.schema"])
        ts_ms = 0
        upto = [(ts, a) for ts, a in self._timeline() if ts <= target]
        payloads = self._instant_payloads_many(upto)
        for ts, action in upto:
            payload = payloads[(ts, action)]
            for paths in payload.get("partitionToReplacedFilePaths", {}).values():
                for p in paths:
                    files.pop(p, None)
            for stats in payload.get("partitionToWriteStats", {}).values():
                for w in stats:
                    f = _file_from_stat(w)
                    files[f.path] = f
            if "schema" in payload.get("extraMetadata", {}):
                schema = schema_from_avro(payload["extraMetadata"]["schema"])
            ts_ms = max(ts_ms, payload.get("timestampMs", 0))
        pf = props.get("hoodie.table.partition.fields", "")
        spec = PartitionSpec([c for c in pf.split(",") if c])
        user_props = {k: v for k, v in props.items()
                      if not k.startswith("hoodie.")}
        return TableState(FORMAT, target, ts_ms, schema, spec, files, user_props)

    def changes(self, version: str) -> tuple[list[DataFileMeta], list[str], str, dict]:
        for ts, action in self._timeline():
            if ts == version:
                payload = self._instant_payload(ts, action)
                adds = [_file_from_stat(w) for stats in
                        payload.get("partitionToWriteStats", {}).values()
                        for w in stats]
                removes = [p for paths in
                           payload.get("partitionToReplacedFilePaths", {}).values()
                           for p in paths]
                return adds, removes, payload.get("operationType", "unknown"), \
                    decode_extra_metadata(payload.get("extraMetadata", {}))
        raise KeyError(f"instant {version} not found")

    def replay(self, since: str | None = None,
               seed: CommitEntry | None = None,
               probe: list | None = None
               ) -> tuple[TableState | None, list[CommitEntry]]:
        """Single-pass scan of the timeline -> per-instant entries.

        Each completed instant payload is read exactly once; the base state
        is the empty pre-first-instant table (version "0").

        With ``since`` set, only instants AFTER that timestamp are read
        (tail-only refresh, ``base`` is ``None``); ``seed`` — the caller's
        ``CommitEntry`` for ``since`` — supplies the as-of schema, so the
        tail costs O(new instants) reads.  Raises ``KeyError`` if ``since``
        is not a completed instant.

        ``probe`` — the completed-instant timeline from a same-cycle
        ``head_probe()`` — replaces the ``.hoodie/`` listing, so a hinted
        refresh never re-discovers the head it was just told about.
        """
        props = self._read_props()
        schema = schema_from_avro(props["hoodie.table.create.schema"])
        pf = props.get("hoodie.table.partition.fields", "")
        spec = PartitionSpec([c for c in pf.split(",") if c])
        user_props = {k: v for k, v in props.items()
                      if not k.startswith("hoodie.")}
        timeline = list(probe) if probe is not None else self._timeline()
        base: TableState | None = TableState(FORMAT, "0", 0, schema, spec, {},
                                             user_props)
        ts_ms = 0
        if since is not None and since != "0":
            if since not in {ts for ts, _ in timeline}:
                raise KeyError(f"instant {since} not in hudi timeline")
            if seed is None:   # no as-of schema to resume from
                raise KeyError(f"no seed state for instant {since}")
            timeline = [(ts, a) for ts, a in timeline if ts > since]
            base = None
            schema = seed.schema
            ts_ms = seed.timestamp_ms
        elif since is not None:
            base = None
        payloads = self._instant_payloads_many(timeline)
        entries = []
        for ts, action in timeline:
            payload = payloads[(ts, action)]
            adds = [_file_from_stat(w) for stats in
                    payload.get("partitionToWriteStats", {}).values()
                    for w in stats]
            removes = [p for paths in
                       payload.get("partitionToReplacedFilePaths", {}).values()
                       for p in paths]
            if "schema" in payload.get("extraMetadata", {}):
                schema = schema_from_avro(payload["extraMetadata"]["schema"])
            ts_ms = max(ts_ms, payload.get("timestampMs", 0))
            entries.append(CommitEntry(
                ts, ts_ms, payload.get("operationType", "unknown"),
                tuple(adds), tuple(removes), schema, spec, dict(user_props),
                decode_extra_metadata(payload.get("extraMetadata", {}))))
        return base, entries

    def properties(self) -> dict:
        props = self._read_props()
        return {k: v for k, v in props.items() if not k.startswith("hoodie.")}

    def table_properties(self) -> dict:
        """The full ``hoodie.properties`` map, ``hoodie.*`` keys included
        (``properties()`` filters those out) — the public accessor for
        table-level facts like ``hoodie.table.create.schema``."""
        return dict(self._read_props())

    def latest_extra_metadata(self) -> dict:
        tl = self._timeline()
        if not tl:
            return {}
        return decode_extra_metadata(
            self._instant_payload(*tl[-1]).get("extraMetadata", {}))

    # --------------------------------------------------------------- commits
    def commit(self, adds: list[DataFileMeta] = (), removes: list[str] = (), *,
               schema: Schema | None = None, properties: dict | None = None,
               operation: str = "upsert", extra_meta: dict | None = None,
               max_retries: int = 5) -> str:
        action = "replacecommit" if removes else "commit"
        for _ in range(max_retries):
            instant = new_instant()
            hdir = join(self.base, HOODIE_DIR)
            try:
                # three-phase instant state machine
                self.fs.write_bytes(join(hdir, f"{instant}.{action}.requested"), b"{}")
            except PutIfAbsentError:
                continue
            self.fs.write_bytes(join(hdir, f"{instant}.{action}.inflight"), b"{}",
                                overwrite=True)
            p2ws: dict[str, list] = {}
            for f in adds:
                part = "/".join(f"{k}={v}" for k, v in f.partition_values.items())
                p2ws.setdefault(part, []).append(_stat_entry(f))
            p2rf: dict[str, list] = {}
            for p in removes:
                p2rf.setdefault(p.rsplit("/", 1)[0] if "/" in p else "", []) \
                    .append(p)
            cur_schema = schema if schema is not None else self.snapshot().schema
            extra = {"schema": schema_to_avro(cur_schema)}
            if extra_meta:
                extra.update(extra_meta)
            payload = {"partitionToWriteStats": p2ws,
                       "operationType": operation.upper(),
                       "timestampMs": time.time_ns() // 1_000_000,
                       "extraMetadata": encode_extra_metadata(extra)}
            if removes:
                payload["partitionToReplacedFilePaths"] = p2rf
            try:
                self.fs.write_bytes(join(hdir, f"{instant}.{action}"),
                                    json.dumps(payload).encode())
            except PutIfAbsentError:
                continue
            if properties:
                props = self._read_props()
                props.update({k: str(v) for k, v in properties.items()})
                self._write_props(props)
            return instant
        raise CommitConflict("hudi commit retries exhausted")

    # ----------------------------------------------------------- transaction
    def transaction(self, *, schema: Schema | None = None,
                    props: dict | None = None) -> "HudiTransaction":
        """Multi-commit transaction: read the properties + latest instant
        ONCE, keep the schema and table properties in memory, and buffer
        each instant — the requested/inflight markers of the whole chain
        are staged and flushed in one pipelined ``write_many`` round at
        ``flush()``/``close()``; only the completed-instant puts (the
        atomic commit points) stay serial, and the properties file is
        rewritten once per flush instead of once per commit.  ``props`` —
        an already-read ``hoodie.properties`` map — makes begin cost zero
        requests."""
        return HudiTransaction(self, schema=schema, props=props)


class HudiTransaction:
    """Buffered writer state for an N-instant sync unit (single writer).

    Begin cost: one properties read (+ one latest-instant read when the
    schema is not seeded by the caller).  ``commit()`` only buffers: the
    instant timestamp is allocated eagerly (monotonic), the payload is
    materialized in memory, and nothing touches storage until ``flush()``,
    which (1) stages every pending instant's requested + inflight markers
    in one pipelined ``write_many`` round, (2) puts the completed instants
    serially — each a put-if-absent, the atomic commit point — and
    (3) rewrites ``hoodie.properties`` once if any commit changed it.

    A crash anywhere leaves a valid prefix: markers are invisible to
    readers (only *completed* instants are commits), and completed instants
    land oldest-first.  A completed-instant collision (foreign writer owns
    the timestamp) re-allocates that instant AND every later pending one,
    keeping timeline order, then re-flushes the affected markers.
    """

    def __init__(self, table: HudiTable, *, schema: Schema | None = None,
                 props: dict | None = None):
        self.t = table
        self._props = dict(props) if props is not None else table._read_props()
        self._props_dirty = False
        if schema is not None:
            self._schema = schema
        else:
            em = table.latest_extra_metadata()
            self._schema = schema_from_avro(
                em.get("schema") or self._props["hoodie.table.create.schema"])
        self._pending: list[dict] = []   # materialized, not yet flushed
        self._max_retries = 5

    def commit(self, adds: list[DataFileMeta] = (), removes: list[str] = (), *,
               schema: Schema | None = None, properties: dict | None = None,
               operation: str = "upsert", extra_meta: dict | None = None,
               max_retries: int = 5) -> str:
        """Buffer one instant; it lands at the next ``flush()``/``close()``.
        Returns the allocated instant timestamp (re-allocated only if a
        foreign writer collides on it at flush time)."""
        self._max_retries = max(self._max_retries, max_retries)
        action = "replacecommit" if removes else "commit"
        cur_schema = schema if schema is not None else self._schema
        p2ws: dict[str, list] = {}
        for f in adds:
            part = "/".join(f"{k}={v}" for k, v in f.partition_values.items())
            p2ws.setdefault(part, []).append(_stat_entry(f))
        p2rf: dict[str, list] = {}
        for p in removes:
            p2rf.setdefault(p.rsplit("/", 1)[0] if "/" in p else "", []) \
                .append(p)
        extra = {"schema": schema_to_avro(cur_schema)}
        if extra_meta:
            extra.update(extra_meta)
        payload = {"partitionToWriteStats": p2ws,
                   "operationType": operation.upper(),
                   "timestampMs": time.time_ns() // 1_000_000,
                   "extraMetadata": encode_extra_metadata(extra)}
        if removes:
            payload["partitionToReplacedFilePaths"] = p2rf
        self._schema = cur_schema
        if properties:
            self._props.update({k: str(v) for k, v in properties.items()})
            self._props_dirty = True
        instant = new_instant()
        self._pending.append({"instant": instant, "action": action,
                              "payload": json.dumps(payload).encode()})
        return instant

    # ---------------------------------------------------------------- flush
    def flush(self) -> None:
        """Land every buffered instant (see class docstring for the order)."""
        hdir = join(self.t.base, HOODIE_DIR)
        for _ in range(self._max_retries):
            if not self._pending:
                break
            # one pipelined round for ALL pending markers (idempotent:
            # marker content is constant, so restaging after a collision
            # re-allocation is safe with overwrite)
            staged = []
            for p in self._pending:
                stem = join(hdir, f"{p['instant']}.{p['action']}")
                staged.append((f"{stem}.requested", b"{}"))
                staged.append((f"{stem}.inflight", b"{}"))
            flush_many(self.t.fs, staged, overwrite=True)
            collided = False
            while self._pending:
                p = self._pending[0]
                try:
                    self.t.fs.write_bytes(
                        join(hdir, f"{p['instant']}.{p['action']}"),
                        p["payload"])
                except PutIfAbsentError:
                    # a foreign writer owns this timestamp: re-allocate it
                    # and every later pending instant (monotonic allocation
                    # keeps timeline order), then restage their markers
                    for q in self._pending:
                        q["instant"] = new_instant()
                    collided = True
                    break
                self._pending.pop(0)
            if not collided:
                break
        else:
            raise CommitConflict("hudi transactional commit retries exhausted")
        if self._props_dirty:
            self.t._write_props(self._props)
            self._props_dirty = False

    def close(self) -> None:
        self.flush()
