"""Deterministic, resumable data loader over LST tables.

* Reads through ANY format's connector (the engine-flexibility story: the
  same corpus written once is consumed by loaders opening it as Delta,
  Iceberg, or Hudi after an XTable sync).
* Deterministic order: files sorted by path, rows in file order; the loader
  state is a single global row cursor — committed alongside the model
  checkpoint for exact-resume after preemption.
* Straggler mitigation: a background prefetch thread keeps a bounded queue
  of ready batches per host; slow storage reads overlap compute.
* Multi-host striping: host h of H takes rows where (row_idx % H) == h.
"""

from __future__ import annotations

import queue
import threading

import numpy as np

from repro.lst.table import LakeTable


class LakeDataLoader:
    def __init__(self, fs, base_path: str, fmt: str, *, batch_size: int,
                 seq_len: int, host_id: int = 0, n_hosts: int = 1,
                 start_row: int = 0, prefetch: int = 2, loop: bool = True):
        self.table = LakeTable.open(fs, base_path, fmt)
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.row = start_row
        self.loop = loop
        self._files = sorted(self.table.state().files.values(),
                             key=lambda f: f.path)
        self._rows_per_file = [f.record_count for f in self._files]
        self.total_rows = sum(self._rows_per_file)
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # --------------------------------------------------------------- cursor
    def state_dict(self) -> dict:
        return {"row": self.row}

    def load_state_dict(self, d: dict) -> None:
        self.row = int(d["row"])

    def _fetch_row(self, idx: int) -> np.ndarray:
        from repro.lst.chunkfile import read_chunk
        idx %= self.total_rows
        for f, n in zip(self._files, self._rows_per_file):
            if idx < n:
                cols, _ = read_chunk(self.table.fs, self.table.base, f.path)
                return cols["tokens"][idx]
            idx -= n
        raise IndexError(idx)

    # ---------------------------------------------------------------- batch
    def next_batch(self) -> dict:
        """Synchronous batch (deterministic; used by tests)."""
        rows = []
        while len(rows) < self.batch_size:
            if not self.loop and self.row >= self.total_rows:
                raise StopIteration
            if self.row % self.n_hosts == self.host_id:
                rows.append(self._fetch_row(self.row))
            self.row += 1
        toks = np.stack(rows)[:, :self.seq_len + 1].astype(np.int32)
        return {"inputs": toks[:, :-1], "targets": toks[:, 1:]}

    # ------------------------------------------------------------- prefetch
    def _producer(self) -> None:
        # file-level cache so the producer isn't re-reading chunks per row
        cache: dict[str, np.ndarray] = {}
        from repro.lst.chunkfile import read_chunk
        while not self._stop.is_set():
            rows = []
            while len(rows) < self.batch_size:
                if not self.loop and self.row >= self.total_rows:
                    self._q.put(None)
                    return
                if self.row % self.n_hosts == self.host_id:
                    idx = self.row % self.total_rows
                    for f, n in zip(self._files, self._rows_per_file):
                        if idx < n:
                            if f.path not in cache:
                                cols, _ = read_chunk(self.table.fs,
                                                     self.table.base, f.path)
                                cache[f.path] = cols["tokens"]
                                if len(cache) > 8:
                                    cache.pop(next(iter(cache)))
                            rows.append(cache[f.path][idx])
                            break
                        idx -= n
                self.row += 1
            toks = np.stack(rows)[:, :self.seq_len + 1].astype(np.int32)
            batch = {"inputs": toks[:, :-1], "targets": toks[:, 1:],
                     "cursor": self.row}
            self._q.put(batch)

    def start(self) -> "LakeDataLoader":
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()
        return self

    def get(self, timeout: float = 60.0) -> dict:
        b = self._q.get(timeout=timeout)
        if b is None:
            raise StopIteration
        return b

    def stop(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
