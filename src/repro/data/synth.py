"""Synthetic token corpus written into an LST table (Scenario-1 style import).

Rows are packed sequences of ``pack_len`` int32 tokens, partitioned by
``shard`` so multi-host loaders stripe cleanly. The generator is a small
in-vocab Markov chain so a model can actually *learn* structure (loss drops
measurably in the end-to-end example, unlike uniform noise).
"""

from __future__ import annotations

import numpy as np

from repro.lst.schema import Field, PartitionSpec, Schema
from repro.lst.table import LakeTable

CORPUS_SCHEMA = Schema([
    Field("tokens", "int32"), Field("doc_id", "int64"), Field("shard", "string"),
])


def _markov_tokens(rng: np.random.Generator, n: int, vocab: int) -> np.ndarray:
    """Tokens with learnable bigram structure: t+1 ~ f(t) + noise."""
    base = rng.integers(0, vocab, size=n, dtype=np.int32)
    out = np.empty(n, np.int32)
    out[0] = base[0]
    # deterministic successor for 85% of steps
    succ = (np.arange(vocab, dtype=np.int64) * 31 + 7) % vocab
    use_succ = rng.random(n) < 0.85
    for i in range(1, n):
        out[i] = succ[out[i - 1]] if use_succ[i] else base[i]
    return out


def write_synth_corpus(fs, base_path: str, *, fmt: str = "delta",
                       n_docs: int = 64, pack_len: int = 129,
                       vocab: int = 256, n_shards: int = 4,
                       seed: int = 0) -> LakeTable:
    rng = np.random.default_rng(seed)
    table = LakeTable.create(fs, base_path, CORPUS_SCHEMA, fmt,
                             PartitionSpec(["shard"]))
    toks = np.stack([_markov_tokens(rng, pack_len, vocab)
                     for _ in range(n_docs)])
    table.append({
        "tokens": toks.astype(np.int32),
        "doc_id": np.arange(n_docs, dtype=np.int64),
        "shard": np.array([f"s{i % n_shards}" for i in range(n_docs)]),
    })
    return table
