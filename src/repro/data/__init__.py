from repro.data.pipeline import LakeDataLoader
from repro.data.synth import write_synth_corpus

__all__ = ["LakeDataLoader", "write_synth_corpus"]
