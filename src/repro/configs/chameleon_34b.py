"""chameleon-34b: 48L, GQA 64H/8KV, early-fusion VQ image tokens in the
shared vocab, qk-norm (training stability fix from the paper), vocab 65536.
The VQ-VAE image tokenizer is a STUB: input_specs() provides token ids that
already include image codebook entries. [arXiv:2405.09818; unverified]"""
from repro.configs.registry import _shrink_common
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    d_model=8192, n_layers=48, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=65536,
    cycle=(LayerSpec(kind="attn"),),
    mlp_act="silu", gated=True, qk_norm=True,
)


def smoke():
    return _shrink_common(CONFIG)
