"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""

from __future__ import annotations

import importlib
from dataclasses import replace

from repro.models.config import ModelConfig

ARCH_MODULES = {
    "gemma2-27b": "gemma2_27b",
    "stablelm-3b": "stablelm_3b",
    "yi-9b": "yi_9b",
    "starcoder2-15b": "starcoder2_15b",
    "dbrx-132b": "dbrx_132b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "chameleon-34b": "chameleon_34b",
    "whisper-small": "whisper_small",
    "mamba2-2.7b": "mamba2_2_7b",
}

ARCHS = tuple(ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch]}")
    return mod.CONFIG


def list_archs() -> list[str]:
    return list(ARCHS)


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config: tiny dims, same cycle structure."""
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch]}")
    return mod.smoke()


def _shrink_common(cfg: ModelConfig, **kw) -> ModelConfig:
    base = dict(
        d_model=64, n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2), head_dim=16,
        d_ff=128 if cfg.d_ff else 0, vocab_size=256,
        n_layers=2 * len(cfg.cycle), remat="none", attn_q_blocks=2)
    base.update(kw)
    return replace(cfg, **base)
