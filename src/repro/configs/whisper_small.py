"""whisper-small: enc-dec 12L+12L, d_model 768, 12H, d_ff 3072, vocab 51865.
Conv audio frontend is a STUB: input_specs() provides precomputed
(batch, 1500, d_model) frame embeddings. RoPE replaces Whisper's absolute
positions (TPU-native backbone; deviation noted in DESIGN.md).
[arXiv:2212.04356; unverified]"""
from repro.configs.registry import _shrink_common
from repro.models.config import EncoderConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    d_model=768, n_layers=12, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51865,
    cycle=(LayerSpec(kind="attn", cross_attn=True),),
    mlp_act="gelu", gated=False, norm_type="ln",
    encoder=EncoderConfig(n_layers=12, n_frames=1500),
    tie_embeddings=True,
)


def smoke():
    from dataclasses import replace
    cfg = _shrink_common(CONFIG)
    return replace(cfg, encoder=EncoderConfig(n_layers=2, n_frames=16))
