"""stablelm-3b: 32L, 32H (kv=32, i.e. MHA), d_ff 6912, vocab 50304.
[hf:stabilityai/stablelm-2-1_6b family; unverified]"""
from repro.configs.registry import _shrink_common
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    d_model=2560, n_layers=32, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=6912, vocab_size=50304,
    cycle=(LayerSpec(kind="attn"),),
    mlp_act="silu", gated=True,
)


def smoke():
    return _shrink_common(CONFIG, n_kv_heads=4)
