from repro.configs.registry import ARCHS, get_config, list_archs, smoke_config

__all__ = ["ARCHS", "get_config", "list_archs", "smoke_config"]
