"""jamba-v0.1-52b: 32L hybrid, attn:mamba 1:7 interleave (attn at offset 4
of each 8-layer period), MoE 16e top-2 on every other layer, vocab 65536.
Mamba-1-style mixer = SSD with head_dim 1 (see models/ssm.py).
[arXiv:2403.19887; hf]"""
from dataclasses import replace

from repro.configs.registry import _shrink_common
from repro.models.config import LayerSpec, ModelConfig, SSMConfig

_D_INNER = 8192

CYCLE = tuple(
    LayerSpec(kind=("attn" if i == 4 else "ssm"), moe=(i % 2 == 1), mlp=True)
    for i in range(8))

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    d_model=4096, n_layers=32, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    cycle=CYCLE,
    mlp_act="silu", gated=True,
    n_experts=16, top_k=2,
    ssm=SSMConfig(d_inner=_D_INNER, d_state=16, n_heads=_D_INNER, head_dim=1,
                  n_groups=1, conv_width=4, chunk=16),
)


def smoke():
    cfg = _shrink_common(CONFIG, n_experts=4, top_k=2, n_layers=8)
    return replace(cfg, ssm=SSMConfig(d_inner=128, d_state=8, n_heads=128,
                                      head_dim=1, n_groups=1, conv_width=4,
                                      chunk=16))
