"""starcoder2-15b: 40L, GQA 48H/4KV, RoPE, vocab 49152.
[arXiv:2402.19173; hf]"""
from repro.configs.registry import _shrink_common
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    d_model=6144, n_layers=40, n_heads=48, n_kv_heads=4, head_dim=128,
    d_ff=24576, vocab_size=49152,
    cycle=(LayerSpec(kind="attn"),),
    mlp_act="gelu", gated=False, norm_type="ln", rope_theta=100_000.0,
)


def smoke():
    return _shrink_common(CONFIG, n_kv_heads=2)
