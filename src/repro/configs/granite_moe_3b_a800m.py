"""granite-moe-3b-a800m: 32L, GQA 24H/8KV, MoE 40 experts top-8, d_ff 512
per expert, vocab 49155. [hf:ibm-granite family; hf]"""
from repro.configs.registry import _shrink_common
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    d_model=1536, n_layers=32, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    cycle=(LayerSpec(kind="attn", moe=True),),
    mlp_act="silu", gated=True,
    n_experts=40, top_k=8,
)


def smoke():
    return _shrink_common(CONFIG, n_experts=8, top_k=2)
