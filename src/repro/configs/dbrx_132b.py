"""dbrx-132b: 40L, GQA 48H/8KV, fine-grained MoE 16 experts top-4,
d_ff 10752 per expert, vocab 100352. [hf:databricks/dbrx-base; unverified]"""
from repro.configs.registry import _shrink_common
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    d_model=6144, n_layers=40, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100352,
    cycle=(LayerSpec(kind="attn", moe=True),),
    mlp_act="silu", gated=True, rope_theta=500_000.0,
    n_experts=16, top_k=4,
)


def smoke():
    return _shrink_common(CONFIG, n_experts=4, top_k=2)
