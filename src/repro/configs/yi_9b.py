"""yi-9b: 48L, GQA 32H/4KV, llama-arch SwiGLU, vocab 64000.
[arXiv:2403.04652; hf]"""
from repro.configs.registry import _shrink_common
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="yi-9b", family="dense",
    d_model=4096, n_layers=48, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab_size=64000,
    cycle=(LayerSpec(kind="attn"),),
    mlp_act="silu", gated=True, rope_theta=5_000_000.0,
)


def smoke():
    return _shrink_common(CONFIG, n_kv_heads=2)
