"""mamba2-2.7b: 64L attention-free SSD blocks, d_model 2560, d_inner 5120,
ssm_state 128, head_dim 64 (80 heads), vocab 50280. [arXiv:2405.21060]"""
from dataclasses import replace

from repro.configs.registry import _shrink_common
from repro.models.config import LayerSpec, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    d_model=2560, n_layers=64, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    cycle=(LayerSpec(kind="ssm", mlp=False),),
    ssm=SSMConfig(d_inner=5120, d_state=128, n_heads=80, head_dim=64,
                  n_groups=1, conv_width=4, chunk=256),
    tie_embeddings=True,
)


def smoke():
    cfg = _shrink_common(CONFIG, n_heads=0, n_kv_heads=0, head_dim=0, d_ff=0)
    return replace(cfg, ssm=SSMConfig(d_inner=128, d_state=16, n_heads=8,
                                      head_dim=16, n_groups=1, conv_width=4,
                                      chunk=16))
