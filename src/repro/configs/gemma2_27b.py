"""gemma2-27b: 46L, GQA 32H/16KV, local(4096)+global alternating, logit
softcaps, tied embeddings. [arXiv:2408.00118; hf]"""
from repro.configs.registry import _shrink_common
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    d_model=4608, n_layers=46, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000,
    cycle=(LayerSpec(kind="attn", window=4096),      # local sliding
           LayerSpec(kind="attn", window=0)),        # global
    mlp_act="gelu", gated=True,
    attn_softcap=50.0, final_softcap=30.0,
    post_block_norm=True, tie_embeddings=True, embed_scale=True,
)


def smoke() -> ModelConfig:
    return _shrink_common(CONFIG, d_ff=128)
