import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede every other import — jax locks the device
count at first init, and the production meshes need 512 placeholder devices.

Per cell this proves:
* the sharding config is coherent (SPMD partitioning succeeds),
* the memory footprint fits (``memory_analysis`` per device),
* and extracts the roofline raw terms (FLOPs / HBM bytes / collective bytes)
  via the scan-aware HLO walker (``hlo_analysis``).

Results land in ``experiments/dryrun/<arch>__<shape>__<mesh>.json``.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun [--arch ID|all]
        [--shape NAME|all] [--mesh single|multi|both] [--out DIR]
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCHS                                   # noqa: E402
from repro.launch.cells import build_cell, is_applicable          # noqa: E402
from repro.launch.hlo_analysis import analyze                     # noqa: E402
from repro.launch.mesh import make_production_mesh, pod_size      # noqa: E402
from repro.models.config import SHAPE_CELLS                       # noqa: E402


def _named_shardings(mesh, tree):
    """PartitionSpec / None pytree -> NamedSharding pytree (old-jax jit)."""
    from jax.sharding import NamedSharding, PartitionSpec

    def conv(x):
        if x is None:
            return NamedSharding(mesh, PartitionSpec())
        if isinstance(x, PartitionSpec):
            return NamedSharding(mesh, x)
        return x

    return jax.tree.map(
        conv, tree,
        is_leaf=lambda x: x is None or isinstance(x, PartitionSpec))


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             grad_accum: int = 1, save: bool = True,
             overrides=None) -> dict:
    mesh_name = "pod2" if multi_pod else "pod1"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "ok": False}
    ok, why = is_applicable(arch, shape)
    if not ok:
        rec.update({"skipped": True, "reason": why})
        if save:
            _save(out_dir, rec)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        cb = build_cell(arch, shape, mesh, grad_accum=grad_accum)
        if overrides:
            cb = overrides(cb)
        # jax >= 0.6 accepts bare PartitionSpecs under jax.set_mesh; older
        # jax wants concrete NamedShardings and enters the Mesh object
        # itself as the context manager
        set_mesh = getattr(jax, "set_mesh", None)
        if set_mesh is None:
            in_sh = _named_shardings(mesh, cb.in_shardings)
            out_sh = _named_shardings(mesh, cb.out_shardings)
            mesh_cm = mesh
        else:
            in_sh, out_sh = cb.in_shardings, cb.out_shardings
            mesh_cm = set_mesh(mesh)
        with mesh_cm:
            jitted = jax.jit(cb.fn, in_shardings=in_sh,
                             out_shardings=out_sh,
                             donate_argnums=cb.donate_argnums)
            lowered = jitted.lower(*cb.args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, list):     # newer jax returns [per-device dict]
            ca = ca[0] if ca else {}
        hlo = analyze(compiled.as_text(), pod_size(mesh))
        rec.update({
            "ok": True,
            "step": cb.step_name,
            "n_params": cb.n_params,
            "n_active_params": cb.n_active_params,
            "attn_hbm_bytes": cb.attn_hbm_bytes,
            "tokens_per_step": cb.cell.global_batch *
            (cb.cell.seq_len if cb.cell.step != "decode" else 1),
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_bytes_est": mem.argument_size_in_bytes +
                mem.temp_size_in_bytes - mem.alias_size_in_bytes,
            },
            "xla_cost": {"flops": ca.get("flops"),
                         "bytes": ca.get("bytes accessed")},
            "hlo": hlo,
        })
    except Exception as e:  # record the failure, keep sweeping
        rec.update({"error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc()[-2000:]})
    if save:
        _save(out_dir, rec)
    return rec


def _save(out_dir: str, rec: dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def summarize(rec: dict) -> str:
    if rec.get("skipped"):
        return (f"{rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:5s} "
                f"SKIP ({rec['reason'][:40]}...)")
    if not rec.get("ok"):
        return (f"{rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:5s} "
                f"FAIL {rec.get('error', '?')[:80]}")
    m = rec["memory"]
    h = rec["hlo"]
    return (f"{rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:5s} OK "
            f"compile={rec['compile_s']:6.1f}s "
            f"mem/dev={(m['peak_bytes_est']) / 2**30:7.2f}GiB "
            f"flops/dev={h['flops']:.3e} hbm={h['hbm_bytes']:.3e} "
            f"ici={h['coll_ici_bytes']:.3e} dcn={h['coll_dcn_bytes']:.3e}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--grad-accum", type=int, default=1)
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = [c.name for c in SHAPE_CELLS] if args.shape == "all" \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                rec = run_cell(arch, shape, multi, args.out,
                               grad_accum=args.grad_accum)
                print(summarize(rec), flush=True)
                if not rec.get("ok") and not rec.get("skipped"):
                    n_fail += 1
    print(f"\ndry-run complete, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
