"""Production meshes.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16) — the ``pod``
axis is pure data parallelism whose gradient all-reduce crosses DCN once per
step; everything else stays inside a pod's ICI.

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (tests / examples): 1D data mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def pod_size(mesh) -> int | None:
    """Devices per pod (for DCN/ICI classification); None if single pod."""
    if "pod" in mesh.axis_names:
        i = mesh.axis_names.index("pod")
        per_pod = mesh.devices.size // mesh.devices.shape[i]
        return per_pod
    return None
