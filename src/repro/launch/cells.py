"""(architecture x input-shape) cells: step functions + input specs + shardings.

``input_specs`` returns weak-type-correct ``ShapeDtypeStruct`` stand-ins for
every model input — nothing is allocated; the dry-run lowers directly from
these.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models.config import ShapeCell, get_shape_cell
from repro.models.model import Model
from repro.models.param import template_shapes
from repro.optim import AdamWConfig
from repro.parallel.sharding import Sharder
from repro.train.loop import make_train_step, train_state_template

f32 = jnp.float32

# long_500k needs sub-quadratic attention; these archs are pure full
# attention so the cell is skipped (documented in DESIGN.md §Arch-applicability)
LONG_CONTEXT_ARCHS = ("gemma2-27b", "jamba-v0.1-52b", "mamba2-2.7b")


def is_applicable(arch: str, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, ("pure full-attention architecture: 512k KV decode "
                       "requires sub-quadratic attention (see DESIGN.md)")
    return True, ""


def attn_intermediate_bytes(cfg, cell, sh: Sharder) -> float:
    """Per-device HBM bytes of materialized attention intermediates on the
    XLA path (f32 scores write+read by softmax, bf16 probs write+read by the
    PV matmul = 12 B per visible (q,k) pair; x3 on the train path for
    recompute + backward). The Pallas flash kernel keeps these in VMEM —
    subtracting this models the kernel-path roofline."""
    specs = cfg.layer_specs()
    attn_layers = [s for s in specs if s.kind == "attn"]
    if not attn_layers or cfg.n_heads == 0:
        return 0.0
    def _div(spec) -> int:
        d = 1
        for ax in list(spec):
            for a in (ax if isinstance(ax, tuple) else ((ax,) if ax else ())):
                d *= sh.mesh_axes.get(a, 1)
        return max(d, 1)

    h_loc = cfg.n_heads // _div(sh.resolve(("heads",), (cfg.n_heads,)))
    b_loc = max(1, cell.global_batch //
                _div(sh.resolve(("batch",), (cell.global_batch,))))

    total_pairs = 0.0
    s = cell.seq_len
    for spec in attn_layers:
        if cell.step == "decode":
            klen = min(spec.window, s) if spec.window else s
            pairs = float(klen)                     # one query token
        else:
            nq = max(1, min(cfg.attn_q_blocks, s))
            qb = s // nq
            pairs = 0.0
            for i in range(nq):
                q_lo = i * qb
                k_hi = min(q_lo + qb, s)
                k_lo = max(0, q_lo - spec.window) if spec.window else 0
                pairs += qb * (k_hi - k_lo)
        total_pairs += pairs * b_loc * h_loc
    mult = 3.0 if cell.step == "train" else 1.0
    return total_pairs * 12.0 * mult


@dataclass
class CellBuild:
    arch: str
    shape: str
    step_name: str
    fn: object
    args: tuple
    in_shardings: tuple
    out_shardings: object
    donate_argnums: tuple
    model: Model
    cell: ShapeCell
    n_params: int
    n_active_params: int
    attn_hbm_bytes: float = 0.0   # XLA-path attention intermediates/device


def _counted_params(model: Model) -> tuple[int, int]:
    """(total, active) parameter counts; active discounts unrouted experts."""
    import numpy as np
    from repro.models.param import is_spec
    cfg = model.cfg
    total = expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            model.param_template(), is_leaf=is_spec)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        if any(k in ("w_in", "w_gate", "w_out") for k in keys) and \
                cfg.n_experts:
            expert += n
    active = total - int(expert * (1 - cfg.top_k / max(cfg.n_experts, 1)))
    return total, active


# sub-1B models: TP over 16 ways is counterproductive — prefer widening data
# parallelism onto the model axis (params are small enough to replicate
# across it; activations shard fully).
_PURE_DP_ARCHS = ("whisper-small",)


def make_cell_sharder(mesh, arch: str, shape: str) -> Sharder:
    overrides = {}
    if shape == "long_500k":
        overrides["kvseq"] = (("data",),)   # sequence-parallel 512k KV
    if arch in _PURE_DP_ARCHS:
        overrides.update({
            "batch": (("pod", "data", "model"), ("pod", "data")),
            "embed": (),                     # replicate the small params
            "act_seq": (),
        })
    return Sharder.for_mesh(mesh, overrides)


def _arch_cfg(arch: str, shape: str):
    cfg = get_config(arch)
    if shape == "long_500k":
        cfg = cfg.with_updates(long_context_seq_shard=True)
    return cfg


def _token_specs(sh: Sharder, b: int, s: int):
    spec = jax.ShapeDtypeStruct((b, s), jnp.int32)
    pspec = sh.resolve(("batch", "seq"), (b, s))
    return spec, pspec


def build_cell(arch: str, shape: str, mesh, *,
               grad_accum: int = 1) -> CellBuild:
    ok, why = is_applicable(arch, shape)
    if not ok:
        raise ValueError(f"{arch} x {shape} skipped: {why}")
    cell = get_shape_cell(shape)
    cfg = _arch_cfg(arch, shape)
    sh = make_cell_sharder(mesh, arch, shape)
    model = Model(cfg, sh)
    n_params, n_active = _counted_params(model)
    attn_hbm = attn_intermediate_bytes(cfg, cell, sh)

    params_shapes = template_shapes(model.param_template())
    params_pspecs = sh.template_pspecs(model.param_template())
    b, s = cell.global_batch, cell.seq_len

    if cell.step == "train":
        ptpl, opt_shapes = train_state_template(model)
        opt_pspecs = {
            "step": P(),
            "m": params_pspecs, "v": params_pspecs, "master": params_pspecs,
        }
        tok, tok_p = _token_specs(sh, b, s)
        batch_shapes = {"inputs": tok, "targets": tok}
        batch_pspecs = {"inputs": tok_p, "targets": tok_p}
        if cfg.encoder:
            eshape = (b, cfg.encoder.n_frames, cfg.d_model)
            batch_shapes["enc_embeds"] = jax.ShapeDtypeStruct(
                eshape, jnp.dtype(cfg.dtype))
            batch_pspecs["enc_embeds"] = sh.resolve(
                ("batch", "frames", None), eshape)
        step = make_train_step(model, AdamWConfig(), grad_accum=grad_accum,
                               grad_pspecs=params_pspecs)
        metrics_p = {"loss": P(), "grad_norm": P(), "lr": P()}
        return CellBuild(
            arch, shape, "train_step", step,
            (params_shapes, opt_shapes, batch_shapes),
            (params_pspecs, opt_pspecs, batch_pspecs),
            (params_pspecs, opt_pspecs, metrics_p),
            (0, 1), model, cell, n_params, n_active, attn_hbm)

    if cell.step == "prefill":
        tok, tok_p = _token_specs(sh, b, s)
        cache_tpl = model.cache_template(b, s)
        cache_pspecs = sh.template_pspecs(cache_tpl)
        logits_p = sh.resolve(("batch", "vocab"), (b, cfg.vocab_size))
        args = [params_shapes, tok]
        in_sh = [params_pspecs, tok_p]
        if cfg.encoder:
            eshape = (b, cfg.encoder.n_frames, cfg.d_model)
            args.append(jax.ShapeDtypeStruct(eshape, jnp.dtype(cfg.dtype)))
            in_sh.append(sh.resolve(("batch", "frames", None), eshape))

            def fn(params, tokens, enc):
                return model.prefill(params, tokens, cache_len=s,
                                     enc_embeds=enc)
        else:
            def fn(params, tokens):
                return model.prefill(params, tokens, cache_len=s)
        return CellBuild(
            arch, shape, "prefill_step", fn, tuple(args), tuple(in_sh),
            (logits_p, cache_pspecs), (), model, cell, n_params,
            n_active, attn_hbm)

    # decode: one new token against a cache of length seq_len
    cache_tpl = model.cache_template(b, s)
    cache_shapes = template_shapes(cache_tpl)
    cache_pspecs = sh.template_pspecs(cache_tpl)
    tok = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)
    tok_p = sh.resolve(("batch",), (b,))
    logits_p = sh.resolve(("batch", "vocab"), (b, cfg.vocab_size))

    def fn(params, cache, tokens, posv):
        return model.decode_step(params, cache, tokens, posv)

    return CellBuild(
        arch, shape, "serve_step", fn,
        (params_shapes, cache_shapes, tok, pos),
        (params_pspecs, cache_pspecs, tok_p, tok_p),
        (logits_p, cache_pspecs), (1,), model, cell, n_params, n_active,
        attn_hbm)


def input_specs(arch: str, shape: str, mesh) -> dict:
    """Public helper: ShapeDtypeStruct stand-ins for every input of the cell."""
    cb = build_cell(arch, shape, mesh)
    return {"step": cb.step_name, "args": cb.args,
            "in_shardings": cb.in_shardings}
