"""Roofline terms from dry-run artifacts (TPU v5e targets).

Per (arch x shape x mesh) record produced by ``launch.dryrun``:

    compute_s    = FLOPs_per_device   / 197e12      (bf16 peak / chip)
    memory_s     = HBM_bytes_per_dev  / 819e9       (HBM bandwidth / chip)
    ici_s        = ICI coll bytes/dev / 50e9        (per-link ICI)
    dcn_s        = DCN coll bytes/dev / 6.25e9      (~50 Gbps/chip DCN, stated
                                                     assumption for cross-pod)

The dominant term is the bottleneck; roofline fraction = compute_s /
max(terms) (1.0 = perfectly compute-bound). ``MODEL_FLOPS`` uses 6·N·D for
training and 2·N·D for inference steps (N = active params for MoE), and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs exposes remat/dispatch/causal
waste.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link
DCN_BW = 6.25e9            # bytes/s / chip (assumed 50 Gbps)
CHIPS = {"pod1": 256, "pod2": 512}


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    step: str
    compute_s: float
    memory_s: float
    ici_s: float
    dcn_s: float
    model_flops_dev: float
    hlo_flops_dev: float
    mem_gib: float
    attn_hbm_bytes: float = 0.0

    @property
    def memory_kernel_s(self) -> float:
        """Memory term with the Pallas flash kernel (attention
        score/prob HBM round trips stay in VMEM)."""
        return max(self.memory_s - self.attn_hbm_bytes / HBM_BW, 0.0)

    @property
    def kernel_step_time_s(self) -> float:
        return max(self.compute_s, self.memory_kernel_s, self.ici_s,
                   self.dcn_s)

    @property
    def kernel_roofline_frac(self) -> float:
        t = self.kernel_step_time_s
        return self.compute_s / t if t else 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "ici": self.ici_s, "dcn": self.dcn_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.ici_s, self.dcn_s)

    @property
    def roofline_frac(self) -> float:
        return self.compute_s / self.step_time_s if self.step_time_s else 0.0

    @property
    def useful_ratio(self) -> float:
        return (self.model_flops_dev / self.hlo_flops_dev
                if self.hlo_flops_dev else 0.0)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline-predicted step time."""
        if not self.step_time_s:
            return 0.0
        return self.model_flops_dev / (self.step_time_s * PEAK_FLOPS)


def from_record(rec: dict) -> Roofline | None:
    if not rec.get("ok"):
        return None
    h = rec["hlo"]
    chips = CHIPS[rec["mesh"]]
    mult = 6.0 if rec["step"] == "train_step" else 2.0
    n = rec["n_active_params"]
    model_flops = mult * n * rec["tokens_per_step"] / chips
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        step=rec["step"],
        compute_s=h["flops"] / PEAK_FLOPS,
        memory_s=h["hbm_bytes"] / HBM_BW,
        ici_s=h["coll_ici_bytes"] / ICI_BW,
        dcn_s=h["coll_dcn_bytes"] / DCN_BW,
        model_flops_dev=model_flops,
        hlo_flops_dev=h["flops"],
        mem_gib=rec["memory"]["peak_bytes_est"] / 2**30,
        attn_hbm_bytes=rec.get("attn_hbm_bytes", 0.0),
    )


ADVICE = {
    "compute": "compute-bound: reduce HLO waste (remat policy, causal/block "
               "skipping, dispatch einsums) or accept — this is the target.",
    "memory": "HBM-bound: increase arithmetic intensity (fuse, larger "
              "per-chip tiles, bf16 intermediates, fewer re-reads).",
    "ici": "ICI-bound: reshard to cut all-gathers (wider FSDP shards, "
           "sequence-parallel boundaries, overlap or batch collectives).",
    "dcn": "DCN-bound: keep cross-pod traffic to one gradient reduce per "
           "step; compress grads or accumulate more microbatches.",
}


def load_dir(path: str) -> list[dict]:
    out = []
    for name in sorted(os.listdir(path)):
        if name.endswith(".json"):
            with open(os.path.join(path, name)) as f:
                out.append(json.load(f))
    return out


def table(records: list[dict], mesh: str = "pod1") -> str:
    rows = ["| arch | shape | step | compute s | memory s | mem(kern) s "
            "| ici s | dcn s | bottleneck | roofline | roofline(kern) | MFU "
            "| useful | mem GiB |",
            "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for rec in records:
        if rec.get("mesh") != mesh:
            continue
        if rec.get("skipped"):
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | — "
                        f"| — | — | SKIP | — | — | — | — | — |")
            continue
        r = from_record(rec)
        if r is None:
            rows.append(f"| {rec['arch']} | {rec['shape']} | FAIL "
                        f"| — | — | — | — | — | — | — | — | — | — |")
            continue
        rows.append(
            f"| {r.arch} | {r.shape} | {r.step.replace('_step','')} "
            f"| {r.compute_s:.4f} | {r.memory_s:.4f} "
            f"| {r.memory_kernel_s:.4f} | {r.ici_s:.4f} "
            f"| {r.dcn_s:.4f} | {r.dominant} | {r.roofline_frac:.2f} "
            f"| {r.kernel_roofline_frac:.2f} "
            f"| {r.mfu:.2f} | {r.useful_ratio:.2f} | {r.mem_gib:.1f} |")
    return "\n".join(rows)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod1")
    args = ap.parse_args()
    recs = load_dir(args.dir)
    print(table(recs, args.mesh))
    print()
    for rec in recs:
        r = from_record(rec)
        if r and rec.get("mesh") == args.mesh:
            print(f"{r.arch:22s} {r.shape:12s} -> {r.dominant}: "
                  f"{ADVICE[r.dominant]}")


if __name__ == "__main__":
    main()
