"""Static cost analysis of compiled (SPMD-partitioned) HLO text.

Why not ``compiled.cost_analysis()``? XLA's HloCostAnalysis visits a
``while`` body **once**, so a scanned 46-layer model reports ~1/46th of its
FLOPs (verified empirically). This walker:

* parses every computation in ``compiled.as_text()``,
* extracts ``while`` trip counts from the loop condition's comparison
  constant and multiplies body costs accordingly (nested loops compose),
* counts dot/convolution FLOPs from shapes + contraction dims,
* models HBM traffic at fusion boundaries (operands + outputs of top-level
  ops; fusion-internal ops are free),
* sums per-device collective bytes with ring-model scaling
  ((n-1)/n per participant) and splits ICI vs DCN traffic by whether a
  replica group crosses the pod boundary.

Everything is per-device (post-SPMD shapes), which is what the roofline
terms need.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
               "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
               "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
               "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OPNAME_RE = re.compile(r" ([a-z][a-z0-9\-]*)\(")
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_ATTR_COMP_RE = re.compile(r"(condition|body|calls|to_apply|true_computation|"
                           r"false_computation)=%?([\w.\-]+)")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_RE = re.compile(r"%?([\w.\-]+)\s*=\s*s\d+\[\]\s*constant\((\d+)\)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_elems(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


@dataclass
class Op:
    name: str
    opcode: str
    out_text: str
    operands_text: str
    attrs_text: str
    line: str

    def out_bytes(self) -> int:
        return _shape_bytes(self.out_text)

    def operand_bytes(self) -> int:
        return _shape_bytes(self.operands_text)


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    is_fusion: bool = False
    defs: dict = field(default_factory=dict)   # op name -> output shape text


@dataclass
class CollectiveRecord:
    kind: str
    bytes_moved: float          # ring-scaled per-device bytes
    raw_bytes: int
    group_size: int
    crosses_pod: bool
    multiplier: float
    source_line: str


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_ici: float = 0.0
    coll_dcn: float = 0.0
    dot_flops: float = 0.0
    elem_flops: float = 0.0
    collectives: list = field(default_factory=list)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_ici += other.coll_ici * mult
        self.coll_dcn += other.coll_dcn * mult
        self.dot_flops += other.dot_flops * mult
        self.elem_flops += other.elem_flops * mult
        for c in other.collectives:
            self.collectives.append(CollectiveRecord(
                c.kind, c.bytes_moved, c.raw_bytes, c.group_size,
                c.crosses_pod, c.multiplier * mult, c.source_line))


def _split_op_line(line: str) -> Op | None:
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%") or " = " not in s:
        return None
    name, rest = s.split(" = ", 1)
    m = _OPNAME_RE.search(" " + rest)
    if not m:
        return None
    opcode = m.group(1)
    out_text = rest[:m.start()]
    # bracket-match the operand list
    start = rest.index(m.group(0)) + len(m.group(0))
    depth = 1
    i = start
    while i < len(rest) and depth:
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
        i += 1
    operands = rest[start:i - 1]
    attrs = rest[i:]
    return Op(name.strip("%"), opcode, out_text, operands, attrs, s)


def parse_hlo(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        if stripped.endswith("{") and ("->" in stripped):
            m = _COMP_HEADER_RE.match(stripped)
            if m:
                name = m.group(2)
                cur = Computation(name,
                                  is_fusion="fused" in name or
                                  "computation" in name)
                comps[name] = cur
                if m.group(1):
                    entry = name
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            op = _split_op_line(line)
            if op:
                cur.ops.append(op)
                cur.defs[op.name] = op.out_text
    if entry is None:  # fall back: computation containing no callers
        entry = next(iter(comps))
    return {"computations": comps, "entry": entry}


def _operand_shape_texts(op: Op, comp: "Computation") -> list[str]:
    """Shape text per operand; falls back to the defining op's output shape
    (fusion bodies often print bare ``%name`` operands)."""
    out = []
    for part in _split_top_level(op.operands_text):
        if _SHAPE_RE.search(part):
            out.append(part)
            continue
        m = re.search(r"%([\w.\-]+)", part)
        if m and comp is not None and m.group(1) in comp.defs:
            out.append(comp.defs[m.group(1)])
        else:
            out.append(part)
    return out


def _split_top_level(s: str) -> list[str]:
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def _dot_flops(op: Op, comp: "Computation" = None) -> float:
    out_elems = _shape_elems(op.out_text)
    # contracting dim sizes come from the lhs operand shape + attr dims
    shapes = _operand_shape_texts(op, comp)
    mlhs = _SHAPE_RE.search(shapes[0]) if shapes else None
    if not mlhs:
        return 0.0
    lhs_dims = [int(d) for d in mlhs.group(2).split(",") if d]
    mcontract = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs_text)
    contract = 1
    if mcontract and mcontract.group(1):
        for idx in mcontract.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * out_elems * contract


def _conv_flops(op: Op) -> float:
    out_elems = _shape_elems(op.out_text)
    shapes = _SHAPE_RE.findall(op.operands_text)
    if len(shapes) < 2:
        return 0.0
    k_dims = [int(d) for d in shapes[1][1].split(",") if d]
    # rough: 2 * out * prod(kernel dims except output-feature dim)
    if not k_dims:
        return 0.0
    kernel_work = 1
    for d in k_dims:
        kernel_work *= d
    kernel_work /= max(k_dims)          # drop output-feature dim
    return 2.0 * out_elems * kernel_work


def _trip_count(cond: Computation) -> int:
    consts = {}
    for op in cond.ops:
        m = _CONST_RE.search(op.line)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for op in cond.ops:
        if op.opcode == "compare":
            for ref in re.findall(r"%([\w.\-]+)", op.operands_text):
                if ref in consts:
                    return consts[ref]
    # fallback: any constant in the cond
    return max(consts.values(), default=1)


def _collective_cost(op: Op, pod_size: int | None) -> CollectiveRecord:
    kind = op.opcode.replace("-start", "")
    raw = max(op.operand_bytes(), 1)
    out = max(op.out_bytes(), 1)
    n = 1
    crosses = False
    m = _GROUPS_LIST_RE.search(op.attrs_text)
    first_group: list[int] = []
    if m:
        first_group = [int(x) for x in m.group(1).split(",")]
        n = len(first_group)
    else:
        m2 = _GROUPS_IOTA_RE.search(op.attrs_text)
        if m2:
            n = int(m2.group(2))
            # iota groups [G, n] <= [dims]T(perm): group stride pattern —
            # conservatively flag pod-crossing if group span >= pod size
            first_group = []
    if pod_size and first_group:
        crosses = len({d // pod_size for d in first_group}) > 1
    elif pod_size and n > 1:
        # iota form: check attr for transpose spanning the leading axis
        crosses = "T(" in op.attrs_text and n >= pod_size
    ring = (n - 1) / n if n > 1 else 0.0
    if kind == "all-reduce":
        moved = 2.0 * raw * ring
    elif kind == "all-gather":
        moved = out * ring
    elif kind == "reduce-scatter":
        moved = raw * ring
    elif kind == "all-to-all":
        moved = raw * ring
    else:  # collective-permute
        moved = float(raw)
    return CollectiveRecord(kind, moved, raw, n, crosses, 1.0, op.line[:160])


_ZERO_FLOP_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "reshape", "transpose", "copy", "broadcast", "iota", "slice",
    "dynamic-slice", "dynamic-update-slice", "concatenate", "gather",
    "scatter", "pad", "reverse", "convert", "after-all", "custom-call",
    "partition-id", "replica-id", "rng-bit-generator", "optimization-barrier",
    "copy-start", "copy-done", "send", "recv", "send-done", "recv-done",
    "infeed", "outfeed", "domain",
}

# ops that are pure aliasing / metadata: no HBM traffic
_NO_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "optimization-barrier", "domain", "reshape",
    "partition-id", "replica-id", "copy-start", "copy-done",
}


def _op_hbm_bytes(op: Op, comp: "Computation") -> float:
    """Approximate HBM traffic of one top-level op."""
    code = op.opcode
    if code in _NO_BYTES_OPS:
        return 0.0
    out = op.out_bytes()
    if code in ("broadcast", "iota"):
        return float(out)
    if code in ("slice", "dynamic-slice", "gather"):
        return 2.0 * out          # reads the slice, writes the slice
    if code == "dynamic-update-slice":
        shapes = _operand_shape_texts(op, comp)
        upd = _shape_bytes(shapes[1]) if len(shapes) > 1 else out
        return 2.0 * upd          # touches only the updated region
    if code == "copy":
        return 2.0 * out
    operands = sum(_shape_bytes(s) for s in _operand_shape_texts(op, comp))
    return float(operands + out)


class HloCostModel:
    def __init__(self, text: str, pod_size: int | None = None):
        parsed = parse_hlo(text)
        self.comps: dict[str, Computation] = parsed["computations"]
        self.entry: str = parsed["entry"]
        self.pod_size = pod_size
        self._memo: dict[str, Cost] = {}

    def cost(self) -> Cost:
        return self._comp_cost(self.entry, top_level=True)

    # ------------------------------------------------------------ internals
    def _comp_cost(self, name: str, top_level: bool) -> Cost:
        key = f"{name}:{top_level}"
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Cost()          # cycle guard
        comp = self.comps.get(name)
        if comp is None:
            return Cost()
        total = Cost()
        for op in comp.ops:
            total.add(self._op_cost(op, top_level, comp))
        self._memo[key] = total
        return total

    def _fusion_bytes(self, op: Op, comp: Computation,
                      body: Computation | None) -> float:
        """HBM traffic of one fusion, window-aware.

        * An operand whose only in-fusion users are (dynamic-)slices is read
          at the *slice* size, not the full buffer (scan bodies slice one
          layer out of the stacked params/residuals per iteration).
        * A fusion rooted in dynamic-update-slice writes only the update
          region (the stacked buffer is aliased in place), so the output
          counts at ~2x update size, not the full stack.
        """
        if body is None:
            return _op_hbm_bytes(op, comp)
        # map parameter index -> name, and find users
        param_names = {}
        for bop in body.ops:
            if bop.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", bop.line)
                if m:
                    param_names[int(m.group(1))] = bop.name
        users: dict[str, list] = {}
        for bop in body.ops:
            for ref in re.findall(r"%([\w.\-]+)", bop.operands_text):
                users.setdefault(ref, []).append(bop)

        def effective_read(pname: str, full: int) -> float:
            """Bytes actually read: chase unary-elementwise chains down to
            (dynamic-)slices — XLA loop fusions only compute the sliced
            window, so a param->convert->slice chain reads slice-sized."""
            read = 0.0
            frontier = [pname]
            seen = set()
            while frontier:
                nm = frontier.pop()
                if nm in seen:
                    continue
                seen.add(nm)
                for u in users.get(nm, []):
                    if u.opcode in ("slice", "dynamic-slice"):
                        read += _shape_bytes(u.out_text)
                    elif u.opcode in ("convert", "copy", "bitcast",
                                      "reshape", "transpose", "negate",
                                      "exponential", "tanh"):
                        frontier.append(u.name)
                    else:
                        return float(full)      # real full-tensor consumer
            return min(read, float(full)) if read else float(full)

        total = 0.0
        operand_shapes = _operand_shape_texts(op, comp)
        for i, shape_text in enumerate(operand_shapes):
            full = _shape_bytes(shape_text)
            pname = param_names.get(i)
            if pname and full > 2**20:
                total += effective_read(pname, full)
            else:
                total += full
        # output side
        root = body.ops[-1] if body.ops else None
        if root is not None and root.opcode == "dynamic-update-slice":
            upd = _operand_shape_texts(root, body)
            total += _shape_bytes(upd[1]) if len(upd) > 1 else op.out_bytes()
        elif root is not None and root.opcode == "tuple" and all(
                body.defs.get(r, "") and u.opcode == "dynamic-update-slice"
                for r in re.findall(r"%([\w.\-]+)", root.operands_text)
                for u in [next((o for o in body.ops if o.name == r), root)]):
            for r in re.findall(r"%([\w.\-]+)", root.operands_text):
                dus = next((o for o in body.ops if o.name == r), None)
                if dus is not None and dus.opcode == "dynamic-update-slice":
                    upd = _operand_shape_texts(dus, body)
                    total += _shape_bytes(upd[1]) if len(upd) > 1 else \
                        _shape_bytes(dus.out_text)
                elif dus is not None:
                    total += _shape_bytes(dus.out_text)
        else:
            total += op.out_bytes()
        return total

    def _op_cost(self, op: Op, top_level: bool, comp: Computation) -> Cost:
        c = Cost()
        code = op.opcode
        called = dict(_ATTR_COMP_RE.findall(op.attrs_text))

        if code == "while":
            body = called.get("body")
            cond = called.get("condition")
            trips = _trip_count(self.comps[cond]) if cond in self.comps else 1
            if body in self.comps:
                c.add(self._comp_cost(body, top_level=True), mult=trips)
            if cond in self.comps:
                c.add(self._comp_cost(cond, top_level=True), mult=trips)
            return c

        if code == "fusion":
            inner = called.get("calls")
            if inner in self.comps:
                ic = self._comp_cost(inner, top_level=False)
                c.flops += ic.flops
                c.dot_flops += ic.dot_flops
                c.elem_flops += ic.elem_flops
                # HBM traffic only at the fusion boundary
            if top_level:
                c.bytes += self._fusion_bytes(op, comp,
                                              self.comps.get(inner))
            return c

        if code == "conditional":
            branches = [called.get("true_computation"),
                        called.get("false_computation")]
            branch_costs = [self._comp_cost(b, top_level=True)
                            for b in branches if b in self.comps]
            if branch_costs:
                worst = max(branch_costs, key=lambda x: x.flops + x.bytes)
                c.add(worst)
            return c

        if code == "call":
            inner = called.get("to_apply") or called.get("calls")
            if inner in self.comps:
                c.add(self._comp_cost(inner, top_level=top_level))
            return c

        base = code.replace("-start", "").replace("-done", "")
        if base in COLLECTIVES:
            if code.endswith("-done"):
                return c
            rec = _collective_cost(op, self.pod_size)
            c.collectives.append(rec)
            if rec.crosses_pod:
                c.coll_dcn += rec.bytes_moved
            else:
                c.coll_ici += rec.bytes_moved
            if top_level:
                c.bytes += _op_hbm_bytes(op, comp)
            return c

        if code == "dot":
            f = _dot_flops(op, comp)
            c.flops += f
            c.dot_flops += f
        elif code == "convolution":
            f = _conv_flops(op)
            c.flops += f
            c.dot_flops += f
        elif code in ("reduce", "reduce-window", "sort", "map", "select-and-scatter"):
            f = float(_shape_elems(op.operands_text))
            c.flops += f
            c.elem_flops += f
        elif code not in _ZERO_FLOP_OPS:
            f = float(_shape_elems(op.out_text))
            c.flops += f
            c.elem_flops += f

        if top_level:
            c.bytes += _op_hbm_bytes(op, comp)
        return c


def analyze(text: str, pod_size: int | None = None) -> dict:
    """Full analysis -> plain-dict summary (JSON-friendly)."""
    model = HloCostModel(text, pod_size)
    c = model.cost()
    by_kind: dict[str, float] = {}
    top = sorted(c.collectives, key=lambda r: -r.bytes_moved * r.multiplier)
    for r in c.collectives:
        by_kind[r.kind] = by_kind.get(r.kind, 0.0) + \
            r.bytes_moved * r.multiplier
    return {
        "flops": c.flops,
        "dot_flops": c.dot_flops,
        "elem_flops": c.elem_flops,
        "hbm_bytes": c.bytes,
        "coll_ici_bytes": c.coll_ici,
        "coll_dcn_bytes": c.coll_dcn,
        "coll_by_kind": by_kind,
        "n_collectives": len(c.collectives),
        "top_collectives": [
            {"kind": r.kind, "bytes": r.bytes_moved, "mult": r.multiplier,
             "group": r.group_size, "dcn": r.crosses_pod,
             "line": r.source_line}
            for r in top[:20]],
    }
