"""Continuous-sync daemon: watch -> replan -> drain cycles.

The paper's promise — a table written in one format is readable in any
other "with negligible overhead" — only holds in practice if translation
runs *continuously* as writers append, not as one-shot batch jobs.  This
module turns the batch pipeline (``SyncPlanner`` / ``MetadataCache`` /
``SyncExecutor``) into that always-on companion process:

1. **Watch** — every cycle probes each source table's head with ONE cheap
   storage request (``handle.head_token()``: delta log-tail listing,
   iceberg ``version-hint`` read, hudi newest-instant listing — never a
   replay).  A quiet table costs exactly its head probe and nothing else:
   no planning, no target reads.
2. **Replan** — only datasets whose token moved (or that still carry a
   capped/failed backlog) are re-planned, through the shared
   :class:`~repro.core.metadata_cache.MetadataCache` held across cycles, so
   a cycle with N new commits costs O(N) source reads (the tail-only index
   refresh) plus O(1) target reads per drained unit.
3. **Drain** — the changed units run through the normal transactional /
   coalescing executor path; ``maxCommitsPerSync`` bounds each cycle's
   drain (backpressure), and the leftover backlog keeps the dataset marked
   *pending* so the next cycle continues from the recorded sync token even
   if the source head did not move again.

Scheduling is deterministic: the clock is injected (``ManualClock`` in
tests and benchmarks — nothing ever wall-sleeps), the poll interval comes
from the config's ``daemon:`` block, and a table whose probe or drain hits
a storage error backs off individually with seeded, jittered exponential
delays — one throttled table never stalls the fleet.

Every cycle emits a :class:`DaemonCycleReport`: tables probed / quiet /
changed / backed-off, units planned / drained / skipped / errored, commits
applied, remaining lag in commits per (dataset, target), and the cycle's
exact storage-request census when the filesystem is instrumented.

``stop()`` is graceful: the in-flight cycle always completes (every target
commit is an atomic put-if-absent, so there is no torn state to clean up).
``stop(drain=True)`` keeps cycling without poll sleeps until no table has a
pending backlog, then stops — call ``stop()`` again to give up on a
persistently failing table and exit immediately.

Robustness and publishing companions (all opt-in through the config):

* **Durable checkpoints** (``checkpoint:`` block, ``core/checkpoint.py``) —
  every non-idle cycle persists the watch state, a metadata-index tail
  seed, breaker states and commit-rate estimates as one conditionally-put
  generation; a restarted daemon resumes at O(new commits) instead of a
  cold O(history) rebuild.  The checkpoint is *advisory*: the first
  cycle's probes re-verify every table against its live head, which
  always wins.
* **Per-table circuit breakers** (``health:`` block, ``core/health.py``) —
  repeated failures open a breaker (the table is skipped outright, not
  even probed, until a cooldown), repeated opens quarantine the table;
  quarantined backlogs are excluded from ``stop(drain=True)`` so one
  poisoned table cannot hold shutdown hostage.
* **Catalog group publish** (``catalog:`` block, ``lst/catalog/``) —
  every cycle's cleanly drained tables register in the catalog as ONE
  atomic generation post-drain (a *group commit*), so cross-table
  readers pinning through the catalog
  (``SnapshotServer.read_group``) observe either all of a cycle's
  publish or none of it; the catalog generation rides the checkpoint.

Facade: ``run_daemon(config, cycles=N)`` for scripts and operators;
``examples/continuous_sync.py`` drives it against an ``s3sim://`` store.
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field

from repro.core.checkpoint import CheckpointStore, decode_seed, encode_seed
from repro.core.config import DatasetConfig, FleetOptions, SyncConfig
from repro.core.executor import SyncExecutor
from repro.core.fleet import SyncFleet
from repro.core.health import ALLOW, PARKED, HealthTracker
from repro.core.metadata_cache import MetadataCache
from repro.core.plan import ERROR, SKIP, SyncPlan, SyncPlanner
from repro.core.telemetry import Telemetry
from repro.lst.catalog import Catalog, TablePointer, ViewRef
from repro.lst.storage.base import join

__all__ = ["SystemClock", "ManualClock", "DaemonCycleReport", "SyncDaemon",
           "run_daemon"]

# unbounded run(): rolling window of retained per-cycle reports (an
# always-on daemon at 1s polls produces ~86k cycles/day; keeping them all
# would grow memory with uptime)
MAX_RETAINED_REPORTS = 1000


class SystemClock:
    """Wall clock (monotonic) — the default outside tests."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)

    def wait(self, event: threading.Event, seconds: float) -> bool:
        """Sleep up to ``seconds`` but wake immediately if ``event`` sets —
        this is what makes ``stop()`` interrupt a long poll interval."""
        if seconds > 0:
            return event.wait(seconds)
        return event.is_set()


class ManualClock:
    """Deterministic clock: ``sleep`` advances ``now`` instantly.

    Injected into the daemon by tests and benchmarks so poll intervals and
    backoff windows are exercised without ever wall-sleeping.
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            self._t += seconds

    def wait(self, event: threading.Event, seconds: float) -> bool:
        self.sleep(seconds)
        return event.is_set()

    def advance(self, seconds: float) -> None:
        self._t += float(seconds)


@dataclass
class _TableWatch:
    """Per-dataset watch state carried across cycles."""
    token: str | None = None   # head token as of the last clean drain
    pending: bool = False      # bounded/failed drain left commits behind
    failures: int = 0          # consecutive probe/drain errors
    not_before: float = 0.0    # backoff window end (clock time)
    lag: int = 0               # commits still behind after the last cycle
                               # (feeds the fleet's commit-rate estimator)


@dataclass
class DaemonCycleReport:
    """What one watch -> replan -> drain cycle saw and did."""
    cycle: int
    started_at: float = 0.0        # clock time at cycle start
    elapsed_s: float = 0.0
    probed: int = 0                # tables head-probed this cycle
    quiet: int = 0                 # probed, head unchanged, no backlog
    changed: int = 0               # probed, head moved or backlog pending
    backed_off: int = 0            # skipped: inside a backoff window
    table_errors: int = 0          # probe/plan/drain blew up for the table
    units_planned: int = 0
    units_drained: int = 0         # FULL / INCREMENTAL executed ok
    units_skipped: int = 0
    units_errored: int = 0
    commits_applied: int = 0       # source commits applied across all units
    units_deferred: int = 0        # fleet drain budget pushed these to the
                                   # next cycle (maxUnitsPerCycle)
    workers: int = 1               # fleet width this cycle (1 = serial path)
    steals: int = 0                # cells drained off their home shard
    breaker_open: int = 0          # skipped: circuit breaker open (cooling)
    quarantined: int = 0           # skipped: quarantined (given up on)
    checkpoint_gen: int | None = None  # generation saved this cycle
    catalog_generation: int | None = None  # catalog generation this cycle's
                                           # group publish landed (or the
                                           # already-converged generation)
    health: dict = field(default_factory=dict)  # path -> breaker state
    lag: dict = field(default_factory=dict)   # (dataset, target) -> commits
                                              # still behind after the cycle
    failures: list = field(default_factory=list)  # (dataset, phase, error)
    storage_ops: dict | None = None    # cycle's storage-request census delta
                                       # (instrumented filesystems only)
    results: list = field(default_factory=list)   # SyncResults, plan order

    @property
    def idle(self) -> bool:
        """Nothing to do and nothing in the way: every table quiet.

        An open (cooling-down) breaker counts as "in the way" — the table
        will be retried — but a *quarantined* table does not: the daemon
        has given up on it, and it must not keep an idle-bounded run
        alive.
        """
        return (self.changed == 0 and self.backed_off == 0
                and self.table_errors == 0 and self.breaker_open == 0)

    @property
    def total_lag(self) -> int:
        return sum(self.lag.values())

    def summary(self) -> str:
        return (f"cycle {self.cycle}: probed={self.probed} "
                f"quiet={self.quiet} changed={self.changed} "
                f"backed_off={self.backed_off} "
                f"drained={self.units_drained} skipped={self.units_skipped} "
                f"errored={self.units_errored + self.table_errors} "
                f"commits={self.commits_applied} lag={self.total_lag}")


class SyncDaemon:
    """Always-on continuous sync over one :class:`SyncConfig`.

    Holds the shared filesystem, metadata cache, telemetry and per-table
    watch state across cycles; ``run_cycle()`` is one deterministic watch ->
    replan -> drain pass, ``run()`` loops cycles on the injected clock.
    Thread-safety: ``run()`` / ``run_cycle()`` belong to one driving thread;
    ``stop()`` may be called from any thread.
    """

    def __init__(self, config: SyncConfig, fs=None,
                 telemetry: Telemetry | None = None,
                 cache: MetadataCache | None = None, *,
                 max_workers: int | None = None, clock=None,
                 fleet: FleetOptions | None = None, read_plane=None):
        self.config = config
        self.telemetry = telemetry or Telemetry()
        self.clock = clock or SystemClock()
        # thread the injected clock into the retry layer's backoff sleeper,
        # so a ManualClock daemon never wall-sleeps even through storage
        # retries (a passed-in fs keeps whatever sleeper it was built with)
        self.fs = fs or config.build_fs(self.telemetry,
                                        sleep=self.clock.sleep)
        self.cache = cache or MetadataCache(self.fs)
        self.max_workers = max_workers
        self.opts = config.daemon
        self.fleet_opts = fleet if fleet is not None else config.fleet
        self._fleet: SyncFleet | None = None
        # the fleet path engages for real width OR a drain budget (the
        # budget/scheduler only exist there — a budgeted single worker
        # still needs the urgency ordering to pick WHICH cells drain)
        if self.fleet_opts.workers > 1 or \
                self.fleet_opts.max_units_per_cycle is not None:
            if self.fleet_opts.mode == "process":
                self._check_process_mode_fs()
            self._fleet = SyncFleet(self.fleet_opts, self.clock)
        # optional co-located SnapshotServer (serve/read_plane.py): every
        # clean drain publishes the fresh source head token post-drain,
        # while the cycle hint is still installed — co-located readers
        # then skip even the per-TTL-window head probe
        self.read_plane = read_plane
        self.cycles_run = 0
        self._rng = random.Random(self.opts.seed)
        self._watch: dict[str, _TableWatch] = {}
        self._stop_event = threading.Event()
        self._drain_on_stop = False
        self.health: HealthTracker | None = \
            HealthTracker(config.health) if config.health.enabled else None
        # optional catalog (lst/catalog/): every cycle's cleanly drained
        # tables publish as ONE atomic group generation post-drain, so
        # cross-table readers pinning through the catalog never observe a
        # half-published cycle
        self.catalog: Catalog | None = None
        self._group_stage: set[str] = set()
        if config.catalog.enabled and (config.catalog.path or config.datasets):
            cat_path = config.catalog.path
            if cat_path is None:
                ds0 = config.datasets[0].path
                parent = ds0.rsplit("/", 1)[0] if "/" in ds0 else ds0
                cat_path = join(parent, "_xtable", "catalog")
            self.catalog = Catalog(self.fs, cat_path,
                                   retain=config.catalog.retain)
            # stage every configured dataset up front: a restarted daemon
            # re-resolves each table once and converges (identical pointers
            # publish nothing) instead of leaving gaps
            self._group_stage = {ds.path for ds in config.datasets}
        self._ckpt: CheckpointStore | None = None
        self._cycles_since_save = 0
        self.restored_from_checkpoint = False
        if config.checkpoint.enabled and \
                (config.checkpoint.path or config.datasets):
            path = config.checkpoint.path or \
                join(config.datasets[0].path, "_xtable", "checkpoint")
            self._ckpt = CheckpointStore(self.fs, path,
                                         retain=config.checkpoint.retain)
            self._restore_checkpoint()

    def _check_process_mode_fs(self) -> None:
        """Process mode ships picklable units to child processes that
        reopen the store themselves — only a plain local filesystem
        satisfies that (simulated/instrumented layers live in this
        process's memory and would silently not be exercised)."""
        from repro.lst.storage.local import LocalFS
        base = self.fs
        while hasattr(base, "inner"):
            base = base.inner
        if not isinstance(base, LocalFS):
            raise ValueError("fleet mode 'process' requires local storage "
                             "(file:// or plain paths)")

    # ------------------------------------------------------------------ api
    def close(self) -> None:
        """Release fleet worker pools (no-op for the serial path)."""
        if self._fleet is not None:
            self._fleet.close()

    def run_cycle(self) -> DaemonCycleReport:
        """One watch -> replan -> drain pass over every dataset."""
        if self._fleet is not None:
            return self._run_fleet_cycle()
        rep = DaemonCycleReport(cycle=self.cycles_run,
                                started_at=self.clock.now())
        t0 = time.perf_counter()
        stats_fn = getattr(self.fs, "stats", None)
        before = stats_fn().as_dict() if stats_fn is not None else None

        for ds in self.config.datasets:
            w = self._watch.setdefault(ds.path, _TableWatch())
            if self.clock.now() < w.not_before:
                rep.backed_off += 1
                continue
            if not self._admit(ds, rep):
                continue
            try:
                # the probe doubles as this cycle's head hint: the planner's
                # current_commit() and the index refresh consume the SAME
                # one-request probe instead of re-reading the source head
                token = self._probe(ds)
            except Exception as e:
                self._table_failed(ds, w, rep, "probe", e)
                self._end_cycle(ds)
                continue
            rep.probed += 1
            if token == w.token and not w.pending:
                rep.quiet += 1
                self._end_cycle(ds)
                continue
            rep.changed += 1
            try:
                self._drain(ds, w, token, rep)
            except Exception as e:
                self._table_failed(ds, w, rep, "drain", e)
            finally:
                # the hint is scoped to THIS cycle: a lingering hint would
                # pin refresh() to a past head forever
                self._end_cycle(ds)

        self._finish_cycle(rep)
        if before is not None:
            after = stats_fn().as_dict()
            rep.storage_ops = {k: after[k] - before[k] for k in after}
        rep.elapsed_s = time.perf_counter() - t0
        self.cycles_run += 1
        self.telemetry.bump("daemon.cycles")
        self.telemetry.record("daemon", "*", "cycle", rep.summary(),
                              rep.elapsed_s)
        return rep

    def run(self, cycles: int | None = None,
            max_cycles_idle: int | None = None) -> list[DaemonCycleReport]:
        """Loop cycles on the injected clock until a bound or a stop.

        ``cycles`` caps the number of cycles this call runs (None = no
        cap); ``max_cycles_idle`` (default: the config's ``maxCyclesIdle``)
        stops after that many *consecutive* idle cycles.  A pending
        ``stop()`` wins over everything — including an in-progress poll
        sleep, which it wakes immediately; ``stop(drain=True)`` keeps
        cycling — skipping poll sleeps while progress is being made —
        until no table has a pending backlog.

        Returns the per-cycle reports; an *unbounded* run retains only the
        newest ``MAX_RETAINED_REPORTS`` so service-mode memory stays flat.
        """
        if max_cycles_idle is None:
            max_cycles_idle = self.opts.max_cycles_idle
        poll_s = self.opts.poll_interval_ms / 1000.0
        reports: list[DaemonCycleReport] = []
        ran = 0
        idle = 0
        while True:
            if self._stop_event.is_set() and \
                    not (self._drain_on_stop and self._pending()):
                break
            rep = self.run_cycle()
            reports.append(rep)
            ran += 1
            if cycles is None and len(reports) > MAX_RETAINED_REPORTS:
                # unbounded service mode must not grow memory with uptime:
                # keep a rolling window of the newest reports
                del reports[0]
            idle = idle + 1 if rep.idle else 0
            if cycles is not None and ran >= cycles:
                break
            if max_cycles_idle is not None and idle >= max_cycles_idle:
                break
            if self._stop_event.is_set():
                if rep.units_drained == 0:
                    # only backed-off stragglers remain: wait the poll out
                    # instead of hot-looping on their closed windows (a
                    # plain clock sleep — the stop-event wait would return
                    # instantly here, the stop is already set)
                    self.clock.sleep(poll_s)
                continue
            if self._wait(poll_s):
                continue        # stop() during the sleep: re-check at the top
        return reports

    def stop(self, *, drain: bool = False) -> None:
        """Request a graceful stop (thread-safe).

        The in-flight cycle always completes — every target commit is an
        atomic put-if-absent, so stopping between cycles never leaves torn
        state.  With ``drain=True`` the daemon keeps cycling until no table
        has a pending backlog before it stops (repeating ``stop(drain=True)``
        is idempotent); a plain ``stop()`` downgrades a draining stop to an
        immediate one — the escape hatch when a pending table fails
        persistently.  A stop during the poll sleep wakes it immediately.
        """
        if self._stop_event.is_set():
            self._drain_on_stop = self._drain_on_stop and drain
        else:
            self._drain_on_stop = drain
            self._stop_event.set()

    def lag(self) -> dict:
        """Last known (dataset path) -> pending flag, for monitoring."""
        return {p: w.pending for p, w in self._watch.items()}

    def _wait(self, seconds: float) -> bool:
        """Poll-interval wait, woken early by ``stop()``; returns whether a
        stop is pending.  Falls back to a plain sleep for injected clocks
        without a ``wait``."""
        wait = getattr(self.clock, "wait", None)
        if wait is not None:
            return bool(wait(self._stop_event, seconds))
        self.clock.sleep(seconds)
        return self._stop_event.is_set()

    # ---------------------------------------------------------- fleet cycle
    def _run_fleet_cycle(self) -> DaemonCycleReport:
        """One watch -> replan -> drain pass across the sharded fleet.

        Same contract as the serial cycle — one head probe per eligible
        table, per-table error isolation and backoff, ``maxCommitsPerSync``
        backpressure — but the probe and plan phases fan out over the
        worker pool (they are RTT-bound), and the planned cells drain
        through per-worker shard queues: most-urgent-first per the
        lag-aware scheduler, with idle workers stealing from the longest
        queue, and ``maxUnitsPerCycle`` bounding the whole pass.
        """
        fleet = self._fleet
        rep = DaemonCycleReport(cycle=self.cycles_run,
                                started_at=self.clock.now(),
                                workers=fleet.opts.workers)
        t0 = time.perf_counter()
        stats_fn = getattr(self.fs, "stats", None)
        before = stats_fn().as_dict() if stats_fn is not None else None

        now = self.clock.now()
        eligible = []
        for ds in self.config.datasets:
            w = self._watch.setdefault(ds.path, _TableWatch())
            if now < w.not_before:
                rep.backed_off += 1
                continue
            if not self._admit(ds, rep):
                continue
            eligible.append((ds, w))

        # every eligible table's cycle hint must be cleared exactly once,
        # whatever phase it leaves the cycle in
        ended: set[str] = set()

        def end(ds: DatasetConfig) -> None:
            if ds.path not in ended:
                ended.add(ds.path)
                self._end_cycle(ds)

        try:
            # watch: still exactly ONE head request per table, overlapped
            # across the pool instead of serialized
            probes = fleet.map(lambda e: self._probe(e[0]), eligible)
            changed = []
            for (ds, w), (token, err) in zip(eligible, probes):
                if err is not None:
                    self._table_failed(ds, w, rep, "probe", err)
                    end(ds)
                    continue
                rep.probed += 1
                if token == w.token and not w.pending:
                    rep.quiet += 1
                    end(ds)
                    continue
                rep.changed += 1
                changed.append((ds, w, token))

            # replan: per-dataset planning (source tail refresh + target
            # state reads) is RTT-bound too — same pool
            planned = fleet.map(lambda c: self._plan_ds(c[0], c[2]), changed)
            work = []
            writers: dict = {}
            for (ds, w, token), (res, err) in zip(changed, planned):
                if err is not None:
                    self._table_failed(ds, w, rep, "plan", err)
                    end(ds)
                    continue
                units, ds_writers = res
                writers.update(ds_writers)
                rep.units_planned += len(units)
                # feed the commit-rate EWMA with how far the head moved
                # past what was already pending after the last cycle
                backlog = max((u.backlog for u in units), default=0)
                fleet.scheduler.observe(ds.path, max(0, backlog - w.lag),
                                        now)
                work.append((ds, w, token, units))

            # drain: one global urgency ordering across datasets, sharded
            # over the worker queues, stolen when a shard stalls
            all_units = fleet.scheduler.order(
                [u for _, _, _, units in work for u in units], now)
            executor = SyncExecutor(
                self.fs, self.cache, self.telemetry, 1,
                manifest_compaction_threshold=self.config
                .manifest_compaction_threshold)
            executor.prepare(writers)
            outcome = fleet.drain(all_units, executor,
                                  budget=fleet.opts.max_units_per_cycle)
            rep.steals = outcome.steals
            by_unit = {id(u): r
                       for u, r in zip(all_units, outcome.results)}
            for ds, w, token, units in work:
                self._account(ds, w, token, units,
                              [by_unit.get(id(u)) for u in units], rep)
                end(ds)
        finally:
            for ds, _w in eligible:
                end(ds)

        self._finish_cycle(rep)
        if before is not None:
            after = stats_fn().as_dict()
            rep.storage_ops = {k: after[k] - before[k] for k in after}
        rep.elapsed_s = time.perf_counter() - t0
        self.cycles_run += 1
        self.telemetry.bump("daemon.cycles")
        self.telemetry.record("daemon", "*", "cycle", rep.summary(),
                              rep.elapsed_s)
        return rep

    def _plan_ds(self, ds: DatasetConfig, token: str) -> tuple:
        """Plan one dataset's cells (fleet plan phase); returns the units
        plus the planner's opened target writers for the executor."""
        planner = SyncPlanner(self.config, self.fs, self.cache,
                              self.telemetry)
        units = planner.plan_dataset(ds, head_hint=token)
        return units, planner.writers

    # ------------------------------------------------------------- internals
    def _admit(self, ds: DatasetConfig, rep: DaemonCycleReport) -> bool:
        """Circuit-breaker gate: may this table take a cycle?  An open
        breaker skips the table entirely (not even a probe); a quarantined
        one is parked until its (long) cooldown."""
        if self.health is None:
            return True
        verdict = self.health.admit(ds.path, self.clock.now())
        if verdict == ALLOW:
            return True
        if verdict == PARKED:
            rep.quarantined += 1
        else:
            rep.breaker_open += 1
        self.telemetry.bump("daemon.breaker_skips")
        return False

    def _finish_cycle(self, rep: DaemonCycleReport) -> None:
        """End-of-cycle bookkeeping shared by the serial and fleet paths:
        publish breaker states into the report, group-publish the cycle's
        drained tables into the catalog, and save a checkpoint generation
        if this cycle changed anything.  The catalog publish runs BEFORE
        the checkpoint so the new generation rides the same save."""
        if self.health is not None:
            rep.health = self.health.states()
        self._publish_catalog(rep)
        self._maybe_checkpoint(rep)

    def _maybe_checkpoint(self, rep: DaemonCycleReport) -> None:
        if self._ckpt is None or (rep.changed == 0 and rep.table_errors == 0):
            return              # nothing enabled / an idle cycle: no save
        self._cycles_since_save += 1
        if self._cycles_since_save < self.config.checkpoint.interval_cycles:
            return
        try:
            rep.checkpoint_gen = self._ckpt.save(self._capture_checkpoint())
            self._cycles_since_save = 0
            self.telemetry.bump("daemon.checkpoints")
        except Exception as e:
            # the checkpoint is advisory: a failed save costs the NEXT
            # restart some warmth, never this daemon its cycle
            self.telemetry.bump("daemon.checkpoint_errors")
            self.telemetry.record("daemon", "*", "checkpoint_error", str(e))

    def _publish_catalog(self, rep: DaemonCycleReport) -> None:
        """Group-publish every staged cleanly-drained table as ONE catalog
        generation (the atomic multi-table registration of ISSUE/ROADMAP
        open item 2).

        Staged tables that are still pending, backed off, or mid-failure
        stay staged — they join a later cycle's group instead of splitting
        this one.  Tables whose resolved pointer matches the published one
        are dropped from the stage without minting a generation (a
        restarted daemon converges instead of publishing per boot).  The
        publish is best-effort, exactly like the checkpoint: a failure
        keeps the stage intact for the next cycle and never fails the
        cycle that drained the data.
        """
        if self.catalog is None or not self._group_stage:
            return
        try:
            current = self.catalog.snapshot()
        except Exception as e:
            self.telemetry.bump("daemon.catalog_errors")
            self.telemetry.record("daemon", "*", "catalog_error", str(e))
            return
        staged: list[tuple[str, TablePointer]] = []
        for ds in self.config.datasets:
            if ds.path not in self._group_stage:
                continue
            w = self._watch.get(ds.path)
            if w is None or w.token is None or w.pending or \
                    self.clock.now() < w.not_before:
                continue        # not cleanly drained yet / mid-backoff:
                                # stays staged for a later cycle's group
            try:
                staged.append((ds.path, self._pointer_for(ds, w)))
            except Exception as e:
                self.telemetry.bump("daemon.catalog_errors")
                self.telemetry.record(ds.name, "*", "catalog_error",
                                      f"resolve: {e}")
        if not staged:
            return
        fresh = [(p, ptr) for p, ptr in staged
                 if current.tables.get(ptr.name) != ptr]
        if not fresh:
            self._group_stage.difference_update(p for p, _ in staged)
            rep.catalog_generation = current.generation
            return
        try:
            with self.catalog.transaction() as txn:
                for _path, ptr in fresh:
                    txn.put(ptr)
                txn.add_to_group(self.config.catalog.group,
                                 *[ptr.name for _path, ptr in fresh])
            snap = txn.published
        except Exception as e:
            self.telemetry.bump("daemon.catalog_errors")
            self.telemetry.record("daemon", "*", "catalog_error",
                                  f"publish: {e}")
            return
        self._group_stage.difference_update(p for p, _ in staged)
        rep.catalog_generation = snap.generation
        self.telemetry.bump("daemon.catalog_publishes")
        self.telemetry.record("daemon", "*", "catalog_publish",
                              f"generation {snap.generation}: "
                              f"{sorted(ptr.name for _p, ptr in fresh)}")

    def _pointer_for(self, ds: DatasetConfig, w: _TableWatch) -> TablePointer:
        """Resolve one cleanly drained dataset's catalog pointer.

        The source view is free: after a clean drain the index was
        refreshed against exactly ``w.token``, so ``refresh_to`` is a
        lock-only no-op and ``pinned_state`` answers from the memo.
        Target views (``publishViews: all``) each cost one O(1) head
        probe plus at most a tail-only refresh — the drain itself just
        wrote those heads, so the replay tail is the cycle's own commits.
        """
        src = self.config.source_format
        views: dict[str, ViewRef] = {}
        idx = self.cache.index(src, ds.path)
        try:
            idx.refresh_to(w.token)
            head, _state = idx.pinned_state()
        finally:
            idx.end_cycle()
        views[src] = ViewRef(token=w.token, commit=head)
        if self.config.catalog.publish_views == "all":
            for fmt in self.config.target_formats:
                if fmt == src:
                    continue
                tidx = self.cache.index(fmt, ds.path)
                try:
                    token = tidx.probe()
                    tidx.refresh_to(token)
                    thead, _tstate = tidx.pinned_state()
                finally:
                    tidx.end_cycle()
                views[fmt] = ViewRef(token=token, commit=thead)
        return TablePointer(name=ds.name, base_path=ds.path,
                            source_format=src, views=views)

    def _capture_checkpoint(self) -> dict:
        """One JSON-ready document of everything a restart can reuse."""
        ck = self.config.checkpoint
        tables = {}
        for path, w in self._watch.items():
            idx = self.cache.peek(self.config.source_format, path)
            seed = None
            if idx is not None:
                # the seed window must reach back past the laggiest
                # target's token, or the restarted planner would go FULL
                seed = idx.snapshot_seed(w.lag + ck.min_window)
            tables[path] = {
                "watch": {"token": w.token, "pending": w.pending,
                          "lag": w.lag},
                "seed": encode_seed(seed)}
        payload = {"sourceFormat": self.config.source_format,
                   "savedAt": self.clock.now(), "tables": tables}
        if self._fleet is not None:
            payload["rates"] = self._fleet.scheduler.rates.export()
        if self.health is not None:
            payload["health"] = self.health.snapshot()
        if self.catalog is not None:
            payload["catalog"] = {
                "generation": self.catalog.last_generation}
        return payload

    def _restore_checkpoint(self) -> None:
        """Seed watch state, index tails, rates and breaker states from the
        newest readable checkpoint generation.  Everything restored here is
        advisory — the first cycle's head probes re-verify against the live
        tables, and a head the seeded index cannot splice to forces a
        scoped rebuild of just that table."""
        try:
            loaded = self._ckpt.load()
        except Exception:
            loaded = None
        if not loaded:
            return
        _gen, payload = loaded
        if payload.get("sourceFormat") != self.config.source_format:
            return      # some other pipeline's checkpoint prefix
        try:
            tables = payload.get("tables", {})
            for ds in self.config.datasets:
                t = tables.get(ds.path)
                if not t:
                    continue
                wd = t.get("watch", {})
                # backoff windows are clock-relative and the clock restarted
                # with the process: resume with a clean slate (the breaker
                # snapshot below carries the memory of repeated failures)
                self._watch[ds.path] = _TableWatch(
                    token=wd.get("token"),
                    pending=bool(wd.get("pending", False)),
                    lag=int(wd.get("lag", 0)))
                seed = decode_seed(t.get("seed"))
                if seed is not None:
                    self.cache.index(self.config.source_format,
                                     ds.path).restore_seed(*seed)
            if self._fleet is not None:
                self._fleet.scheduler.rates.restore(payload.get("rates"))
            if self.health is not None:
                self.health.restore(payload.get("health"))
            cat = payload.get("catalog")
            if cat and self.catalog is not None:
                # advisory generation cursor — never trusted over a LIST
                self.catalog.seed_generation(int(cat.get("generation", 0)))
            self.restored_from_checkpoint = True
            self.telemetry.bump("daemon.checkpoint_restores")
        except Exception as e:
            # a malformed checkpoint must degrade to a cold start, never
            # block the daemon
            self._watch.clear()
            self.telemetry.bump("daemon.checkpoint_errors")
            self.telemetry.record("daemon", "*", "checkpoint_restore_error",
                                  str(e))

    def _probe(self, ds: DatasetConfig) -> str:
        """One cheap head probe, memoized on the index as the cycle's head
        hint; the index handle is cached across cycles."""
        return self.cache.index(self.config.source_format, ds.path).probe()

    def _end_cycle(self, ds: DatasetConfig) -> None:
        idx = self.cache.peek(self.config.source_format, ds.path)
        if idx is not None:
            idx.end_cycle()

    def _drain(self, ds: DatasetConfig, w: _TableWatch, token: str,
               rep: DaemonCycleReport) -> None:
        """Replan this dataset's cells and drain the actionable units."""
        planner = SyncPlanner(self.config, self.fs, self.cache,
                              self.telemetry)
        units = planner.plan_dataset(ds, head_hint=token)
        rep.units_planned += len(units)
        executor = SyncExecutor(
            self.fs, self.cache, self.telemetry, self.max_workers,
            manifest_compaction_threshold=self.config
            .manifest_compaction_threshold)
        results = executor.execute(SyncPlan(units, planner.writers))
        self._account(ds, w, token, units, results, rep)

    def _account(self, ds: DatasetConfig, w: _TableWatch, token: str,
                 units: list, results: list,
                 rep: DaemonCycleReport) -> None:
        """Fold one dataset's unit results into the report and its watch
        state (shared by the serial and fleet paths).  A ``None`` result
        is a cell the fleet's drain budget deferred: it counts as lag and
        keeps the dataset pending, but is no error."""
        pending = False
        failed = False
        deferred = False
        lag_left = 0
        for u, r in zip(units, results):
            key = (u.dataset, u.target_format)
            if r is None:
                rep.units_deferred += 1
                deferred = True
                if u.backlog:
                    rep.lag[key] = u.backlog
                    lag_left = max(lag_left, u.backlog)
                continue
            rep.results.append(r)
            if r.mode == SKIP:
                rep.units_skipped += 1
            elif r.mode == ERROR:
                rep.units_errored += 1
                failed = True
                if u.backlog:
                    rep.lag[key] = u.backlog
                    lag_left = max(lag_left, u.backlog)
            else:
                rep.units_drained += 1
                rep.commits_applied += r.commits_synced
                left = max(0, u.backlog - r.commits_synced)
                if left:
                    rep.lag[key] = left
                    pending = True
                    lag_left = max(lag_left, left)

        if failed:
            # keep the old token so the next eligible cycle replans, and
            # back the table off — target errors here include storage
            # retry exhaustion, and hot-looping on them helps nobody
            w.pending = True
            self._backoff(ds, w, rep)
            if self.health is not None:
                self.health.record_failure(ds.path, self.clock.now())
        else:
            w.token = token
            w.pending = pending or deferred
            w.failures = 0
            w.not_before = 0.0
            if self.health is not None:
                self.health.record_success(ds.path)
            if self.read_plane is not None:
                # the cycle hint is still installed here (_end_cycle runs
                # after accounting), so the eager snapshot build inside
                # publish() reuses this cycle's replay at zero requests
                self.read_plane.publish(ds.path,
                                        self.config.source_format, token)
            if self.catalog is not None:
                # stage for this cycle's post-drain group publish; the
                # whole cycle's stage becomes visible as ONE catalog
                # generation in _publish_catalog
                self._group_stage.add(ds.path)
        w.lag = lag_left

    def _table_failed(self, ds: DatasetConfig, w: _TableWatch,
                      rep: DaemonCycleReport, phase: str,
                      err: Exception) -> None:
        rep.table_errors += 1
        rep.failures.append((ds.name, phase, str(err)))
        self.telemetry.bump("daemon.table_errors")
        self.telemetry.record(ds.name, "*", "error", f"{phase}: {err}")
        self._backoff(ds, w, rep)
        if self.health is not None:
            self.health.record_failure(ds.path, self.clock.now())

    def _backoff(self, ds: DatasetConfig, w: _TableWatch,
                 rep: DaemonCycleReport) -> None:
        w.failures += 1
        delay = self.opts.backoff_delay_s(w.failures)
        delay *= 1.0 + self.opts.backoff_jitter * self._rng.random()
        w.not_before = self.clock.now() + delay
        self.telemetry.bump("daemon.backoffs")
        self.telemetry.record(ds.name, "*", "backoff",
                              f"attempt {w.failures}, retry in {delay:.3f}s")

    def _pending(self) -> bool:
        """A quarantined table's backlog must not hold ``stop(drain=True)``
        hostage — the daemon has explicitly given up on it."""
        return any(w.pending and not (self.health is not None and
                                      self.health.is_quarantined(p))
                   for p, w in self._watch.items())


def run_daemon(config: SyncConfig, fs=None,
               telemetry: Telemetry | None = None, *,
               cycles: int | None = None,
               max_cycles_idle: int | None = None,
               max_workers: int | None = None,
               cache: MetadataCache | None = None,
               clock=None,
               fleet: FleetOptions | None = None) -> list[DaemonCycleReport]:
    """Run a continuous-sync daemon to completion (the CLI / service body).

    ``cycles`` bounds the run for scripts and tests; an unbounded call
    relies on the config's ``maxCyclesIdle`` or an external ``stop()``.
    ``fleet`` overrides the config's ``fleet:`` block (workers > 1 runs
    the sharded fleet cycle path).  Returns the per-cycle reports.
    """
    daemon = SyncDaemon(config, fs, telemetry, cache,
                        max_workers=max_workers, clock=clock, fleet=fleet)
    try:
        return daemon.run(cycles=cycles, max_cycles_idle=max_cycles_idle)
    finally:
        daemon.close()
