"""Source readers (paper §3.1): format -> Unified Internal Representation.

One reader per LST format. Each uses the format's own access layer (the way
real XTable links the Delta Kernel / Iceberg API / Hudi client) and emits IR
snapshots and per-commit change sets. Readers are cached by the core logic so
multiple targets share one pass over source metadata.
"""

from __future__ import annotations

from typing import Protocol

from repro.core.ir import InternalDataFile, InternalSnapshot, TableChange
from repro.lst.delta import DeltaTable
from repro.lst.hudi import HudiTable
from repro.lst.iceberg import IcebergTable


class ConversionSource(Protocol):
    format: str

    def current_commit(self) -> str: ...
    def get_snapshot(self, commit: str | None = None) -> InternalSnapshot: ...
    def get_commits_since(self, token: str | None) -> list[str]: ...
    def get_changes(self, commit: str) -> TableChange: ...
    def has_commit(self, token: str) -> bool: ...


class _HandleSource:
    """Shared implementation over the common format-handle protocol."""

    handle_cls = None
    format = "?"

    def __init__(self, fs, base_path: str):
        self.fs = fs
        self.base = base_path
        self.handle = self.handle_cls.open(fs, base_path)
        self._change_cache: dict[str, TableChange] = {}

    # -- snapshots ---------------------------------------------------------
    def current_commit(self) -> str:
        return self.handle.current_version()

    def get_snapshot(self, commit: str | None = None) -> InternalSnapshot:
        st = self.handle.snapshot(commit)
        props = dict(st.properties)
        props.update(self._latest_commit_meta())
        return InternalSnapshot(
            source_format=self.format, source_commit=st.version,
            timestamp_ms=st.timestamp_ms, schema=st.schema,
            partition_spec=st.partition_spec,
            files=tuple(InternalDataFile.from_meta(f)
                        for f in st.files.values()),
            properties=props)

    def _latest_commit_meta(self) -> dict:
        """User metadata of the head commit (carried into targets)."""
        versions = self.handle.versions()
        if not versions:
            return {}
        try:
            return self.get_changes(versions[-1]).extra
        except Exception:
            return {}

    # -- incremental -------------------------------------------------------
    def get_commits_since(self, token: str | None) -> list[str]:
        versions = self.handle.versions()
        if token is None:
            return versions
        if token not in versions:
            raise KeyError(f"token {token} not in source history")
        return versions[versions.index(token) + 1:]

    def has_commit(self, token: str) -> bool:
        return token in self.handle.versions()

    def get_changes(self, commit: str) -> TableChange:
        if commit in self._change_cache:
            return self._change_cache[commit]
        adds, removes, op, info = self.handle.changes(commit)
        # schema may have evolved at this commit; record the schema-as-of
        schema = self.handle.snapshot(commit).schema
        extra = {k: v for k, v in (info or {}).items()
                 if isinstance(v, str) and not k.startswith("xtable.")
                 and k not in ("schema", "timestamp", "operation")}
        ch = TableChange(
            source_format=self.format, source_commit=commit,
            timestamp_ms=self.handle.snapshot(commit).timestamp_ms,
            operation=op,
            adds=tuple(InternalDataFile.from_meta(f) for f in adds),
            removes=tuple(removes), schema=schema, extra=extra)
        self._change_cache[commit] = ch
        return ch


class DeltaSource(_HandleSource):
    handle_cls = DeltaTable
    format = "delta"


class IcebergSource(_HandleSource):
    handle_cls = IcebergTable
    format = "iceberg"

    def get_commits_since(self, token: str | None) -> list[str]:
        # iceberg "-1" denotes the empty pre-first-snapshot state
        versions = self.handle.versions()
        if token in (None, "-1"):
            return versions
        if token not in versions:
            raise KeyError(f"token {token} not in source history")
        return versions[versions.index(token) + 1:]

    def has_commit(self, token: str) -> bool:
        return token == "-1" or token in self.handle.versions()


class HudiSource(_HandleSource):
    handle_cls = HudiTable
    format = "hudi"

    def has_commit(self, token: str) -> bool:
        # "0" denotes the empty pre-first-instant state
        return token == "0" or token in self.handle.versions()

    def get_commits_since(self, token: str | None) -> list[str]:
        versions = self.handle.versions()
        if token in (None, "0"):
            return versions
        if token not in versions:
            raise KeyError(f"token {token} not in source history")
        return versions[versions.index(token) + 1:]


SOURCES = {"delta": DeltaSource, "iceberg": IcebergSource, "hudi": HudiSource}


def make_source(fmt: str, fs, base_path: str) -> ConversionSource:
    return SOURCES[fmt](fs, base_path)
