"""Source readers (paper §3.1): format -> Unified Internal Representation.

One reader per LST format. Each uses the format's own access layer (the way
real XTable links the Delta Kernel / Iceberg API / Hudi client) and emits IR
snapshots and per-commit change sets.

Readers sit on a :class:`~repro.core.metadata_cache.TableMetadataIndex`: the
source log is replayed once per table and every snapshot/change question —
for every commit, for every target — is answered from that single pass.
The index is shared across all targets of a dataset via the run's
``MetadataCache``, so N targets still cost one replay.
"""

from __future__ import annotations

from typing import Protocol

from repro.core.ir import InternalDataFile, InternalSnapshot, TableChange
from repro.core.metadata_cache import TableMetadataIndex
from repro.lst.delta import DeltaTable
from repro.lst.hudi import HudiTable
from repro.lst.iceberg import IcebergTable
from repro.lst.schema import CommitEntry


class ConversionSource(Protocol):
    format: str

    def current_commit(self) -> str: ...
    def get_snapshot(self, commit: str | None = None) -> InternalSnapshot: ...
    def get_commits_since(self, token: str | None) -> list[str]: ...
    def get_changes(self, commit: str) -> TableChange: ...
    def has_commit(self, token: str) -> bool: ...


def _change_extra(info: dict) -> dict:
    """Commit user-metadata carried into targets (strings, minus internals)."""
    return {k: v for k, v in (info or {}).items()
            if isinstance(v, str) and not k.startswith("xtable.")
            and k not in ("schema", "timestamp", "operation")}


class _HandleSource:
    """Shared implementation over the common format-handle protocol."""

    handle_cls = None
    format = "?"

    def __init__(self, fs, base_path: str, index: TableMetadataIndex | None = None):
        self.fs = fs
        self.base = base_path
        if index is not None:
            self.index = index
            self.handle = index.handle
        else:
            self.handle = self.handle_cls.open(fs, base_path)
            self.index = TableMetadataIndex(self.handle)

    # -- snapshots ---------------------------------------------------------
    def current_commit(self) -> str:
        return self.index.head()

    def get_snapshot(self, commit: str | None = None) -> InternalSnapshot:
        st = self.index.state_at(commit)
        props = dict(st.properties)
        props.update(self._latest_commit_meta())
        return InternalSnapshot(
            source_format=self.format, source_commit=st.version,
            timestamp_ms=st.timestamp_ms, schema=st.schema,
            partition_spec=st.partition_spec,
            files=tuple(InternalDataFile.from_meta(f)
                        for f in st.files.values()),
            properties=props)

    def _latest_commit_meta(self) -> dict:
        """User metadata of the head commit (carried into targets)."""
        versions = self.index.versions()
        if not versions:
            return {}
        return _change_extra(self.index.entry(versions[-1]).info)

    # -- incremental -------------------------------------------------------
    def get_commits_since(self, token: str | None) -> list[str]:
        versions = self.index.versions()
        if token is None:
            return versions
        if token not in versions:
            raise KeyError(f"token {token} not in source history")
        return versions[versions.index(token) + 1:]

    def has_commit(self, token: str) -> bool:
        return self.index.has(token)

    def get_changes(self, commit: str) -> TableChange:
        e: CommitEntry = self.index.entry(commit)
        return TableChange(
            source_format=self.format, source_commit=commit,
            timestamp_ms=e.timestamp_ms, operation=e.operation,
            adds=tuple(InternalDataFile.from_meta(f) for f in e.adds),
            removes=tuple(e.removes), schema=e.schema,
            extra=_change_extra(e.info))


class DeltaSource(_HandleSource):
    handle_cls = DeltaTable
    format = "delta"


class IcebergSource(_HandleSource):
    handle_cls = IcebergTable
    format = "iceberg"

    def get_commits_since(self, token: str | None) -> list[str]:
        # iceberg "-1" denotes the empty pre-first-snapshot state
        if token == "-1":
            return self.index.versions()
        return super().get_commits_since(token)

    def has_commit(self, token: str) -> bool:
        return token == "-1" or super().has_commit(token)


class HudiSource(_HandleSource):
    handle_cls = HudiTable
    format = "hudi"

    def get_commits_since(self, token: str | None) -> list[str]:
        # hudi "0" denotes the empty pre-first-instant state
        if token == "0":
            return self.index.versions()
        return super().get_commits_since(token)

    def has_commit(self, token: str) -> bool:
        return token == "0" or super().has_commit(token)


SOURCES = {"delta": DeltaSource, "iceberg": IcebergSource, "hudi": HudiSource}


def make_source(fmt: str, fs, base_path: str,
                index: TableMetadataIndex | None = None) -> ConversionSource:
    return SOURCES[fmt](fs, base_path, index)
