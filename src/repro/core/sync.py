"""XTable core logic (paper §3.1): orchestrates the translation.

Responsibilities, per the paper: initializing components, managing sources
and targets, caching for efficiency, state management for recovery and
incremental processing, telemetry for monitoring.

Sync decision per target:

* target has no sync state            -> FULL snapshot sync
* target's token missing from source  -> FULL (history cleaned / diverged)
* otherwise                           -> INCREMENTAL, commit-by-commit

Both paths are idempotent: rerunning a sync that is already current is a
no-op (``skip``), and a crash between two targets leaves each target either
untouched or atomically advanced — recovery is simply "run it again",
because the sync state lives inside each target's own atomic commit.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.core.config import DatasetConfig, SyncConfig
from repro.core.sources import ConversionSource, make_source
from repro.core.targets import make_target
from repro.core.telemetry import Telemetry
from repro.lst.fs import LocalFS


@dataclass
class SyncResult:
    dataset: str
    target_format: str
    mode: str                  # FULL | INCREMENTAL | SKIP | ERROR
    commits_synced: int = 0
    source_commit: str | None = None
    elapsed_s: float = 0.0
    error: str | None = None

    @property
    def ok(self) -> bool:
        return self.error is None


@dataclass
class XTableSyncer:
    config: SyncConfig
    fs: object = None
    telemetry: Telemetry = field(default_factory=Telemetry)

    def __post_init__(self):
        self.fs = self.fs or LocalFS()

    # ------------------------------------------------------------------ api
    def run(self) -> list[SyncResult]:
        results = []
        for ds in self.config.datasets:
            results.extend(self.sync_dataset(ds))
        return results

    def sync_dataset(self, ds: DatasetConfig) -> list[SyncResult]:
        source = make_source(self.config.source_format, self.fs, ds.path)
        head = source.current_commit()
        results = []
        for tf in self.config.target_formats:
            t0 = time.perf_counter()
            try:
                r = self._sync_one(ds, source, head, tf)
            except Exception as e:  # a failing target must not poison others
                self.telemetry.bump("sync.errors")
                self.telemetry.record(ds.name, tf, "error", str(e))
                r = SyncResult(ds.name, tf, "ERROR", error=str(e))
            r.elapsed_s = time.perf_counter() - t0
            results.append(r)
        return results

    # ------------------------------------------------------------- internals
    def _sync_one(self, ds: DatasetConfig, source: ConversionSource,
                  head: str, target_format: str) -> SyncResult:
        target = make_target(target_format, self.fs, ds.path)
        token = target.get_sync_token()
        src_fmt_on_target = target.get_sync_source_format()

        if token == head and src_fmt_on_target == source.format:
            self.telemetry.bump("sync.skipped")
            self.telemetry.record(ds.name, target_format, "skip",
                                  f"already at {head}")
            return SyncResult(ds.name, target_format, "SKIP",
                              source_commit=head)

        use_incremental = (
            self.config.incremental
            and token is not None
            and src_fmt_on_target == source.format
            and source.has_commit(token))

        if not use_incremental:
            with self.telemetry.timed(ds.name, target_format, "full",
                                      f"to {head}"):
                snapshot = source.get_snapshot()   # head snapshot (cached read)
                target.full_sync(snapshot)
            self.telemetry.bump("sync.full")
            return SyncResult(ds.name, target_format, "FULL", 1, head)

        commits = source.get_commits_since(token)
        n = 0
        for c in commits:
            change = source.get_changes(c)   # cached across targets
            with self.telemetry.timed(ds.name, target_format, "incremental",
                                      f"commit {c}"):
                target.incremental_sync(change)
            n += 1
        self.telemetry.bump("sync.incremental", n)
        return SyncResult(ds.name, target_format, "INCREMENTAL", n, head)


def run_sync(config: SyncConfig, fs=None,
             telemetry: Telemetry | None = None) -> list[SyncResult]:
    """One-shot entry point (the CLI / background-process body)."""
    syncer = XTableSyncer(config, fs, telemetry or Telemetry())
    return syncer.run()
