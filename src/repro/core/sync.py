"""XTable core logic (paper §3.1): a facade over plan -> cache -> execute.

Responsibilities, per the paper: initializing components, managing sources
and targets, caching for efficiency, state management for recovery and
incremental processing, telemetry for monitoring.

The work is split across three layers (see ``plan.py``, ``metadata_cache.py``
and ``executor.py``):

1. :class:`~repro.core.plan.SyncPlanner` inspects all sources and targets and
   emits a ``SyncPlan`` of FULL / INCREMENTAL / SKIP units with exact commit
   ranges — decisions, testable without executing anything.
2. :class:`~repro.core.metadata_cache.MetadataCache` replays each source log
   ONCE and serves every per-commit snapshot/change from that pass, shared
   by all targets of a dataset; a moved head refreshes the index by
   replaying only the new tail commits.
3. :class:`~repro.core.executor.SyncExecutor` runs independent units on a
   thread pool with per-unit telemetry and fail isolation.  Each unit
   drains inside one target *transaction* (target metadata parsed once,
   every commit flushed put-if-absent with no re-read), and the
   ``coalesceIncremental`` / ``maxCommitsPerSync`` config knobs trade 1:1
   history fidelity for a single net commit / bounded batch per run.

Both paths stay idempotent: rerunning a sync that is already current is a
no-op (``skip``), and a crash between two targets leaves each target either
untouched or atomically advanced — recovery is simply "run it again",
because the sync state lives inside each target's own atomic commit.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

from repro.core.config import DatasetConfig, SyncConfig
from repro.core.executor import SyncExecutor, SyncResult
from repro.core.metadata_cache import MetadataCache
from repro.core.plan import SyncPlan, SyncPlanner
from repro.core.telemetry import Telemetry

__all__ = ["SyncResult", "XTableSyncer", "run_sync"]


@dataclass
class XTableSyncer:
    config: SyncConfig
    fs: object = None
    telemetry: Telemetry = field(default_factory=Telemetry)
    max_workers: int | None = None        # None = auto; 1 = serial
    cache: MetadataCache | None = None
    coalesce: bool | None = None          # None = take from config
    max_commits_per_sync: int | None = None

    def __post_init__(self):
        # no explicit fs -> build the config's storage stack (scheme-registry
        # backend + optional simulation + retry + telemetry instrumentation)
        self.fs = self.fs or self.config.build_fs(self.telemetry)
        self.cache = self.cache or MetadataCache(self.fs)
        overrides = {}
        if self.coalesce is not None:
            overrides["coalesce_incremental"] = self.coalesce
        if self.max_commits_per_sync is not None:
            overrides["max_commits_per_sync"] = self.max_commits_per_sync
        if overrides:
            self.config = replace(self.config, **overrides)

    # ------------------------------------------------------------------ api
    def plan(self) -> SyncPlan:
        """Inspect sources/targets and decide, without executing anything."""
        return SyncPlanner(self.config, self.fs, self.cache,
                           self.telemetry).plan()

    def run(self) -> list[SyncResult]:
        return self._execute(self.plan())

    def sync_dataset(self, ds: DatasetConfig) -> list[SyncResult]:
        planner = SyncPlanner(self.config, self.fs, self.cache,
                              self.telemetry)
        units = planner.plan_dataset(ds)
        return self._execute(SyncPlan(units, planner.writers))

    # ------------------------------------------------------------- internals
    def _execute(self, plan: SyncPlan) -> list[SyncResult]:
        executor = SyncExecutor(
            self.fs, self.cache, self.telemetry, self.max_workers,
            manifest_compaction_threshold=self.config
            .manifest_compaction_threshold)
        return executor.execute(plan)


def run_sync(config: SyncConfig, fs=None,
             telemetry: Telemetry | None = None, *,
             max_workers: int | None = None,
             cache: MetadataCache | None = None,
             coalesce: bool | None = None,
             max_commits_per_sync: int | None = None) -> list[SyncResult]:
    """One-shot entry point (the CLI / background-process body).

    ``coalesce`` / ``max_commits_per_sync`` override the corresponding
    config knobs for this run only.
    """
    syncer = XTableSyncer(config, fs, telemetry or Telemetry(),
                          max_workers, cache, coalesce, max_commits_per_sync)
    return syncer.run()
