"""Durable daemon checkpoints: crash-safe warm restarts.

A crashed or roll-restarted daemon loses only *derived* state — sync tokens
are already persisted in each target's own metadata (the targets are
self-describing), and every target commit is an atomic put-if-absent, so
correctness never depended on daemon memory.  What a cold restart loses is
*time*: the :class:`~repro.core.metadata_cache.TableMetadataIndex` rebuilds
from a full O(history) log replay per table.  This module persists the
cheap-to-save, expensive-to-recompute remainder through the same storage
layer the daemon already writes targets with:

* per-table watch state (last clean-drain token, pending flag, lag),
* an index *seed* — the folded :class:`TableState` at an anchor just behind
  the head plus the tail of :class:`CommitEntry`\\ s from the anchor to the
  head (wide enough to cover the table's pending backlog),
* the breaker states (``core/health.py``) and the fleet's per-table EWMA
  commit rates.

**The write is the same single-atomic-commit-point discipline the targets
use**: one ``gen-N.json`` object per save, created with a conditional put
(put-if-absent), so concurrent daemons race on the generation number and a
crash mid-save leaves at worst a missing or partial *newest* generation —
``load()`` walks generations newest-first and skips anything unreadable or
unparseable.  Older generations are pruned best-effort.

**The checkpoint is advisory; the live head always wins.**  Restoring only
seeds in-memory state: the first cycle's head probe re-verifies against the
real table, a moved head replays just the new tail (O(new commits)), and an
anchor the log no longer reaches (vacuum, divergent rewrite, a head behind
the checkpoint) falls back to a full rebuild — a stale or lying checkpoint
can cost a rebuild, never a wrong splice.
"""

from __future__ import annotations

import json
import threading

from repro.lst.chunkfile import ColumnStats, DataFileMeta
from repro.lst.schema import (CommitEntry, Field, PartitionField,
                              PartitionSpec, Schema, TableState)
from repro.lst.storage.base import PutIfAbsentError, join

__all__ = ["CHECKPOINT_VERSION", "CheckpointStore", "encode_seed",
           "decode_seed", "entry_to_json", "entry_from_json",
           "state_to_json", "state_from_json"]

CHECKPOINT_VERSION = 1

_GEN_PREFIX = "gen-"
_GEN_SUFFIX = ".json"


# --------------------------------------------------------------- JSON codecs
def _schema_to_json(s: Schema) -> dict:
    return {"schemaId": s.schema_id,
            "fields": [{"name": f.name, "type": f.type,
                        "nullable": f.nullable, "fieldId": f.field_id}
                       for f in s.fields]}


def _schema_from_json(d: dict) -> Schema:
    return Schema([Field(f["name"], f["type"], f.get("nullable", True),
                         f.get("fieldId"))
                   for f in d["fields"]], d.get("schemaId", 0))


def _spec_to_json(p: PartitionSpec) -> dict:
    return {"fields": [{"source": f.source, "transform": f.transform,
                        "name": f.name} for f in p.fields]}


def _spec_from_json(d: dict) -> PartitionSpec:
    return PartitionSpec([PartitionField(f["source"],
                                         f.get("transform", "identity"),
                                         f.get("name"))
                          for f in d["fields"]])


def _stats_to_json(stats: dict) -> dict:
    # column_stats values are ColumnStats instances or raw JSON-safe
    # values, depending on which handle parsed them; tag the typed ones so
    # the round trip reconstructs exactly what was serialized
    return {k: ({"__cs__": v.to_dict()} if isinstance(v, ColumnStats) else v)
            for k, v in stats.items()}


def _stats_from_json(d: dict) -> dict:
    return {k: (ColumnStats.from_dict(v["__cs__"])
                if isinstance(v, dict) and "__cs__" in v else v)
            for k, v in d.items()}


def _file_to_json(f: DataFileMeta) -> dict:
    return {"path": f.path, "sizeBytes": f.size_bytes,
            "recordCount": f.record_count,
            "partitionValues": dict(f.partition_values),
            "columnStats": _stats_to_json(f.column_stats),
            "extra": dict(f.extra)}


def _file_from_json(d: dict) -> DataFileMeta:
    return DataFileMeta(d["path"], d["sizeBytes"], d["recordCount"],
                        dict(d.get("partitionValues", {})),
                        _stats_from_json(d.get("columnStats", {})),
                        dict(d.get("extra", {})))


def entry_to_json(e: CommitEntry) -> dict:
    return {"version": e.version, "timestampMs": e.timestamp_ms,
            "operation": e.operation,
            "adds": [_file_to_json(f) for f in e.adds],
            "removes": list(e.removes),
            "schema": _schema_to_json(e.schema),
            "partitionSpec": _spec_to_json(e.partition_spec),
            "properties": dict(e.properties), "info": dict(e.info)}


def entry_from_json(d: dict) -> CommitEntry:
    return CommitEntry(
        version=d["version"], timestamp_ms=d["timestampMs"],
        operation=d["operation"],
        adds=tuple(_file_from_json(f) for f in d["adds"]),
        removes=tuple(d["removes"]),
        schema=_schema_from_json(d["schema"]),
        partition_spec=_spec_from_json(d["partitionSpec"]),
        properties=dict(d.get("properties", {})),
        info=dict(d.get("info", {})))


def state_to_json(s: TableState) -> dict:
    return {"format": s.format, "version": s.version,
            "timestampMs": s.timestamp_ms,
            "schema": _schema_to_json(s.schema),
            "partitionSpec": _spec_to_json(s.partition_spec),
            "files": [_file_to_json(f) for f in s.files.values()],
            "properties": dict(s.properties)}


def state_from_json(d: dict) -> TableState:
    files = [_file_from_json(f) for f in d["files"]]
    return TableState(d["format"], d["version"], d["timestampMs"],
                      _schema_from_json(d["schema"]),
                      _spec_from_json(d["partitionSpec"]),
                      {f.path: f for f in files},
                      dict(d.get("properties", {})))


def encode_seed(seed: tuple[TableState, list[CommitEntry]] | None) -> dict | None:
    """JSON form of ``TableMetadataIndex.snapshot_seed()``'s result."""
    if seed is None:
        return None
    base, entries = seed
    return {"base": state_to_json(base),
            "entries": [entry_to_json(e) for e in entries]}


def decode_seed(d: dict | None) -> tuple[TableState, list[CommitEntry]] | None:
    if not d:
        return None
    return (state_from_json(d["base"]),
            [entry_from_json(e) for e in d["entries"]])


# ------------------------------------------------------------ durable store
class CheckpointStore:
    """Generation-numbered checkpoint documents under one storage prefix.

    ``save()`` is one conditional put of ``gen-{N+1}.json`` — the atomic
    commit point; two daemons racing the same prefix see exactly one
    winner per generation and the loser re-reads the latest and takes the
    next number.  ``load()`` returns the newest *parseable* generation, so
    a crash mid-save (or a corrupt object) silently falls back one
    generation instead of poisoning the restart.
    """

    def __init__(self, fs, base_path: str, *, retain: int = 3):
        if retain < 1:
            raise ValueError("retain must be >= 1")
        self.fs = fs
        self.base_path = base_path.rstrip("/")
        self.retain = retain
        self._lock = threading.Lock()
        self._gen: int | None = None      # highest generation we know exists
        self.saves = 0
        self.load_fallbacks = 0           # corrupt generations skipped

    def _path(self, gen: int) -> str:
        return join(self.base_path, f"{_GEN_PREFIX}{gen:010d}{_GEN_SUFFIX}")

    def _scan(self) -> list[int]:
        """Existing generation numbers, ascending (one LIST request)."""
        try:
            names = self.fs.list_dir(self.base_path)
        except FileNotFoundError:
            return []
        gens = []
        for n in names:
            if n.startswith(_GEN_PREFIX) and n.endswith(_GEN_SUFFIX):
                try:
                    gens.append(int(n[len(_GEN_PREFIX):-len(_GEN_SUFFIX)]))
                except ValueError:
                    continue
        return sorted(gens)

    # ---------------------------------------------------------------- load
    def load(self) -> tuple[int, dict] | None:
        """``(generation, payload)`` of the newest readable+parseable
        generation, or ``None`` for a cold start.  Unreadable newest
        generations (crash mid-save, corruption) are skipped, not fatal."""
        gens = self._scan()
        with self._lock:
            self._gen = gens[-1] if gens else 0
        for gen in reversed(gens):
            try:
                payload = json.loads(self.fs.read_bytes(self._path(gen)))
                if payload.get("version") != CHECKPOINT_VERSION:
                    raise ValueError(f"unknown checkpoint version "
                                     f"{payload.get('version')!r}")
                return gen, payload
            except Exception:
                with self._lock:
                    self.load_fallbacks += 1
                continue
        return None

    # ---------------------------------------------------------------- save
    def save(self, payload: dict) -> int:
        """Persist ``payload`` as the next generation (atomic conditional
        put); returns the generation written.  Prunes the generation that
        just fell off the retention window, best-effort."""
        payload = dict(payload)
        payload["version"] = CHECKPOINT_VERSION
        data = json.dumps(payload, sort_keys=True).encode()
        with self._lock:
            gen = self._gen
        if gen is None:
            gens = self._scan()
            gen = gens[-1] if gens else 0
        while True:
            gen += 1
            try:
                self.fs.write_bytes(self._path(gen), data)
                break
            except PutIfAbsentError:
                # another daemon landed this generation first: jump past
                # everything that exists and try the next slot
                gens = self._scan()
                gen = gens[-1] if gens else gen
        with self._lock:
            self._gen = gen
            self.saves += 1
        stale = gen - self.retain
        if stale >= 1:
            try:
                self.fs.delete(self._path(stale))
            except Exception:
                pass        # retention is best-effort; never fail a save
        return gen
