"""Per-table circuit breakers: closed -> open -> half_open -> quarantined.

The daemon's exponential backoff already spaces out retries of a failing
table, but it never *gives up*: a permanently poisoned table (corrupt log,
revoked credentials, deleted bucket) keeps consuming a probe + a failed
drain every time its window reopens, forever, and holds ``stop(drain=True)``
hostage.  This module adds the classic breaker on top:

* **closed** — healthy; every failure increments a consecutive counter and
  ``failure_threshold`` of them open the breaker.
* **open** — the table is skipped outright (not even probed) until
  ``open_cooldown_s`` passes, then one **half_open** trial is admitted.
* **half_open** — ``half_open_probes`` consecutive successes close the
  breaker (full reset); any failure re-opens it immediately.
* **quarantined** — ``quarantine_after`` consecutive opens without a
  recovery park the table until the (much longer) ``quarantine_cooldown_s``;
  quarantined tables are excluded from drain-stop pending checks so one
  dead table cannot keep the daemon alive.

State transitions are pure functions of the injected clock and the
success/failure record stream — deterministic under ``ManualClock``.  The
tracker snapshots/restores through the daemon checkpoint so a restarted
fleet does not hammer a table that was quarantined before the crash.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass

from repro.core.config import HealthOptions

__all__ = ["CLOSED", "OPEN", "HALF_OPEN", "QUARANTINED", "TableHealth",
           "HealthTracker"]

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"
QUARANTINED = "quarantined"

# admit() verdicts
ALLOW = "allow"
COOLING = "cooling"         # open/quarantined, cooldown still running
PARKED = "parked"           # quarantined (distinct so reports can tell)


@dataclass
class TableHealth:
    """One table's breaker state (all times are injected-clock seconds)."""
    state: str = CLOSED
    consecutive_failures: int = 0
    opens: int = 0              # consecutive opens without a full close
    half_open_successes: int = 0
    retry_at: float = 0.0       # when an open/quarantined table may retry
    total_failures: int = 0     # lifetime counters (telemetry/report)
    total_opens: int = 0

    def as_dict(self) -> dict:
        return {"state": self.state,
                "consecutiveFailures": self.consecutive_failures,
                "opens": self.opens,
                "halfOpenSuccesses": self.half_open_successes,
                "retryAt": self.retry_at,
                "totalFailures": self.total_failures,
                "totalOpens": self.total_opens}

    @staticmethod
    def from_dict(d: dict) -> "TableHealth":
        return TableHealth(
            state=str(d.get("state", CLOSED)),
            consecutive_failures=int(d.get("consecutiveFailures", 0)),
            opens=int(d.get("opens", 0)),
            half_open_successes=int(d.get("halfOpenSuccesses", 0)),
            retry_at=float(d.get("retryAt", 0.0)),
            total_failures=int(d.get("totalFailures", 0)),
            total_opens=int(d.get("totalOpens", 0)))


class HealthTracker:
    """Breaker state for every table the daemon watches (thread-safe)."""

    def __init__(self, opts: HealthOptions | None = None):
        self.opts = opts or HealthOptions()
        self._lock = threading.Lock()
        self._tables: dict[str, TableHealth] = {}

    def _get(self, key: str) -> TableHealth:
        h = self._tables.get(key)
        if h is None:
            h = self._tables[key] = TableHealth()
        return h

    # ------------------------------------------------------------ gate
    def admit(self, key: str, now: float) -> str:
        """May this table take a cycle?  ``ALLOW`` | ``COOLING`` |
        ``PARKED``.  An elapsed cooldown flips open/quarantined to
        half_open and admits the trial."""
        with self._lock:
            h = self._get(key)
            if h.state in (OPEN, QUARANTINED):
                if now >= h.retry_at:
                    h.state = HALF_OPEN
                    h.half_open_successes = 0
                    return ALLOW
                return PARKED if h.state == QUARANTINED else COOLING
            return ALLOW

    # ------------------------------------------------------- record stream
    def record_success(self, key: str) -> None:
        with self._lock:
            h = self._get(key)
            h.consecutive_failures = 0
            if h.state == HALF_OPEN:
                h.half_open_successes += 1
                if h.half_open_successes >= self.opts.half_open_probes:
                    h.state = CLOSED
                    h.opens = 0
            elif h.state == CLOSED:
                h.opens = 0

    def record_failure(self, key: str, now: float) -> None:
        with self._lock:
            h = self._get(key)
            h.consecutive_failures += 1
            h.total_failures += 1
            trip = (h.state == HALF_OPEN or
                    (h.state == CLOSED and h.consecutive_failures >=
                     self.opts.failure_threshold))
            if not trip:
                return
            h.opens += 1
            h.total_opens += 1
            h.consecutive_failures = 0
            if h.opens >= self.opts.quarantine_after:
                h.state = QUARANTINED
                h.retry_at = now + self.opts.quarantine_cooldown_ms / 1000.0
            else:
                h.state = OPEN
                h.retry_at = now + self.opts.open_cooldown_ms / 1000.0

    # ------------------------------------------------------------- queries
    def state(self, key: str) -> str:
        with self._lock:
            h = self._tables.get(key)
            return h.state if h is not None else CLOSED

    def is_quarantined(self, key: str) -> bool:
        return self.state(key) == QUARANTINED

    def states(self) -> dict[str, str]:
        """(table path) -> breaker state, for reports/monitoring (only
        tables that ever left ``closed`` or recorded a failure appear)."""
        with self._lock:
            return {k: h.state for k, h in self._tables.items()
                    if h.state != CLOSED or h.total_failures}

    # -------------------------------------------------------- checkpointing
    def snapshot(self) -> dict:
        with self._lock:
            return {k: h.as_dict() for k, h in self._tables.items()}

    def restore(self, payload: dict) -> None:
        """Install checkpointed breaker states for tables not yet seen
        (live observations made since startup win over the checkpoint)."""
        with self._lock:
            for k, d in (payload or {}).items():
                self._tables.setdefault(k, TableHealth.from_dict(d))
