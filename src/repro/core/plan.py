"""Sync planning (stage 1 of plan -> execute).

The planner inspects every (dataset, target) cell of the config up front and
emits a :class:`SyncPlan` of :class:`SyncUnit` work items — FULL /
INCREMENTAL (with the exact commit range) / SKIP / ERROR — without executing
anything.  Decisions become testable in isolation, and the executor receives
a set of independent units it can run concurrently.

Decision per target (same contract as the seed syncer):

* target has no sync state            -> FULL snapshot sync
* target's token missing from source  -> FULL (history cleaned / diverged)
* target already at the source head   -> SKIP
* otherwise                           -> INCREMENTAL, commit-by-commit
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.config import DatasetConfig, SyncConfig
from repro.core.metadata_cache import MetadataCache
from repro.core.sources import make_source
from repro.core.targets import make_target
from repro.core.telemetry import Telemetry

FULL = "FULL"
INCREMENTAL = "INCREMENTAL"
SKIP = "SKIP"
ERROR = "ERROR"


@dataclass(frozen=True)
class SyncUnit:
    """One independently executable (dataset, target) translation."""
    dataset: str
    base_path: str
    source_format: str
    target_format: str
    mode: str                       # FULL | INCREMENTAL | SKIP | ERROR
    source_head: str | None = None
    commits: tuple = ()             # commit range for INCREMENTAL, in order
    reason: str = ""
    transactional: bool = True      # drain inside one target transaction
    coalesce: bool = False          # fold the range into one net commit
    backlog: int = 0                # total commits behind BEFORE the
                                    # maxCommitsPerSync cap; len(commits) <
                                    # backlog means this unit is a bounded
                                    # drain and the target stays behind

    @property
    def actionable(self) -> bool:
        return self.mode in (FULL, INCREMENTAL)


@dataclass
class SyncPlan:
    """Ordered set of SyncUnits for one config (order == config order).

    ``writers`` carries the target writers the planner already opened (keyed
    by ``(base_path, target_format)``) so the executor reuses their cached
    target-side state instead of replaying each target log a second time.
    """
    units: list = field(default_factory=list)
    writers: dict = field(default_factory=dict, repr=False, compare=False)

    def by_mode(self, mode: str) -> list:
        return [u for u in self.units if u.mode == mode]

    def pending(self) -> list:
        return [u for u in self.units if u.actionable]

    def summary(self) -> dict:
        out: dict[str, int] = {}
        for u in self.units:
            out[u.mode] = out.get(u.mode, 0) + 1
        return out


class SyncPlanner:
    """Builds a SyncPlan; shares one MetadataCache with the executor so the
    single log replay done while planning also serves execution."""

    def __init__(self, config: SyncConfig, fs=None,
                 cache: MetadataCache | None = None,
                 telemetry: Telemetry | None = None):
        self.config = config
        self.telemetry = telemetry or Telemetry()
        self.fs = fs or config.build_fs(self.telemetry)
        self.cache = cache or MetadataCache(self.fs)
        self.writers: dict = {}

    # ------------------------------------------------------------------ api
    def plan(self) -> SyncPlan:
        plan = SyncPlan()
        for ds in self.config.datasets:
            plan.units.extend(self.plan_dataset(ds))
        plan.writers = self.writers
        return plan

    def plan_dataset(self, ds: DatasetConfig,
                     head_hint: str | None = None) -> list:
        """Plan every (``ds``, target) cell.

        ``head_hint`` — a head token the caller just probed (the daemon's
        watch phase) — is installed on the dataset's metadata index for the
        duration of this planning pass, so ``current_commit()`` and the
        index's tail refresh consume that one probe instead of re-reading
        the source head; the daemon clears it at cycle end (the hint is
        scoped to a single cycle — ``refresh()`` stays the one explicit
        staleness point).
        """
        src_fmt = self.config.source_format
        index = self.cache.index(src_fmt, ds.path)
        if head_hint:
            index.hint_head(head_hint)
        source = make_source(src_fmt, self.fs, ds.path, index)
        head = source.current_commit()
        units = []
        for tf in self.config.target_formats:
            try:
                u = self._plan_one(ds, source, head, tf)
            except Exception as e:  # a broken target must not poison others
                u = SyncUnit(ds.name, ds.path, src_fmt, tf, ERROR,
                             source_head=head, reason=str(e))
            self.telemetry.record(ds.name, tf, "plan",
                                  f"{u.mode} {u.reason}".strip())
            units.append(u)
        return units

    # ------------------------------------------------------------- internals
    def _plan_one(self, ds: DatasetConfig, source, head: str,
                  target_format: str) -> SyncUnit:
        target = make_target(
            target_format, self.fs, ds.path,
            manifest_compaction_threshold=self.config
            .manifest_compaction_threshold)
        token = target.get_sync_token()
        src_fmt_on_target = target.get_sync_source_format()
        self.writers[(ds.path, target_format)] = target
        txn = self.config.transactional_targets

        if token == head and src_fmt_on_target == source.format:
            return SyncUnit(ds.name, ds.path, source.format, target_format,
                            SKIP, source_head=head,
                            reason=f"already at {head}")

        use_incremental = (
            self.config.incremental
            and token is not None
            and src_fmt_on_target == source.format
            and source.has_commit(token))

        if not use_incremental:
            if token is None:
                reason = "no sync state on target"
            elif src_fmt_on_target != source.format:
                reason = (f"source format changed "
                          f"({src_fmt_on_target} -> {source.format})")
            elif not self.config.incremental:
                reason = "incremental disabled"
            else:
                reason = f"token {token} not in source history"
            return SyncUnit(ds.name, ds.path, source.format, target_format,
                            FULL, source_head=head, reason=reason,
                            transactional=txn)

        commits = tuple(source.get_commits_since(token))
        backlog = len(commits)
        reason = f"{backlog} commits behind"
        cap = self.config.max_commits_per_sync
        if cap is not None and len(commits) > cap:
            commits = commits[:cap]
            reason += f", capped at {cap}"
        return SyncUnit(ds.name, ds.path, source.format, target_format,
                        INCREMENTAL, source_head=head, commits=commits,
                        reason=reason, transactional=txn,
                        coalesce=self.config.coalesce_incremental,
                        backlog=backlog)
