"""XTABLE core: omni-directional, incremental LST metadata translation.

The paper's contribution, implemented as described in §3: source readers and
target writers around a unified internal representation, orchestrated as an
explicit plan -> shared-metadata-cache -> concurrent-execute pipeline (see
``plan.py`` / ``metadata_cache.py`` / ``executor.py``; ``sync.py`` is the
facade with persisted state, caching, and telemetry).

Around that pipeline live the operational layers: the continuous-sync
daemon and sharded fleet (``daemon.py`` / ``fleet.py``), durable warm-
restart checkpoints (``checkpoint.py``), per-table circuit breakers
(``health.py``), and the per-cycle atomic catalog group publish
(``lst/catalog/``, wired through the daemon's ``catalog:`` block).
``docs/config.md`` is the consolidated reference for every config knob.
"""

from repro.core.checkpoint import CheckpointStore
from repro.core.config import (CatalogOptions, CheckpointOptions,
                               DaemonOptions, DatasetConfig, FleetOptions,
                               HealthOptions, ReadPlaneOptions,
                               StorageOptions, SyncConfig)
from repro.core.daemon import (DaemonCycleReport, ManualClock, SyncDaemon,
                               SystemClock, run_daemon)
from repro.core.executor import SyncExecutor
from repro.core.fleet import (CommitRateEstimator, LagAwareScheduler,
                              SyncFleet)
from repro.core.health import HealthTracker
from repro.core.ir import (InternalDataFile, InternalSnapshot, InternalTable,
                           TableChange, fold_changes)
from repro.core.metadata_cache import MetadataCache, TableMetadataIndex
from repro.core.plan import SyncPlan, SyncPlanner, SyncUnit
from repro.core.sources import make_source
from repro.core.sync import SyncResult, XTableSyncer, run_sync
from repro.core.targets import make_target
from repro.core.telemetry import Telemetry

__all__ = ["CatalogOptions", "CheckpointOptions", "CheckpointStore",
           "DaemonOptions",
           "DatasetConfig", "FleetOptions", "HealthOptions",
           "HealthTracker", "ReadPlaneOptions", "StorageOptions",
           "SyncConfig",
           "InternalDataFile", "InternalSnapshot", "InternalTable",
           "TableChange", "fold_changes", "make_source", "make_target",
           "run_sync", "SyncResult", "XTableSyncer", "Telemetry", "SyncPlan",
           "SyncPlanner", "SyncUnit", "SyncExecutor", "MetadataCache",
           "TableMetadataIndex", "DaemonCycleReport", "ManualClock",
           "SyncDaemon", "SystemClock", "run_daemon",
           "CommitRateEstimator", "LagAwareScheduler", "SyncFleet"]
