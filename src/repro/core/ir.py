"""XTable's Unified Internal Representation (paper §3, "Extensible").

The IR is the hub of the hub-and-spoke design: source readers produce it,
target writers consume it, and no format ever needs to know about another.
Adding format N+1 costs one reader + one writer instead of 2N translators.

The IR deliberately captures the *intersection semantics* the paper
identifies as shared across Delta/Iceberg/Hudi metadata layers:
schema, partition spec, versioned file lists with per-column statistics,
and per-commit change sets (adds/removes).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.lst.chunkfile import ColumnStats, DataFileMeta
from repro.lst.schema import PartitionSpec, Schema

# Schema / PartitionSpec / ColumnStats are format-neutral already; the IR
# adopts them as its canonical vocabulary.
InternalSchema = Schema
InternalPartitionSpec = PartitionSpec
InternalColumnStats = ColumnStats


@dataclass(frozen=True)
class InternalDataFile:
    """One immutable data file as the IR sees it (format-independent)."""
    physical_path: str            # relative to the table base path
    file_size_bytes: int
    record_count: int
    partition_values: dict = field(default_factory=dict)
    column_stats: dict = field(default_factory=dict)   # name -> ColumnStats
    extra: dict = field(default_factory=dict)

    @staticmethod
    def from_meta(m: DataFileMeta) -> "InternalDataFile":
        return InternalDataFile(m.path, m.size_bytes, m.record_count,
                                dict(m.partition_values), dict(m.column_stats),
                                dict(m.extra))

    def to_meta(self) -> DataFileMeta:
        return DataFileMeta(self.physical_path, self.file_size_bytes,
                            self.record_count, dict(self.partition_values),
                            dict(self.column_stats), dict(self.extra))


@dataclass(frozen=True)
class InternalSnapshot:
    """Full table state at one source commit (drives FULL sync)."""
    source_format: str
    source_commit: str            # format-native commit/snapshot/instant id
    timestamp_ms: int
    schema: InternalSchema
    partition_spec: InternalPartitionSpec
    files: tuple                  # tuple[InternalDataFile]
    properties: dict = field(default_factory=dict)

    def file_paths(self) -> set[str]:
        return {f.physical_path for f in self.files}


@dataclass(frozen=True)
class TableChange:
    """One source commit's delta (drives INCREMENTAL sync)."""
    source_format: str
    source_commit: str
    timestamp_ms: int
    operation: str
    adds: tuple                   # tuple[InternalDataFile]
    removes: tuple                # tuple[str] — physical paths
    schema: InternalSchema | None = None   # set when the commit evolved schema
    extra: dict = field(default_factory=dict)  # source commit user-metadata


@dataclass(frozen=True)
class InternalTable:
    """Static identity of a dataset under translation."""
    base_path: str
    name: str = "table"
