"""XTable's Unified Internal Representation (paper §3, "Extensible").

The IR is the hub of the hub-and-spoke design: source readers produce it,
target writers consume it, and no format ever needs to know about another.
Adding format N+1 costs one reader + one writer instead of 2N translators.

The IR deliberately captures the *intersection semantics* the paper
identifies as shared across Delta/Iceberg/Hudi metadata layers:
schema, partition spec, versioned file lists with per-column statistics,
and per-commit change sets (adds/removes).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.lst.chunkfile import ColumnStats, DataFileMeta
from repro.lst.schema import PartitionSpec, Schema

# Schema / PartitionSpec / ColumnStats are format-neutral already; the IR
# adopts them as its canonical vocabulary.
InternalSchema = Schema
InternalPartitionSpec = PartitionSpec
InternalColumnStats = ColumnStats


@dataclass(frozen=True)
class InternalDataFile:
    """One immutable data file as the IR sees it (format-independent)."""
    physical_path: str            # relative to the table base path
    file_size_bytes: int
    record_count: int
    partition_values: dict = field(default_factory=dict)
    column_stats: dict = field(default_factory=dict)   # name -> ColumnStats
    extra: dict = field(default_factory=dict)

    @staticmethod
    def from_meta(m: DataFileMeta) -> "InternalDataFile":
        return InternalDataFile(m.path, m.size_bytes, m.record_count,
                                dict(m.partition_values), dict(m.column_stats),
                                dict(m.extra))

    def to_meta(self) -> DataFileMeta:
        return DataFileMeta(self.physical_path, self.file_size_bytes,
                            self.record_count, dict(self.partition_values),
                            dict(self.column_stats), dict(self.extra))


@dataclass(frozen=True)
class InternalSnapshot:
    """Full table state at one source commit (drives FULL sync)."""
    source_format: str
    source_commit: str            # format-native commit/snapshot/instant id
    timestamp_ms: int
    schema: InternalSchema
    partition_spec: InternalPartitionSpec
    files: tuple                  # tuple[InternalDataFile]
    properties: dict = field(default_factory=dict)

    def file_paths(self) -> set[str]:
        return {f.physical_path for f in self.files}


@dataclass(frozen=True)
class TableChange:
    """One source commit's delta (drives INCREMENTAL sync).

    A coalesced change (see :func:`fold_changes`) represents a whole commit
    RANGE folded to its net effect; ``lineage`` then lists the folded source
    commits in order, and target writers persist it in the target commit's
    extra metadata so per-commit provenance survives the fold.
    """
    source_format: str
    source_commit: str
    timestamp_ms: int
    operation: str
    adds: tuple                   # tuple[InternalDataFile]
    removes: tuple                # tuple[str] — physical paths
    schema: InternalSchema | None = None   # set when the commit evolved schema
    extra: dict = field(default_factory=dict)  # source commit user-metadata
    lineage: tuple = ()           # source commits folded into this change


def fold_changes(changes: list) -> TableChange:
    """Fold an ordered commit range into ONE net TableChange.

    Dict-fold of the per-commit adds/removes: a file added then removed
    inside the range disappears entirely; a file removed then re-added
    becomes a replace (listed in both removes and adds — targets apply
    removes before adds within a commit); everything else carries through.
    The result advances a target from just-before ``changes[0]`` to exactly
    ``changes[-1]`` in a single target commit.
    """
    if not changes:
        raise ValueError("cannot fold an empty change list")
    if len(changes) == 1:
        return changes[0]
    net_adds: dict[str, InternalDataFile] = {}
    net_removes: list[str] = []
    seen_removes: set[str] = set()
    extra: dict = {}
    for ch in changes:
        for p in ch.removes:
            if p in net_adds:          # born and died within the range
                del net_adds[p]
            elif p not in seen_removes:
                seen_removes.add(p)
                net_removes.append(p)
        for f in ch.adds:
            net_adds[f.physical_path] = f
        extra.update(ch.extra)
    last = changes[-1]
    schema = next((c.schema for c in reversed(changes)
                   if c.schema is not None), None)
    return TableChange(
        source_format=last.source_format, source_commit=last.source_commit,
        timestamp_ms=last.timestamp_ms, operation="coalesced",
        adds=tuple(net_adds.values()), removes=tuple(net_removes),
        schema=schema, extra=extra,
        lineage=tuple(c.source_commit for c in changes))


@dataclass(frozen=True)
class InternalTable:
    """Static identity of a dataset under translation."""
    base_path: str
    name: str = "table"
