"""Concurrent sync execution (stage 3 of plan -> execute).

Runs the independent :class:`~repro.core.plan.SyncUnit`s of a plan on a
thread pool: the targets of one dataset translate in parallel (they write
disjoint metadata directories — ``_delta_log/`` / ``metadata/`` /
``.hoodie/`` — and each target commit is atomic via the filesystem's
put-if-absent), and so do unrelated datasets.  Source metadata is served
from the shared :class:`~repro.core.metadata_cache.MetadataCache`, so
concurrency adds no extra log replays.

Failures are isolated per unit: one target blowing up yields an ERROR
result for that cell and leaves every other cell untouched (recovery is
"run it again", as in the seed design).  Results are returned in plan
order regardless of completion order, so callers see a deterministic
result list.
"""

from __future__ import annotations

import os
import time
from concurrent.futures import ThreadPoolExecutor
from contextlib import nullcontext
from dataclasses import dataclass

from repro.core.ir import fold_changes
from repro.core.metadata_cache import MetadataCache
from repro.core.plan import (ERROR, FULL, INCREMENTAL, SKIP, SyncPlan,
                             SyncUnit)
from repro.core.sources import make_source
from repro.core.targets import make_target
from repro.core.telemetry import Telemetry
from repro.lst.storage.base import latency_bound

DEFAULT_MAX_WORKERS = 8


@dataclass
class SyncResult:
    dataset: str
    target_format: str
    mode: str                  # FULL | INCREMENTAL | SKIP | ERROR
    commits_synced: int = 0    # SOURCE commits this run advanced the target by
    source_commit: str | None = None   # last source commit applied
    elapsed_s: float = 0.0
    error: str | None = None
    target_commits: int = 0    # target commits written (< commits_synced when
                               # the backlog was coalesced)
    storage_ops: dict | None = None  # per-unit storage request census (only
                                     # when the run's fs is instrumented)

    @property
    def ok(self) -> bool:
        return self.error is None


class SyncExecutor:
    """Executes a SyncPlan; ``max_workers=1`` degrades to the serial loop."""

    def __init__(self, fs, cache: MetadataCache | None = None,
                 telemetry: Telemetry | None = None,
                 max_workers: int | None = None, *,
                 manifest_compaction_threshold: int | None = None):
        self.fs = fs
        self.cache = cache or MetadataCache(fs)
        self.telemetry = telemetry or Telemetry()
        self.max_workers = max_workers
        # threaded into fallback-constructed targets so a unit whose writer
        # is missing from plan.writers behaves like a planner-built one
        self.manifest_compaction_threshold = manifest_compaction_threshold
        self._writers: dict = {}

    # ------------------------------------------------------------------ api
    def execute(self, plan: SyncPlan) -> list:
        units = plan.units
        # reuse the planner's target writers (cached target-side state);
        # each (path, format) pair belongs to exactly one unit, so worker
        # threads never share a writer
        self._writers = dict(plan.writers)
        if not units:
            return []
        workers = self.max_workers or self._auto_workers(len(units))
        if workers <= 1 or len(units) == 1:
            return [self.execute_unit(u) for u in units]
        with ThreadPoolExecutor(max_workers=workers,
                                thread_name_prefix="xtable-sync") as pool:
            return list(pool.map(self.execute_unit, units))

    def prepare(self, writers: dict) -> None:
        """Install the planner's target writers for direct
        ``execute_unit`` calls — the fleet path drives units through its
        own shard queues instead of ``execute()``."""
        self._writers = dict(writers)

    def _auto_workers(self, n_units: int) -> int:
        """Pool width when the caller didn't pin one.

        Against a latency-bound store every unit spends its time waiting
        on round trips, so a wide pool overlaps them — the win the paper's
        "negligible overhead" claim rests on.  Against zero-RTT storage
        the units are pure CPU-bound metadata translation holding the GIL;
        threads beyond the hardware's parallelism only convoy on it (the
        measured sub-1x "concurrent" bootstrap regression), so the width
        is capped at the core count.
        """
        workers = min(DEFAULT_MAX_WORKERS, n_units)
        if not latency_bound(self.fs):
            workers = min(workers, max(1, os.cpu_count() or 1))
        return workers

    def execute_unit(self, unit: SyncUnit) -> SyncResult:
        t0 = time.perf_counter()
        # an instrumented fs tracks per-thread request counters, and a unit
        # runs entirely on this thread — scope them to get the unit's exact
        # storage census (the O(1)-target-reads guarantee is pinned on it)
        scoped = getattr(self.fs, "scoped", None)
        scope_cm = scoped() if scoped is not None else nullcontext()
        try:
            with scope_cm as scope:
                r = self._run_unit(unit)
        except Exception as e:  # a failing target must not poison others
            self.telemetry.bump("sync.errors")
            self.telemetry.record(unit.dataset, unit.target_format,
                                  "error", str(e))
            r = SyncResult(unit.dataset, unit.target_format, ERROR,
                           source_commit=unit.source_head, error=str(e))
        else:
            if scope is not None:
                r.storage_ops = scope.as_dict()
        r.elapsed_s = time.perf_counter() - t0
        return r

    # ------------------------------------------------------------- internals
    def _run_unit(self, unit: SyncUnit) -> SyncResult:
        if unit.mode == SKIP:
            self.telemetry.bump("sync.skipped")
            self.telemetry.record(unit.dataset, unit.target_format, "skip",
                                  unit.reason)
            return SyncResult(unit.dataset, unit.target_format, SKIP,
                              source_commit=unit.source_head)

        if unit.mode == ERROR:  # planning already failed this cell
            self.telemetry.bump("sync.errors")
            self.telemetry.record(unit.dataset, unit.target_format, "error",
                                  unit.reason)
            return SyncResult(unit.dataset, unit.target_format, ERROR,
                              source_commit=unit.source_head,
                              error=unit.reason or "planning failed")

        source = make_source(unit.source_format, self.fs, unit.base_path,
                             self.cache.index(unit.source_format,
                                              unit.base_path))
        target = self._writers.get((unit.base_path, unit.target_format)) \
            or make_target(unit.target_format, self.fs, unit.base_path,
                           manifest_compaction_threshold=self
                           .manifest_compaction_threshold)

        # transactional drain: the target's metadata is parsed once at the
        # first commit and threaded through the rest in memory, so an
        # N-commit unit costs O(N) writes and O(1) reads in table history
        txn = target.transaction() if (unit.transactional and
                                       hasattr(target, "transaction")) \
            else nullcontext()

        if unit.mode == FULL:
            with txn, self.telemetry.timed(unit.dataset, unit.target_format,
                                           "full", f"to {unit.source_head}"):
                snapshot = source.get_snapshot(unit.source_head)
                target.full_sync(snapshot)
            self.telemetry.bump("sync.full")
            return SyncResult(unit.dataset, unit.target_format, FULL,
                              1, unit.source_head, target_commits=1)

        changes = [source.get_changes(c) for c in unit.commits]
        if unit.coalesce and len(changes) > 1:
            changes = [fold_changes(changes)]
        n = 0
        with txn:
            for change in changes:
                label = (f"commits {change.lineage[0]}..{change.source_commit}"
                         if change.lineage else
                         f"commit {change.source_commit}")
                with self.telemetry.timed(unit.dataset, unit.target_format,
                                          "incremental", label):
                    target.incremental_sync(change)
                n += 1
        self.telemetry.bump("sync.incremental", n)
        last = unit.commits[-1] if unit.commits else unit.source_head
        return SyncResult(unit.dataset, unit.target_format,
                          INCREMENTAL, len(unit.commits), last,
                          target_commits=n)
