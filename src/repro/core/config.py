"""Sync configuration — the paper's Listing 2.

::

    sourceFormat: HUDI
    targetFormats:
      - DELTA
      - ICEBERG
    datasets:
      -
        tableBasePath: abfs://container@ac.dfs.core.windows.net/sales

Accepts YAML text, a file path, or a plain dict.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.lst.fs import strip_scheme

KNOWN_FORMATS = ("delta", "iceberg", "hudi")


@dataclass(frozen=True)
class DatasetConfig:
    table_base_path: str
    table_name: str | None = None

    @property
    def path(self) -> str:
        return strip_scheme(self.table_base_path)

    @property
    def name(self) -> str:
        return self.table_name or self.path.rstrip("/").rsplit("/", 1)[-1]


@dataclass(frozen=True)
class SyncConfig:
    source_format: str
    target_formats: tuple
    datasets: tuple
    incremental: bool = True      # prefer incremental, fall back to full

    def __post_init__(self):
        for f in (self.source_format, *self.target_formats):
            if f not in KNOWN_FORMATS:
                raise ValueError(f"unknown format {f!r}; known: {KNOWN_FORMATS}")
        if self.source_format in self.target_formats:
            raise ValueError("source format cannot also be a target")

    @staticmethod
    def from_dict(d: dict) -> "SyncConfig":
        datasets = tuple(
            DatasetConfig(x["tableBasePath"], x.get("tableName"))
            for x in d.get("datasets", []))
        return SyncConfig(
            source_format=d["sourceFormat"].lower(),
            target_formats=tuple(t.lower() for t in d["targetFormats"]),
            datasets=datasets,
            incremental=bool(d.get("incremental", True)))

    @staticmethod
    def from_yaml(text: str) -> "SyncConfig":
        import yaml
        return SyncConfig.from_dict(yaml.safe_load(text))

    @staticmethod
    def from_file(path: str) -> "SyncConfig":
        with open(path) as f:
            return SyncConfig.from_yaml(f.read())
