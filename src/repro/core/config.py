"""Sync configuration — the paper's Listing 2.

::

    sourceFormat: HUDI
    targetFormats:
      - DELTA
      - ICEBERG
    datasets:
      -
        tableBasePath: abfs://container@ac.dfs.core.windows.net/sales

Accepts YAML text, a file path, or a plain dict.  Optional knobs:

* ``incremental`` (default true) — prefer incremental, fall back to full.
* ``transactionalTargets`` (default true) — drain each sync unit inside one
  target transaction (target metadata parsed once, commits flushed with no
  re-reads); false restores the seed per-commit path.
* ``coalesceIncremental`` (default false) — fold the whole backlog into a
  single net target commit (freshness over 1:1 history fidelity).
* ``maxCommitsPerSync`` (default unlimited) — cap the commits one run
  applies; the next run continues from the recorded sync token.
* ``manifestCompactionThreshold`` (default off) — iceberg targets: when a
  commit would leave more than this many manifests in the manifest list
  (long incremental chains grow one small manifest per commit), fold them
  all into one, bounding snapshot-read amplification.
* ``storage`` (default local, no injection) — storage-backend behavior:
  any of ``rttMs`` / ``faultRate`` / ``ambiguousPutRate`` wraps the backend
  in a simulated object store; ``pipelineDepth`` / ``seed`` shape that
  simulation (honored on ``s3sim://`` even with no injection knobs set);
  ``retry: {maxAttempts, baseDelayMs, maxDelayMs}`` tunes the
  exponential-backoff retry layer.  The backend itself comes from the
  dataset URI scheme (``file://`` / ``mem://`` / ``s3sim://`` / plain
  path) via the storage registry.
* ``daemon`` — continuous-sync daemon scheduling (see ``core/daemon.py``):
  ``pollIntervalMs`` between watch cycles, ``maxCyclesIdle`` (stop after N
  consecutive idle cycles; default run forever), and
  ``backoff: {baseDelayMs, maxDelayMs, multiplier, jitter, seed}`` — the
  jittered per-table backoff applied when a table's probe or drain hits a
  (transient) storage error.
* ``fleet`` — sharded sync fleet (see ``core/fleet.py``): ``workers`` (> 1
  engages the fleet cycle path, as does setting a drain budget),
  ``shardStrategy`` (``hash`` |
  ``roundRobin``), ``stealThresholdMs`` (min queue age before an idle
  worker may steal a cell), ``urgencyHalfLifeMs`` (the commit-rate EWMA
  half-life behind urgency = backlog x rate), ``scheduler`` (``urgency`` |
  ``fifo``), ``maxUnitsPerCycle`` (per-cycle drain budget across all
  workers — the top-budget cells of the global scheduler ordering),
  and ``mode`` (``thread`` | ``process``).
* ``checkpoint`` — durable daemon checkpoint for crash-safe warm restarts
  (see ``core/checkpoint.py``): ``enabled``, ``path`` (store-local dir,
  default ``<first dataset>/_xtable/checkpoint``), ``intervalCycles``,
  ``retain`` (generations kept), ``minWindow`` (index entries kept beyond
  each table's pending lag).  The checkpoint is advisory — a restarted
  daemon re-verifies it against the live head, which always wins.
* ``health`` — per-table circuit breakers (see ``core/health.py``):
  ``enabled`` (default true), ``failureThreshold``, ``openCooldownMs``,
  ``halfOpenProbes``, ``quarantineAfter``, ``quarantineCooldownMs``.
* ``readPlane`` — snapshot-serving read plane (see ``serve/read_plane.py``):
  ``ttlMs`` (head-probe amortization window: at most one O(1) head probe
  per table per window, shared across every reader), ``maxSnapshots``
  (LRU bound on memoized head-keyed snapshots), ``statsCacheBytes``
  (budget for the immutable chunk-stats footer cache behind
  ``scan()`` predicate pushdown).
* ``catalog`` — catalog registration + atomic multi-table group publish
  (see ``lst/catalog/``): ``enabled``, ``path``, ``group`` (the dataset
  group this config's tables publish under), ``publishViews``
  (``all`` | ``source``), ``retain`` (manifest generations kept).

The consolidated reference for every block — defaults, camelCase keys,
and the semantics behind each knob — is ``docs/config.md``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.lst.storage import (RetryPolicy, StorageProfile, layer_fs, make_fs,
                               resolve_uri, scheme_of)

KNOWN_FORMATS = ("delta", "iceberg", "hudi")


@dataclass(frozen=True)
class DatasetConfig:
    table_base_path: str
    table_name: str | None = None

    @property
    def path(self) -> str:
        # registry-based resolution keeps the authority/bucket component,
        # so two buckets with the same key path cannot collide
        return resolve_uri(self.table_base_path)

    @property
    def name(self) -> str:
        return self.table_name or self.path.rstrip("/").rsplit("/", 1)[-1]


@dataclass(frozen=True)
class StorageOptions:
    """Storage-backend behavior: fault/latency injection + retry policy."""
    rtt_ms: float = 0.0
    fault_rate: float = 0.0
    ambiguous_put_rate: float = 0.0
    pipeline_depth: int = 16
    seed: int = 0
    retry_max_attempts: int = 5
    retry_base_delay_ms: float = 10.0
    retry_max_delay_ms: float = 1000.0

    def profile(self) -> StorageProfile | None:
        """A StorageProfile when any injection knob is set, else None."""
        if self.rtt_ms or self.fault_rate or self.ambiguous_put_rate:
            return StorageProfile(
                rtt_ms=self.rtt_ms, fault_rate=self.fault_rate,
                ambiguous_put_rate=self.ambiguous_put_rate,
                pipeline_depth=self.pipeline_depth, seed=self.seed)
        return None

    def retry_policy(self) -> RetryPolicy:
        return RetryPolicy(max_attempts=self.retry_max_attempts,
                           base_delay_s=self.retry_base_delay_ms / 1000.0,
                           max_delay_s=self.retry_max_delay_ms / 1000.0)

    @staticmethod
    def from_dict(d: dict) -> "StorageOptions":
        r = d.get("retry", {})
        return StorageOptions(
            rtt_ms=float(d.get("rttMs", 0.0)),
            fault_rate=float(d.get("faultRate", 0.0)),
            ambiguous_put_rate=float(d.get("ambiguousPutRate", 0.0)),
            pipeline_depth=int(d.get("pipelineDepth", 16)),
            seed=int(d.get("seed", 0)),
            retry_max_attempts=int(r.get("maxAttempts", 5)),
            retry_base_delay_ms=float(r.get("baseDelayMs", 10.0)),
            retry_max_delay_ms=float(r.get("maxDelayMs", 1000.0)))


@dataclass(frozen=True)
class DaemonOptions:
    """Continuous-sync daemon scheduling knobs (the ``daemon:`` block)."""
    poll_interval_ms: float = 1000.0
    max_cycles_idle: int | None = None     # None = run until stopped
    backoff_base_delay_ms: float = 100.0
    backoff_max_delay_ms: float = 30_000.0
    backoff_multiplier: float = 2.0
    backoff_jitter: float = 0.1            # +-fraction of the delay
    seed: int = 0                          # jitter RNG seed (determinism)

    def __post_init__(self):
        if self.poll_interval_ms < 0:
            raise ValueError("pollIntervalMs must be >= 0")
        if self.max_cycles_idle is not None and self.max_cycles_idle < 1:
            raise ValueError("maxCyclesIdle must be >= 1")

    def backoff_delay_s(self, failures: int) -> float:
        """Un-jittered backoff after ``failures`` consecutive errors."""
        d = self.backoff_base_delay_ms * \
            (self.backoff_multiplier ** max(0, failures - 1))
        return min(self.backoff_max_delay_ms, d) / 1000.0

    @staticmethod
    def from_dict(d: dict) -> "DaemonOptions":
        b = d.get("backoff", {})
        mci = d.get("maxCyclesIdle")
        return DaemonOptions(
            poll_interval_ms=float(d.get("pollIntervalMs", 1000.0)),
            max_cycles_idle=int(mci) if mci is not None else None,
            backoff_base_delay_ms=float(b.get("baseDelayMs", 100.0)),
            backoff_max_delay_ms=float(b.get("maxDelayMs", 30_000.0)),
            backoff_multiplier=float(b.get("multiplier", 2.0)),
            backoff_jitter=float(b.get("jitter", 0.1)),
            seed=int(b.get("seed", 0)))


@dataclass(frozen=True)
class FleetOptions:
    """Sharded sync fleet knobs (the ``fleet:`` block; see ``core/fleet.py``).

    ``workers > 1`` (or any ``maxUnitsPerCycle`` budget) switches the
    daemon's cycle from the serial per-dataset loop to the fleet path:
    probes and planning fan out over the worker pool, and the planned
    (dataset, target) cells drain through per-worker shard queues with
    work stealing, ordered by the lag-aware scheduler.
    """
    workers: int = 1
    shard_strategy: str = "hash"           # hash | round_robin
    steal_threshold_ms: float = 0.0        # min cell age before stealable
    urgency_half_life_ms: float = 60_000.0  # commit-rate EWMA half-life
    scheduler: str = "urgency"             # urgency | fifo
    max_units_per_cycle: int | None = None  # per-cycle drain budget (None = all)
    mode: str = "thread"                   # thread | process (FULL bootstraps)

    def __post_init__(self):
        if self.workers < 1:
            raise ValueError("fleet workers must be >= 1")
        if self.shard_strategy not in ("hash", "round_robin"):
            raise ValueError("shardStrategy must be 'hash' or 'roundRobin'")
        if self.scheduler not in ("urgency", "fifo"):
            raise ValueError("scheduler must be 'urgency' or 'fifo'")
        if self.mode not in ("thread", "process"):
            raise ValueError("fleet mode must be 'thread' or 'process'")
        if self.steal_threshold_ms < 0:
            raise ValueError("stealThresholdMs must be >= 0")
        if self.urgency_half_life_ms <= 0:
            raise ValueError("urgencyHalfLifeMs must be > 0")
        if self.max_units_per_cycle is not None \
                and self.max_units_per_cycle < 1:
            raise ValueError("maxUnitsPerCycle must be >= 1")

    @staticmethod
    def from_dict(d: dict) -> "FleetOptions":
        strategy = str(d.get("shardStrategy", "hash"))
        # accept camelCase (config idiom) and snake_case spellings
        strategy = {"roundrobin": "round_robin"}.get(
            strategy.replace("_", "").lower(), strategy)
        mupc = d.get("maxUnitsPerCycle")
        return FleetOptions(
            workers=int(d.get("workers", 1)),
            shard_strategy=strategy,
            steal_threshold_ms=float(d.get("stealThresholdMs", 0.0)),
            urgency_half_life_ms=float(d.get("urgencyHalfLifeMs", 60_000.0)),
            scheduler=str(d.get("scheduler", "urgency")).lower(),
            max_units_per_cycle=int(mupc) if mupc is not None else None,
            mode=str(d.get("mode", "thread")).lower())


@dataclass(frozen=True)
class CheckpointOptions:
    """Durable daemon checkpoint knobs (the ``checkpoint:`` block).

    The checkpoint is *advisory*: it only seeds the restarted daemon's
    in-memory state (sync tokens, metadata-index tail, backoff/health and
    commit-rate estimates) so the first cycle costs O(new commits) instead
    of a cold O(history) rebuild — the live table head is always
    re-verified and wins over anything the checkpoint claims (see
    ``core/checkpoint.py``).
    """
    enabled: bool = False
    # store-local path of the checkpoint dir; None derives
    # "<first dataset>/_xtable/checkpoint" so the default always lands in a
    # namespace the daemon can already write
    path: str | None = None
    interval_cycles: int = 1       # save at most every N non-idle cycles
    retain: int = 3                # generations kept (older ones pruned)
    # index entries checkpointed beyond each table's pending lag, so a
    # target that slipped a little further behind still resumes warm
    min_window: int = 4

    def __post_init__(self):
        if self.interval_cycles < 1:
            raise ValueError("checkpoint intervalCycles must be >= 1")
        if self.retain < 1:
            raise ValueError("checkpoint retain must be >= 1")
        if self.min_window < 1:
            raise ValueError("checkpoint minWindow must be >= 1")

    @staticmethod
    def from_dict(d: dict) -> "CheckpointOptions":
        return CheckpointOptions(
            enabled=bool(d.get("enabled", False)),
            path=d.get("path"),
            interval_cycles=int(d.get("intervalCycles", 1)),
            retain=int(d.get("retain", 3)),
            min_window=int(d.get("minWindow", 4)))


@dataclass(frozen=True)
class HealthOptions:
    """Per-table circuit-breaker knobs (the ``health:`` block).

    ``closed -> open -> half_open`` per table (see ``core/health.py``):
    ``failureThreshold`` consecutive probe/plan/drain failures open the
    breaker (the table is skipped — not even probed — until
    ``openCooldownMs`` passes), then one half-open trial cycle either
    closes it or re-opens; ``quarantineAfter`` consecutive opens move the
    table to ``quarantined`` — parked until ``quarantineCooldownMs`` (and
    excluded from ``stop(drain=True)``, so one poisoned table cannot hold
    the daemon's shutdown hostage).
    """
    enabled: bool = True
    failure_threshold: int = 5
    open_cooldown_ms: float = 60_000.0
    half_open_probes: int = 1          # successes to close from half-open
    quarantine_after: int = 3          # consecutive opens before quarantine
    quarantine_cooldown_ms: float = 3_600_000.0

    def __post_init__(self):
        if self.failure_threshold < 1:
            raise ValueError("health failureThreshold must be >= 1")
        if self.open_cooldown_ms < 0:
            raise ValueError("health openCooldownMs must be >= 0")
        if self.half_open_probes < 1:
            raise ValueError("health halfOpenProbes must be >= 1")
        if self.quarantine_after < 1:
            raise ValueError("health quarantineAfter must be >= 1")
        if self.quarantine_cooldown_ms < 0:
            raise ValueError("health quarantineCooldownMs must be >= 0")

    @staticmethod
    def from_dict(d: dict) -> "HealthOptions":
        return HealthOptions(
            enabled=bool(d.get("enabled", True)),
            failure_threshold=int(d.get("failureThreshold", 5)),
            open_cooldown_ms=float(d.get("openCooldownMs", 60_000.0)),
            half_open_probes=int(d.get("halfOpenProbes", 1)),
            quarantine_after=int(d.get("quarantineAfter", 3)),
            quarantine_cooldown_ms=float(
                d.get("quarantineCooldownMs", 3_600_000.0)))


@dataclass(frozen=True)
class ReadPlaneOptions:
    """Snapshot-serving read plane knobs (the ``readPlane:`` block).

    The read plane (``serve/read_plane.py``) serves immutable table
    snapshots keyed by head token with conditional-GET semantics:
    ``ttlMs`` bounds how stale a served token may be — within one window
    at most ONE head probe happens per table, shared by every reader;
    ``maxSnapshots`` bounds the LRU of memoized snapshots; and
    ``statsCacheBytes`` budgets the chunk-stats footer cache behind
    ``scan()``'s predicate pushdown (chunk files are write-once, so the
    footer cache never invalidates — only evicts).
    ``lateMaterialization`` (default on) makes predicated scans fetch in
    two phases through the CHK3 column index — predicate columns first,
    then only the projected columns of chunks whose row masks survived;
    off, a predicated scan fetches every needed column in one ranged
    round (projection pushdown itself stays on — it needs no knob, the
    results are byte-identical either way).
    """
    ttl_ms: float = 1000.0
    max_snapshots: int = 64
    stats_cache_bytes: int = 16 * 2**20
    late_materialization: bool = True

    def __post_init__(self):
        if self.ttl_ms < 0:
            raise ValueError("readPlane ttlMs must be >= 0")
        if self.max_snapshots < 1:
            raise ValueError("readPlane maxSnapshots must be >= 1")
        if self.stats_cache_bytes < 0:
            raise ValueError("readPlane statsCacheBytes must be >= 0")

    @staticmethod
    def from_dict(d: dict) -> "ReadPlaneOptions":
        return ReadPlaneOptions(
            ttl_ms=float(d.get("ttlMs", 1000.0)),
            max_snapshots=int(d.get("maxSnapshots", 64)),
            stats_cache_bytes=int(d.get("statsCacheBytes", 16 * 2**20)),
            late_materialization=bool(d.get("lateMaterialization", True)))


@dataclass(frozen=True)
class CatalogOptions:
    """Catalog publishing knobs (the ``catalog:`` block).

    With ``enabled`` the daemon registers every cleanly drained table in
    the catalog (``lst/catalog/``) and publishes each cycle's drained
    set as ONE atomic group commit, so cross-table readers resolving
    through the catalog never observe a half-synced dataset.  ``group``
    names the dataset group this config's tables publish under;
    ``publishViews`` selects which format views get pinned head tokens
    (``all`` also pins every target view — one O(1) probe plus a
    tail-only index refresh per target per publish; ``source`` pins only
    the source view at zero extra requests).  ``path`` defaults to
    ``<parent of first dataset>/_xtable/catalog``.
    """
    enabled: bool = False
    path: str | None = None
    group: str = "default"
    publish_views: str = "all"     # all | source
    retain: int = 8                # manifest generations kept

    def __post_init__(self):
        if not self.group:
            raise ValueError("catalog group must be non-empty")
        if self.publish_views not in ("all", "source"):
            raise ValueError("catalog publishViews must be 'all' or 'source'")
        if self.retain < 1:
            raise ValueError("catalog retain must be >= 1")

    @staticmethod
    def from_dict(d: dict) -> "CatalogOptions":
        return CatalogOptions(
            enabled=bool(d.get("enabled", False)),
            path=d.get("path"),
            group=str(d.get("group", "default")),
            publish_views=str(d.get("publishViews", "all")).lower(),
            retain=int(d.get("retain", 8)))


@dataclass(frozen=True)
class SyncConfig:
    source_format: str
    target_formats: tuple
    datasets: tuple
    incremental: bool = True      # prefer incremental, fall back to full
    # drain an N-commit backlog inside ONE target transaction (state read
    # once, every commit flushed without a re-read); off = seed per-commit
    # path, kept for benchmarking the difference
    transactional_targets: bool = True
    # fold the whole backlog into a single net target commit (freshness over
    # 1:1 history fidelity; per-commit lineage kept in the commit metadata)
    coalesce_incremental: bool = False
    # cap how many backlog commits one sync run applies (None = all); the
    # target advances to the cap and the next run continues from there
    max_commits_per_sync: int | None = None
    # iceberg targets: fold the manifest list into one manifest whenever a
    # commit would leave more than this many (None = never compact)
    manifest_compaction_threshold: int | None = None
    # storage-backend behavior (latency/fault injection, retry policy)
    storage: StorageOptions = field(default_factory=StorageOptions)
    # continuous-sync daemon scheduling (poll interval, idle stop, backoff)
    daemon: DaemonOptions = field(default_factory=DaemonOptions)
    # sharded sync fleet (workers > 1 engages the fleet cycle path)
    fleet: FleetOptions = field(default_factory=FleetOptions)
    # durable daemon checkpoint (crash-safe warm restarts)
    checkpoint: CheckpointOptions = field(default_factory=CheckpointOptions)
    # per-table circuit breakers (closed -> open -> half_open -> quarantined)
    health: HealthOptions = field(default_factory=HealthOptions)
    # snapshot-serving read plane (memoized head-keyed snapshots)
    read_plane: ReadPlaneOptions = field(default_factory=ReadPlaneOptions)
    # catalog registration + atomic multi-table group publish
    catalog: CatalogOptions = field(default_factory=CatalogOptions)

    def __post_init__(self):
        for f in (self.source_format, *self.target_formats):
            if f not in KNOWN_FORMATS:
                raise ValueError(f"unknown format {f!r}; known: {KNOWN_FORMATS}")
        if self.source_format in self.target_formats:
            raise ValueError("source format cannot also be a target")
        if self.max_commits_per_sync is not None \
                and self.max_commits_per_sync < 1:
            raise ValueError("maxCommitsPerSync must be >= 1")
        if self.manifest_compaction_threshold is not None \
                and self.manifest_compaction_threshold < 1:
            raise ValueError("manifestCompactionThreshold must be >= 1")

    @staticmethod
    def from_dict(d: dict) -> "SyncConfig":
        datasets = tuple(
            DatasetConfig(x["tableBasePath"], x.get("tableName"))
            for x in d.get("datasets", []))
        mcps = d.get("maxCommitsPerSync")
        mct = d.get("manifestCompactionThreshold")
        return SyncConfig(
            source_format=d["sourceFormat"].lower(),
            target_formats=tuple(t.lower() for t in d["targetFormats"]),
            datasets=datasets,
            incremental=bool(d.get("incremental", True)),
            transactional_targets=bool(d.get("transactionalTargets", True)),
            coalesce_incremental=bool(d.get("coalesceIncremental", False)),
            max_commits_per_sync=int(mcps) if mcps is not None else None,
            manifest_compaction_threshold=int(mct) if mct is not None
            else None,
            storage=StorageOptions.from_dict(d.get("storage", {})),
            daemon=DaemonOptions.from_dict(d.get("daemon", {})),
            fleet=FleetOptions.from_dict(d.get("fleet", {})),
            checkpoint=CheckpointOptions.from_dict(d.get("checkpoint", {})),
            health=HealthOptions.from_dict(d.get("health", {})),
            read_plane=ReadPlaneOptions.from_dict(d.get("readPlane", {})),
            catalog=CatalogOptions.from_dict(d.get("catalog", {})))

    def build_fs(self, telemetry=None, *, sleep=None):
        """Construct the storage stack this config describes.

        The backend comes from the dataset URI scheme through the registry
        (all datasets of one config must agree on a scheme — they share one
        FileSystem for the run); it is then layered per ``storage``:
        latency/fault simulation when injection knobs are set, the
        exponential-backoff retry layer, and the instrumented wrapper
        feeding ``telemetry`` request/byte counters.  ``sleep`` replaces
        the retry layer's backoff sleeper — the daemon passes its injected
        clock's ``sleep`` so retry backoff never wall-sleeps in tests or
        benchmarks.
        """
        schemes = {scheme_of(ds.table_base_path) for ds in self.datasets}
        schemes.discard(None)       # plain paths ride the local backend
        if len(schemes) > 1:
            raise ValueError(f"datasets span multiple storage schemes: "
                             f"{sorted(schemes)}")
        scheme = schemes.pop() if schemes else "file"
        profile = self.storage.profile()
        if scheme == "s3sim":
            # the s3sim factory owns the simulation wrapper; hand it every
            # simulation knob (pipelineDepth/seed included, even with no
            # fault/latency injection) instead of double-wrapping
            from dataclasses import asdict
            base = make_fs("s3sim", **asdict(profile or StorageProfile(
                pipeline_depth=self.storage.pipeline_depth,
                seed=self.storage.seed)))
            profile = None
        else:
            base = make_fs(scheme)
        return layer_fs(base, profile=profile,
                        retry=self.storage.retry_policy(),
                        telemetry=telemetry, sleep=sleep)

    @staticmethod
    def from_yaml(text: str) -> "SyncConfig":
        import yaml
        return SyncConfig.from_dict(yaml.safe_load(text))

    @staticmethod
    def from_file(path: str) -> "SyncConfig":
        with open(path) as f:
            return SyncConfig.from_yaml(f.read())
