"""Sync configuration — the paper's Listing 2.

::

    sourceFormat: HUDI
    targetFormats:
      - DELTA
      - ICEBERG
    datasets:
      -
        tableBasePath: abfs://container@ac.dfs.core.windows.net/sales

Accepts YAML text, a file path, or a plain dict.  Optional knobs:

* ``incremental`` (default true) — prefer incremental, fall back to full.
* ``transactionalTargets`` (default true) — drain each sync unit inside one
  target transaction (target metadata parsed once, commits flushed with no
  re-reads); false restores the seed per-commit path.
* ``coalesceIncremental`` (default false) — fold the whole backlog into a
  single net target commit (freshness over 1:1 history fidelity).
* ``maxCommitsPerSync`` (default unlimited) — cap the commits one run
  applies; the next run continues from the recorded sync token.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.lst.fs import strip_scheme

KNOWN_FORMATS = ("delta", "iceberg", "hudi")


@dataclass(frozen=True)
class DatasetConfig:
    table_base_path: str
    table_name: str | None = None

    @property
    def path(self) -> str:
        return strip_scheme(self.table_base_path)

    @property
    def name(self) -> str:
        return self.table_name or self.path.rstrip("/").rsplit("/", 1)[-1]


@dataclass(frozen=True)
class SyncConfig:
    source_format: str
    target_formats: tuple
    datasets: tuple
    incremental: bool = True      # prefer incremental, fall back to full
    # drain an N-commit backlog inside ONE target transaction (state read
    # once, every commit flushed without a re-read); off = seed per-commit
    # path, kept for benchmarking the difference
    transactional_targets: bool = True
    # fold the whole backlog into a single net target commit (freshness over
    # 1:1 history fidelity; per-commit lineage kept in the commit metadata)
    coalesce_incremental: bool = False
    # cap how many backlog commits one sync run applies (None = all); the
    # target advances to the cap and the next run continues from there
    max_commits_per_sync: int | None = None

    def __post_init__(self):
        for f in (self.source_format, *self.target_formats):
            if f not in KNOWN_FORMATS:
                raise ValueError(f"unknown format {f!r}; known: {KNOWN_FORMATS}")
        if self.source_format in self.target_formats:
            raise ValueError("source format cannot also be a target")
        if self.max_commits_per_sync is not None \
                and self.max_commits_per_sync < 1:
            raise ValueError("maxCommitsPerSync must be >= 1")

    @staticmethod
    def from_dict(d: dict) -> "SyncConfig":
        datasets = tuple(
            DatasetConfig(x["tableBasePath"], x.get("tableName"))
            for x in d.get("datasets", []))
        mcps = d.get("maxCommitsPerSync")
        return SyncConfig(
            source_format=d["sourceFormat"].lower(),
            target_formats=tuple(t.lower() for t in d["targetFormats"]),
            datasets=datasets,
            incremental=bool(d.get("incremental", True)),
            transactional_targets=bool(d.get("transactionalTargets", True)),
            coalesce_incremental=bool(d.get("coalesceIncremental", False)),
            max_commits_per_sync=int(mcps) if mcps is not None else None)

    @staticmethod
    def from_yaml(text: str) -> "SyncConfig":
        import yaml
        return SyncConfig.from_dict(yaml.safe_load(text))

    @staticmethod
    def from_file(path: str) -> "SyncConfig":
        with open(path) as f:
            return SyncConfig.from_yaml(f.read())
