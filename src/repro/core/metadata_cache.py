"""Shared incremental metadata index (the cache stage of plan -> execute).

The seed syncer re-replayed the source log per inspected commit
(``handle.snapshot(commit)`` inside ``get_changes``), making an N-commit
incremental backlog O(N^2) in log-replay work — per *target*.  This module
replaces that with a single-pass index: each table's log is replayed exactly
once (``handle.replay()``), and every ``snapshot(commit)`` / ``changes(commit)``
any planner or executor asks for is served from that one pass.  The index is
shared across all targets of a dataset, and across datasets when they alias
the same table.

Thread-safety: executor workers for the targets of one dataset hit the same
index concurrently; the build happens once under a lock and the built
structures are read-only afterwards (snapshot materializations are memoized
under the same lock).
"""

from __future__ import annotations

import threading

from repro.lst.schema import CommitEntry, TableState
from repro.lst.table import FORMATS


class TableMetadataIndex:
    """One-replay commit index over a single LST handle.

    * ``head()`` is cheap (a directory listing / pointer read) and never
      triggers a replay — SKIP planning stays O(1).
    * ``entry(commit)`` / ``versions()`` / ``state_at(commit)`` build the
      index on first use; ``replays`` counts how many full log replays have
      happened (the instrumentation the O(commits) guarantee is tested by).
    """

    def __init__(self, handle):
        self.handle = handle
        self.replays = 0          # full log replays
        self.tail_replays = 0     # tail-only (since=...) refreshes
        self._lock = threading.RLock()
        self._built_head: str | None = None
        self._base: TableState | None = None
        self._order: list[str] = []
        self._entries: dict[str, CommitEntry] = {}
        self._state_memo: dict[str, TableState] = {}
        # per-cycle head hint (see probe()/hint_head()/end_cycle()):
        # _hint_token/_hint_state hold a just-probed head; _built_token is
        # the token the index was last refreshed AGAINST (None when the
        # index was last refreshed by its own head read)
        self._hint_token: str | None = None
        self._hint_state = None
        self._built_token: str | None = None

    # ------------------------------------------------------------- building
    def head(self) -> str:
        """The head commit id.

        Under a consumed per-cycle hint this is served from the index (the
        hinted refresh already read the log tail); otherwise it is one
        storage probe (``handle.current_version()``) — for iceberg a full
        metadata-discovery round, which is exactly what the hint removes.
        """
        with self._lock:
            hinted = self._hint_token
        if hinted:
            self.refresh()
            with self._lock:
                if self._built_token == hinted and \
                        self._built_head is not None:
                    return self._built_head
        return self.handle.current_version()

    # ------------------------------------------------- per-cycle head hints
    def probe(self) -> str:
        """ONE-request head probe that doubles as this cycle's head hint.

        Returns the opaque head token (what ``head_token()`` returns) and
        memoizes the probe's raw payload — delta: the head version number,
        iceberg: the hinted metadata-file version, hudi: the parsed
        completed-instant listing — so the planner's ``current_commit()``
        and this index's ``refresh()`` consume the SAME probe instead of
        re-reading the source head ~3x per changed cycle.  The hint is
        scoped to one daemon cycle: callers must ``end_cycle()`` when the
        cycle's drain finishes (refresh() is the one staleness point, and
        a lingering hint would pin it to a past head forever).
        """
        probe_fn = getattr(self.handle, "head_probe", None)
        if probe_fn is not None:
            token, state = probe_fn()
        else:
            tok_fn = getattr(self.handle, "head_token", None)
            token = tok_fn() if tok_fn is not None \
                else self.handle.current_version()
            state = None
        with self._lock:
            self._hint_token, self._hint_state = token, state
        return token

    def hint_head(self, token: str | None) -> None:
        """Install an externally probed head token as this cycle's hint
        (planner-facing; a daemon that already ran ``probe()`` on this
        index is a no-op).  Without the probe's raw payload the hinted
        refresh still replays the tail, it just cannot skip the head
        listing — the token alone still collapses the *repeat* head reads.
        """
        with self._lock:
            if token and token != self._hint_token:
                self._hint_token, self._hint_state = token, None

    def end_cycle(self) -> None:
        """Drop the per-cycle head hint (idempotent)."""
        with self._lock:
            self._hint_token = self._hint_state = None

    def ensure_built(self) -> "TableMetadataIndex":
        """Build from ONE log replay; no staleness check once built.

        Per-commit queries during a sync run must not re-read the table head
        (for iceberg that is a full metadata-JSON parse) hundreds of times —
        ``refresh()`` is the explicit staleness point, and a missing commit
        triggers one refresh attempt before failing.
        """
        with self._lock:
            if self._built_head is None:
                if self._hint_token:
                    self._refresh_hinted(self._hint_token, self._hint_state)
                else:
                    self._rebuild()
            return self

    def refresh(self) -> "TableMetadataIndex":
        """Refresh if (and only if) the table head moved since the build.

        A moved head replays only the NEW tail commits
        (``handle.replay(since=built_head, seed=...)``) and appends them to
        the index — O(new commits), not O(history).  A full rebuild happens
        only when there is no index yet, or when the anchor commit vanished
        from the log (vacuum / divergent rewrite).

        Under a per-cycle head hint (``probe()`` / ``hint_head()``) the
        staleness check costs ZERO storage requests: a hint matching the
        token the index was last refreshed against is a no-op, and a moved
        hint feeds the probe's payload straight into ``replay(probe=...)``
        so even the tail replay skips head rediscovery.  The new built head
        comes from the replayed entries themselves — no separate head read.
        """
        with self._lock:
            if self._hint_token:
                if self._built_token == self._hint_token:
                    return self
                return self._refresh_hinted(self._hint_token,
                                            self._hint_state)
            head = self.handle.current_version()
            self._built_token = None
            if self._built_head == head:
                return self
            if self._built_head is None:
                self._rebuild()
                return self
            try:
                _, entries = self.handle.replay(
                    since=self._built_head,
                    seed=self._entries.get(self._built_head))
            except (KeyError, FileNotFoundError, ValueError):
                self._rebuild()
                return self
            self._splice(entries)
            self._built_head = head
            return self

    def refresh_to(self, token: str, state=None) -> "TableMetadataIndex":
        """Single-flight refresh against an already-probed head ``token``.

        The read plane's building block: N concurrent readers who all saw
        the same probed token race here, and the RLock serializes them —
        the first one in pays the (tail-only) replay, every later one
        finds ``_built_token == token`` and returns at ZERO storage
        requests.  ``state`` is the probe's raw payload when the caller
        has it (``head_probe``), letting the replay skip head rediscovery
        exactly like the daemon's hinted refresh.

        The token is left installed as the index's head hint — the probe
        IS the head read, and the next ``refresh_to``/``refresh`` against
        the same token stays free.  A co-located daemon is unaffected:
        its own ``probe()`` overwrites the hint at cycle start and
        ``end_cycle()`` clears it.
        """
        with self._lock:
            if self._built_token == token:
                return self
            if state is None and self._hint_token == token:
                # keep the probe's memoized raw payload — a bare token
                # must not downgrade a richer hint for the same head
                state = self._hint_state
            self._hint_token, self._hint_state = token, state
            return self._refresh_hinted(token, state)

    def pinned_state(self) -> tuple[str, TableState]:
        """``(built_head, state_at(built_head))`` as one atomic pair.

        The snapshot-pinning read: the state is materialized from the
        index's memo under the lock (zero storage requests once built),
        and the returned ``TableState`` is immutable by construction —
        later refreshes append new entries and memoize new states, they
        never mutate one already handed out.
        """
        with self._lock:
            self.ensure_built()
            head = self._built_head
            if head is None:
                raise FileNotFoundError("table has no commits to pin")
            return head, self.state_at(head)

    def _refresh_hinted(self, token: str, state) -> "TableMetadataIndex":
        """Refresh against a probed head: the probe IS the head read."""
        if self._built_head is None:
            self._rebuild(probe=state)
            self._built_token = token
            return self
        try:
            _, entries = self._replay(since=self._built_head,
                                      seed=self._entries.get(self._built_head),
                                      probe=state)
        except (KeyError, FileNotFoundError, ValueError):
            self._rebuild(probe=state)
            self._built_token = token
            return self
        self._splice(entries)
        if entries:
            self._built_head = entries[-1].version
        self._built_token = token
        return self

    def _splice(self, entries) -> None:
        self.tail_replays += 1
        for e in entries:
            if e.version not in self._entries:
                self._order.append(e.version)
            self._entries[e.version] = e

    def _replay(self, *, since=None, seed=None, probe=None):
        if probe is None:   # duck-typed handles need not accept probe=
            return self.handle.replay(since=since, seed=seed)
        return self.handle.replay(since=since, seed=seed, probe=probe)

    def _rebuild(self, probe=None) -> None:
        base, entries = self._replay(probe=probe)
        self.replays += 1
        self._base = base
        self._order = [e.version for e in entries]
        self._entries = {e.version: e for e in entries}
        self._state_memo = {}
        # the head falls out of the replay itself (last entry / the base
        # state) — reading it separately would be one more round trip AND
        # racy against a writer landing between the two reads
        if entries:
            self._built_head = entries[-1].version
        elif base is not None:
            self._built_head = base.version
        else:
            self._built_head = self.handle.current_version()

    # ------------------------------------------------- checkpoint seeding
    def snapshot_seed(self, window: int) -> tuple[TableState, list[CommitEntry]] | None:
        """The warm-restart seed: the folded state at an anchor ``window``
        commits behind the built head, plus the entries from the anchor
        (inclusive) to the head.

        ``restore_seed`` on a fresh index re-installs exactly this — enough
        for a restarted daemon to serve ``state_at(head)`` with zero
        storage reads and ``get_commits_since(token)`` for any token inside
        the window, while the next ``refresh()`` replays only the commits
        that landed *after* the checkpoint (O(new), never O(history)).
        Returns ``None`` when the index was never built or holds no entries
        (a cold build on an empty table is already cheap).
        """
        with self._lock:
            if self._built_head is None or not self._order:
                return None
            k = len(self._order) - min(len(self._order), max(1, window))
            anchor = self._order[k]
            # RLock: state_at's fold happens under this same lock, and the
            # anchor is indexed, so this triggers no storage requests
            base = self.state_at(anchor)
            entries = [self._entries[v] for v in self._order[k:]]
            return base, entries

    def restore_seed(self, base: TableState,
                     entries: list[CommitEntry]) -> bool:
        """Seed a fresh index from a checkpoint (inverse of
        ``snapshot_seed``); refuses on a live index — real replays win.

        ``base`` is the state AT ``entries[0]``'s commit, so the fold in
        ``state_at`` re-applies that entry onto its own resulting state —
        idempotent (adds re-assign the same file by path, removes pop
        already-absent keys).  The seed is advisory: the next ``refresh()``
        replays the tail since the seeded head against the LIVE table, and
        a head the log no longer reaches from our anchor (vacuumed /
        divergent rewrite / behind the anchor) falls back to a full
        rebuild — a stale checkpoint can cost a rebuild, never a wrong
        splice.
        """
        with self._lock:
            if self._built_head is not None or not entries:
                return False
            self._base = base
            self._order = [e.version for e in entries]
            self._entries = {e.version: e for e in entries}
            self._state_memo = {}
            self._built_head = entries[-1].version
            self._built_token = None
            return True

    # -------------------------------------------------------------- queries
    def versions(self) -> list[str]:
        self.refresh()
        return list(self._order)

    def has(self, commit: str) -> bool:
        self.ensure_built()
        if commit in self._entries:
            return True
        return commit in self.refresh()._entries

    def entry(self, commit: str) -> CommitEntry:
        self.ensure_built()
        if commit not in self._entries:
            self.refresh()
        return self._entries[commit]

    def state_at(self, commit: str | None = None) -> TableState:
        """Materialize ``snapshot(commit)`` by folding indexed entries.

        Folds from the nearest earlier memoized state (or the replay base),
        so repeated asks — every target wants the head snapshot — cost one
        dict fold total, and zero further file reads.
        """
        if commit is None:
            self.refresh()
        else:
            self.ensure_built()
        with self._lock:
            if commit is None:
                commit = self._built_head
            if self._base is not None and commit == self._base.version:
                return self._base
            if commit in self._state_memo:
                return self._state_memo[commit]
            if commit not in self._entries:
                self.refresh()
            if commit not in self._entries:
                raise KeyError(f"commit {commit} not in indexed history")
            upto = self._order.index(commit)
            # nearest memoized prefix to fold from
            start, files = -1, dict(self._base.files) if self._base else {}
            for i in range(upto - 1, -1, -1):
                v = self._order[i]
                if v in self._state_memo:
                    start, files = i, dict(self._state_memo[v].files)
                    break
            for i in range(start + 1, upto + 1):
                e = self._entries[self._order[i]]
                for p in e.removes:
                    files.pop(p, None)
                for f in e.adds:
                    files[f.path] = f
            e = self._entries[commit]
            st = TableState(self.handle.format, commit, e.timestamp_ms,
                            e.schema, e.partition_spec, files,
                            dict(e.properties))
            self._state_memo[commit] = st
            return st


class MetadataCache:
    """(format, base_path) -> TableMetadataIndex, shared across a sync run.

    All targets of a dataset (and all datasets of a config) resolve their
    source questions through one cache instance, which is what turns the
    per-target O(commits^2) replay work into one O(commits) pass per table.
    """

    def __init__(self, fs):
        self.fs = fs
        self._lock = threading.Lock()
        self._indexes: dict[tuple[str, str], TableMetadataIndex] = {}

    def index(self, fmt: str, base_path: str) -> TableMetadataIndex:
        key = (fmt, base_path)
        with self._lock:
            idx = self._indexes.get(key)
            if idx is None:
                idx = TableMetadataIndex(FORMATS[fmt].open(self.fs, base_path))
                self._indexes[key] = idx
            return idx

    def peek(self, fmt: str, base_path: str) -> TableMetadataIndex | None:
        """The cached index if one exists — never opens the handle (the
        daemon's end-of-cycle hint cleanup must not fail on a table whose
        probe already failed to open)."""
        with self._lock:
            return self._indexes.get((fmt, base_path))

    def total_replays(self) -> int:
        with self._lock:
            return sum(i.replays for i in self._indexes.values())
