"""Sharded sync fleet: N workers over the (dataset, target) cell space.

The paper's pitch — translation overhead stays negligible as tables and
targets multiply — breaks down for a single-threaded daemon long before
the 10k-table regime the comparative studies describe: one slow table's
round trips serialize behind every other table's.  This module shards the
work across a fleet of workers while keeping every correctness property of
the serial daemon (one probe per table per cycle, shared metadata cache,
per-table backoff, atomic per-cell commits):

* **Sharding** — each planned (dataset, target) cell is assigned to a
  worker's queue by ``shardStrategy``: ``hash`` (stable across cycles, so
  a table's cells keep hitting the same worker and its warm caches) or
  ``round_robin`` (uniform spread for pathological key distributions).
* **Work stealing** — a worker whose queue runs dry pops cells from the
  *tail* of the longest remaining queue (the victim keeps its most urgent
  head), so one shard stalling on a throttled store never idles the rest
  of the fleet.  ``stealThresholdMs`` sets the minimum time a cell must
  have sat queued before it may be stolen.
* **Lag-aware scheduling** — cells drain most-urgent-first, where
  urgency = backlog-in-commits x observed commit rate.  The rate is a
  per-table exponentially-weighted moving average (half-life
  ``urgencyHalfLifeMs``) fed each cycle from what the daemon's watch
  phase observed, so under a ``maxUnitsPerCycle`` drain budget or
  ``maxCommitsPerSync`` backpressure the hot tables are always first in
  line and cold tables cannot crowd them out.
* **Worker modes** — ``thread`` (the default) overlaps the round trips
  that dominate incremental drains against object stores; ``process``
  routes FULL bootstraps through a process pool for CPU-bound translation
  work.  Process mode requires a plain local filesystem (the work items
  must be picklable and the store reachable from a child process), and
  only pays off when cores are actually available — on a small container
  the thread mode measures faster, which is why it is the default.

The daemon (``core/daemon.py``) owns watch state, backoff and reporting;
:class:`SyncFleet` owns the pool, the queues, the scheduler, and the drain
loop.  Determinism: the scheduler's ordering is a pure function of the
observed trace with lexicographic tie-breaks, and the idle-cycle cost pin
(exactly one probe request per table) holds for every worker count.
"""

from __future__ import annotations

import threading
import zlib
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

from repro.core.config import FleetOptions
from repro.core.plan import FULL, SyncUnit

__all__ = ["FleetOptions", "CommitRateEstimator", "LagAwareScheduler",
           "SyncFleet", "FleetDrainOutcome"]

# floor for the per-table commit rate inside the urgency product: a table
# never observed committing still ranks by backlog instead of dropping to
# urgency 0 (which would starve FULL bootstraps under a drain budget)
MIN_RATE = 1e-6
# guards the instantaneous-rate division when two observations land on the
# same clock reading (ManualClock cycles that never advance time)
_MIN_DT_S = 1e-3


class CommitRateEstimator:
    """Per-table EWMA of the observed commit rate, in commits/second.

    ``observe(key, commits, now)`` is called once per cycle per table with
    the number of *new* source commits the watch phase saw.  The previous
    estimate decays by ``0.5 ** (dt / half_life)`` and the instantaneous
    rate ``commits / dt`` is blended in with the complementary weight, so
    a table that goes quiet halves its rate every half-life and a burst
    moves the estimate quickly without erasing history.  Thread-safe;
    deterministic given the same observation trace.
    """

    def __init__(self, half_life_s: float):
        if half_life_s <= 0:
            raise ValueError("half_life_s must be > 0")
        self.half_life_s = float(half_life_s)
        self._lock = threading.Lock()
        self._rates: dict[str, tuple[float, float]] = {}  # key -> (rate, t)

    def observe(self, key: str, commits: int, now: float) -> float:
        with self._lock:
            prev = self._rates.get(key)
            if prev is None:
                # first sighting: this cycle's burst is the best guess
                rate = float(commits)
            else:
                rate0, last = prev
                dt = max(now - last, _MIN_DT_S)
                decay = 0.5 ** (dt / self.half_life_s)
                rate = decay * rate0 + (1.0 - decay) * (commits / dt)
            self._rates[key] = (rate, now)
            return rate

    def rate(self, key: str, now: float) -> float:
        """Current estimate, decayed to ``now`` (0.0 for unseen tables)."""
        with self._lock:
            prev = self._rates.get(key)
            if prev is None:
                return 0.0
            rate, last = prev
            return rate * 0.5 ** (max(now - last, 0.0) / self.half_life_s)

    # ----------------------------------------------------- checkpointing
    def export(self) -> dict:
        """JSON-ready ``key -> [rate, observed_at]`` for the daemon
        checkpoint (observed_at is injected-clock time)."""
        with self._lock:
            return {k: [r, t] for k, (r, t) in self._rates.items()}

    def restore(self, rates: dict) -> None:
        """Install checkpointed rates for tables not yet observed live
        (fresh observations always win over the checkpoint)."""
        with self._lock:
            for k, v in (rates or {}).items():
                self._rates.setdefault(k, (float(v[0]), float(v[1])))


class LagAwareScheduler:
    """Orders sync cells most-urgent-first: urgency = backlog x commit rate.

    ``backlog`` is the unit's full commits-behind count (pre
    ``maxCommitsPerSync`` cap; FULL bootstraps count as 1), and the rate
    comes from :class:`CommitRateEstimator` floored at ``MIN_RATE`` so
    never-observed tables still rank by backlog.  Ties break
    lexicographically on (dataset, target) — the ordering is a pure
    function of the observed trace.  ``kind="fifo"`` preserves plan order
    (the comparison arm benchmarks and tests use).
    """

    def __init__(self, half_life_s: float, kind: str = "urgency"):
        if kind not in ("urgency", "fifo"):
            raise ValueError("scheduler kind must be 'urgency' or 'fifo'")
        self.kind = kind
        self.rates = CommitRateEstimator(half_life_s)

    def observe(self, key: str, commits: int, now: float) -> float:
        return self.rates.observe(key, commits, now)

    def urgency(self, unit: SyncUnit, now: float) -> float:
        backlog = max(unit.backlog, len(unit.commits),
                      1 if unit.mode == FULL else 0)
        rate = max(self.rates.rate(unit.base_path, now), MIN_RATE)
        return backlog * rate

    def order(self, units: list, now: float) -> list:
        if self.kind == "fifo":
            return list(units)
        return sorted(units, key=lambda u: (-self.urgency(u, now),
                                            u.dataset, u.target_format))


def _process_run_unit(payload):
    """Process-pool entry point: run one picklable FULL unit against a
    fresh local filesystem in the child (no shared cache — the CPU-bound
    translation is the point, and a FULL bootstrap replays the source
    once either way)."""
    unit, mct = payload
    from repro.core.executor import SyncExecutor
    from repro.lst.storage.local import LocalFS
    ex = SyncExecutor(LocalFS(), max_workers=1,
                      manifest_compaction_threshold=mct)
    return ex.execute_unit(unit)


@dataclass
class _Cell:
    """One queued (dataset, target) drain item."""
    idx: int                 # position in the ordered dispatch list
    unit: SyncUnit
    enqueued_at: float = 0.0


class _ShardQueue:
    """One worker's deque: the owner pops the urgent head, thieves take
    the tail (the victim keeps its hottest work)."""

    def __init__(self):
        self._dq: deque = deque()
        self._lock = threading.Lock()

    def push(self, cell: _Cell) -> None:
        with self._lock:
            self._dq.append(cell)

    def pop_front(self):
        with self._lock:
            return self._dq.popleft() if self._dq else None

    def steal_back(self, now: float, threshold_s: float):
        with self._lock:
            if not self._dq:
                return None
            if now - self._dq[-1].enqueued_at < threshold_s:
                return None
            return self._dq.pop()

    def __len__(self) -> int:
        with self._lock:
            return len(self._dq)

    def drain_remaining(self) -> list:
        with self._lock:
            left, self._dq = list(self._dq), deque()
            return left


@dataclass
class FleetDrainOutcome:
    """What one fleet drain pass did."""
    results: list = field(default_factory=list)   # SyncResult | None, aligned
                                                  # with the ordered units
    deferred: list = field(default_factory=list)  # units the budget cut
    steals: int = 0                               # cells run off-shard


class SyncFleet:
    """The worker pool + shard queues + scheduler behind a fleet daemon.

    Owns no watch state: the daemon hands it callables to fan out (probe /
    plan phases) and ordered units to drain; the fleet returns aligned
    results.  The pool is lazy and persistent across cycles; ``close()``
    (also called by ``__del__``) releases it.
    """

    def __init__(self, opts: FleetOptions, clock):
        self.opts = opts
        self.clock = clock
        self.scheduler = LagAwareScheduler(
            opts.urgency_half_life_ms / 1000.0, opts.scheduler)
        self.steals = 0              # lifetime, across cycles
        self._rr = 0                 # round-robin cursor
        self._lock = threading.Lock()
        self._pool: ThreadPoolExecutor | None = None
        self._procs = None           # lazy ProcessPoolExecutor (process mode)

    # ---------------------------------------------------------------- pool
    def _thread_pool(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.opts.workers,
                    thread_name_prefix="xtable-fleet")
            return self._pool

    def _process_pool(self):
        with self._lock:
            if self._procs is None:
                from concurrent.futures import ProcessPoolExecutor
                self._procs = ProcessPoolExecutor(
                    max_workers=self.opts.workers)
            return self._procs

    def close(self) -> None:
        """Release the worker pools (recreated lazily on next use)."""
        with self._lock:
            pool, self._pool = self._pool, None
            procs, self._procs = self._procs, None
        if pool is not None:
            pool.shutdown(wait=True)
        if procs is not None:
            procs.shutdown(wait=True)

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    # ----------------------------------------------------------- fan-out
    def map(self, fn, items: list) -> list:
        """Run ``fn`` over ``items`` on the pool; returns aligned
        ``(result, error)`` pairs — a failing item never poisons the rest
        (the per-table error isolation the serial daemon has)."""
        def one(item):
            try:
                return fn(item), None
            except Exception as e:
                return None, e
        if not items:
            return []
        if self.opts.workers <= 1 or len(items) == 1:
            return [one(it) for it in items]
        return list(self._thread_pool().map(one, items))

    # ---------------------------------------------------------- sharding
    def shard_of(self, unit: SyncUnit) -> int:
        if self.opts.shard_strategy == "round_robin":
            with self._lock:
                shard = self._rr % self.opts.workers
                self._rr += 1
            return shard
        key = f"{unit.base_path}\x00{unit.target_format}".encode()
        return zlib.crc32(key) % self.opts.workers  # stable across processes

    # ------------------------------------------------------------- drain
    def drain(self, units: list, executor, *,
              budget: int | None = None) -> FleetDrainOutcome:
        """Drain ordered ``units`` through the shard queues.

        ``units`` must already be in scheduler order (most urgent first);
        each worker consumes its own queue front-to-back, stealing from
        the longest other queue when dry.  ``budget`` caps how many cells
        the whole fleet executes this pass (``maxUnitsPerCycle``); cells
        past the budget come back in ``deferred``.  Results align with
        ``units`` (``None`` for deferred cells).
        """
        out = FleetDrainOutcome(results=[None] * len(units))
        if not units:
            return out
        if budget is None:
            budget = len(units)
        # the budget decides WHICH cells run by the *global* ordering,
        # not just how many: trim to the top-``budget`` before sharding,
        # so an urgent cell can never lose its slot to a colder one that
        # happened to land on a less-contended shard queue
        run_units = units[:budget]
        out.deferred.extend(units[budget:])
        queues = [_ShardQueue() for _ in range(self.opts.workers)]
        now = self.clock.now()
        for i, u in enumerate(run_units):
            queues[self.shard_of(u)].push(_Cell(i, u, enqueued_at=now))

        state_lock = threading.Lock()
        state = {"budget": budget, "steals": 0}

        def take_budget() -> bool:
            with state_lock:
                if state["budget"] <= 0:
                    return False
                state["budget"] -= 1
                return True

        def give_back() -> None:
            with state_lock:
                state["budget"] += 1

        def steal(wid: int):
            # richest victim first; the tail steal leaves the victim its
            # most urgent head
            order = sorted((q for i, q in enumerate(queues) if i != wid),
                           key=len, reverse=True)
            thr = self.opts.steal_threshold_ms / 1000.0
            for q in order:
                cell = q.steal_back(self.clock.now(), thr)
                if cell is not None:
                    return cell
            return None

        def worker(wid: int) -> None:
            while True:
                if not take_budget():
                    return
                cell = queues[wid].pop_front()
                stolen = False
                if cell is None:
                    cell = steal(wid)
                    stolen = cell is not None
                if cell is None:
                    give_back()
                    return
                if stolen:
                    with state_lock:
                        state["steals"] += 1
                out.results[cell.idx] = self._run_cell(cell.unit, executor)

        if self.opts.workers <= 1:
            worker(0)
        else:
            futs = [self._thread_pool().submit(worker, wid)
                    for wid in range(self.opts.workers)]
            for f in futs:
                f.result()

        out.steals = state["steals"]
        with self._lock:
            self.steals += out.steals
        for q in queues:
            out.deferred.extend(c.unit for c in q.drain_remaining())
        return out

    def _run_cell(self, unit: SyncUnit, executor):
        """Execute one cell: FULL bootstraps route through the process
        pool in ``process`` mode (CPU-bound translation on real cores),
        everything else runs on this worker thread.  A broken child pool
        falls back to in-thread execution rather than failing the cell."""
        if self.opts.mode == "process" and unit.mode == FULL:
            try:
                return self._process_pool().submit(
                    _process_run_unit,
                    (unit, executor.manifest_compaction_threshold)).result()
            except Exception:
                pass  # pool died / not picklable: the thread path is correct
        return executor.execute_unit(unit)
