"""Target writers (paper §3.1): Unified Internal Representation -> format.

Mirror images of the source readers. A target writer materializes IR
snapshots/changes as native metadata of its format, *referencing the same
data files* (metadata-only translation — the paper's low-overhead property).

Sync state (which source commit the target reflects) is persisted **in the
target's own metadata layer**, exactly as real XTable does: Delta table
configuration, Iceberg table properties / snapshot summary, Hudi commit
``extraMetadata``. That makes incremental sync recoverable from the target
alone — there is no side database to lose.
"""

from __future__ import annotations

import json
from contextlib import contextmanager
from typing import Protocol

from repro.core.ir import InternalSnapshot, TableChange
from repro.lst.delta import DeltaTable
from repro.lst.hudi import HudiTable, schema_from_avro
from repro.lst.iceberg import IcebergTable

TOKEN_KEY = "xtable.lastSyncedSourceCommit"
SOURCE_FMT_KEY = "xtable.sourceFormat"
MODE_KEY = "xtable.lastSyncMode"
LINEAGE_KEY = "xtable.coalescedCommits"


class ConversionTarget(Protocol):
    format: str

    def get_sync_token(self) -> str | None: ...
    def full_sync(self, snapshot: InternalSnapshot) -> str: ...
    def incremental_sync(self, change: TableChange) -> str: ...


class _HandleTarget:
    handle_cls = None
    format = "?"

    def __init__(self, fs, base_path: str, *,
                 manifest_compaction_threshold: int | None = None):
        self.fs = fs
        self.base = base_path
        self.handle = (self.handle_cls.open(fs, base_path)
                       if self.handle_cls.exists(fs, base_path) else None)
        self._snap = None       # cached target-side TableState (one replay)
        self._schema = None     # tracked current schema across commits
        self._state = None      # cached sync-state dict (one tail read)
        self._txn = None        # active handle transaction (None = direct)
        self._in_txn = False
        # format-specific transaction knobs (iceberg: manifest compaction)
        self._txn_opts: dict = {}
        if manifest_compaction_threshold is not None \
                and self.format == "iceberg":
            self._txn_opts["manifest_compaction_threshold"] = \
                manifest_compaction_threshold

    # -- target-side metadata cache ----------------------------------------
    # the target's own log is replayed at most once per writer instance;
    # afterwards the schema is tracked through the commits this writer makes
    # (it is the only writer of the unit), so an N-commit incremental unit
    # costs one replay of the target log instead of N.
    def _snapshot(self):
        if self._snap is None:
            self._snap = self.handle.snapshot()
        return self._snap

    def _current_schema(self):
        if self._schema is None:
            self._schema = self._snapshot().schema
        return self._schema

    # -- transactions -------------------------------------------------------
    # inside a transaction the handle's parsed metadata (version counter /
    # metadata dict + manifest list / timeline schema + properties) is read
    # once and threaded through every commit in memory.  Iceberg/hudi
    # buffer their commits: every non-commit-point object of the whole
    # drain (manifests, manifest-lists, instant markers) is staged and
    # flushed in ONE pipelined write_many round when the transaction
    # closes, and only the per-commit metadata puts stay serial — a crash
    # still leaves a valid prefix because staged objects are unreferenced
    # until their (ordered, atomic put-if-absent) commit point lands, and
    # recovery stays "run it again".
    @contextmanager
    def transaction(self):
        self._in_txn = True
        try:
            yield self
        except BaseException:
            txn, self._txn = self._txn, None
            self._in_txn = False
            if txn is not None:
                try:
                    txn.close()     # best-effort: land what was buffered
                except Exception:
                    pass            # the body's error is the root cause —
                    #                 a secondary flush failure must not
                    #                 mask it (recovery is "run it again")
            raise
        else:
            txn, self._txn = self._txn, None
            self._in_txn = False
            if txn is not None:
                txn.close()         # flush any buffered commits

    def _commit(self, adds, removes, **kw) -> str:
        if self._in_txn:
            if self._txn is None:   # lazy: FULL sync may create the table
                self._txn = self._begin_txn()
            return self._txn.commit(adds, removes, **kw)
        return self.handle.commit(adds, removes, **kw)

    def _begin_txn(self):
        """Open the handle transaction, seeding it with whatever target
        metadata this writer already read at plan time (format overrides
        pass their cached state so begin costs zero re-reads)."""
        return self.handle.transaction(schema=self._schema, **self._txn_opts)

    # -- sync-state bookkeeping (stored in target-native metadata) ---------
    def get_sync_token(self) -> str | None:
        if self.handle is None:
            return None
        return self._read_state().get(TOKEN_KEY)

    def get_sync_source_format(self) -> str | None:
        if self.handle is None:
            return None
        return self._read_state().get(SOURCE_FMT_KEY)

    def _read_state(self) -> dict:
        if self._state is None:
            self._state = self._load_state()
        return self._state

    def _load_state(self) -> dict:
        return self._snapshot().properties

    def _state_props(self, src: InternalSnapshot | TableChange, mode: str) -> dict:
        return {TOKEN_KEY: src.source_commit,
                SOURCE_FMT_KEY: src.source_format, MODE_KEY: mode}

    # -- initialization -----------------------------------------------------
    def _ensure_table(self, schema, partition_spec) -> None:
        if self.handle is None:
            self.handle = self.handle_cls.create(
                self.fs, self.base, schema, partition_spec, {})
            self._snap = None
            self._schema = schema

    # -- FULL: reconcile target state to exactly the snapshot ---------------
    def full_sync(self, snapshot: InternalSnapshot) -> str:
        self._ensure_table(snapshot.schema, snapshot.partition_spec)
        cur = self._snapshot()
        cur_paths = set(cur.files)
        want = {f.physical_path: f for f in snapshot.files}
        removes = sorted(cur_paths - set(want))
        adds = [f.to_meta() for p, f in sorted(want.items())
                if p not in cur_paths]
        schema = None if cur.schema.logical_eq(snapshot.schema) \
            else snapshot.schema
        carried = {k: v for k, v in snapshot.properties.items()
                   if not k.startswith("xtable.")}
        props = {**carried, **self._state_props(snapshot, "FULL")}
        v = self._commit(
            adds, removes, schema=schema,
            properties=props,
            operation="xtable-full-sync",
            extra_meta=props)
        self._snap = None
        self._state = None
        self._schema = snapshot.schema
        return v

    # -- INCREMENTAL: replay one source commit (or a coalesced range) --------
    def incremental_sync(self, change: TableChange) -> str:
        if self.handle is None:
            raise RuntimeError("incremental sync on uninitialized target")
        cur_schema = self._current_schema()
        schema = None
        if change.schema is not None and not cur_schema.logical_eq(change.schema):
            schema = change.schema
        props = {**change.extra, **self._state_props(change, "INCREMENTAL")}
        extra = dict(props)
        if change.lineage:   # coalesced range: keep per-commit provenance
            extra[LINEAGE_KEY] = json.dumps(list(change.lineage))
        v = self._commit(
            [f.to_meta() for f in change.adds], list(change.removes),
            schema=schema, properties=props,
            operation=f"xtable-incr-{change.operation}",
            extra_meta=extra)
        self._snap = None
        self._state = None
        if change.schema is not None:
            self._schema = change.schema
        return v


class DeltaTarget(_HandleTarget):
    handle_cls = DeltaTable
    format = "delta"

    # sync state lives in the table configuration, which every sync commit
    # rewrites in its metaData action — the log TAIL answers "where is this
    # target?" in one read; replaying the whole log per planning pass would
    # make token reads O(history)
    def _load_state(self) -> dict:
        _, schema, _, props = self.handle.tail_state()
        if self._schema is None:
            self._schema = schema
        return props

    def _current_schema(self):
        if self._schema is None:
            self._schema = self.handle.tail_state()[1]
        return self._schema


class IcebergTarget(_HandleTarget):
    handle_cls = IcebergTable
    format = "iceberg"

    # iceberg keeps properties and schema in the metadata JSON; ONE metadata
    # read at plan time serves the sync token, the current schema AND the
    # transaction begin — re-discovering the head for each (hint read +
    # roll-forward + metadata parse) would pay ~3 extra RTT rounds per unit
    def __init__(self, fs, base_path, **kw):
        super().__init__(fs, base_path, **kw)
        self._meta = None       # (version, metadata dict) from _load_state

    def _load_state(self) -> dict:
        self._meta = self.handle.read_metadata()
        meta = self._meta[1]
        if self._schema is None:
            self._schema = self.handle.schema_from_metadata(meta)
        return dict(meta["properties"])

    def _current_schema(self):
        if self._schema is None:
            if self._meta is not None:
                self._schema = self.handle.schema_from_metadata(
                    self._meta[1])
            else:
                self._schema = self.handle.current_schema()
        return self._schema

    def _begin_txn(self):
        # seed the transaction with the plan-time metadata read: begin then
        # costs ZERO requests (a foreign commit in between surfaces as a
        # conflict at flush and re-syncs — the same race window as before)
        return self.handle.transaction(schema=self._schema, meta=self._meta,
                                       **self._txn_opts)


class HudiTarget(_HandleTarget):
    handle_cls = HudiTable
    format = "hudi"

    def __init__(self, fs, base_path, **kw):
        super().__init__(fs, base_path, **kw)
        self._props_full = None     # full hoodie.properties from _load_state

    def _load_state(self) -> dict:
        # hudi keeps sync state in the latest commit's extraMetadata, whose
        # values arrive already decoded by the shared extraMetadata codec;
        # the ONE properties read here also seeds the transaction begin
        em = self.handle.latest_extra_metadata()
        self._props_full = self.handle.table_properties()
        if self._schema is None:
            s = em.get("schema") or \
                self._props_full["hoodie.table.create.schema"]
            self._schema = schema_from_avro(s)
        out = {k: v for k, v in self._props_full.items()
               if not k.startswith("hoodie.")}
        for k in (TOKEN_KEY, SOURCE_FMT_KEY, MODE_KEY):
            if k in em:
                # sync-state values are strings by contract; a foreign/legacy
                # writer storing a raw numeric token (e.g. "7" for a delta
                # version) decodes as a scalar — coerce it back
                out[k] = em[k] if isinstance(em[k], str) else str(em[k])
        return out

    def _current_schema(self):
        # the schema rides in the newest instant's extraMetadata — one
        # instant read instead of a whole-timeline replay
        if self._schema is None:
            em = self.handle.latest_extra_metadata()
            s = em.get("schema") or \
                self.handle.table_properties()["hoodie.table.create.schema"]
            self._schema = schema_from_avro(s)
        return self._schema

    def _begin_txn(self):
        # seed the transaction with the plan-time properties read
        return self.handle.transaction(schema=self._schema,
                                       props=self._props_full)


TARGETS = {"delta": DeltaTarget, "iceberg": IcebergTarget, "hudi": HudiTarget}


def make_target(fmt: str, fs, base_path: str, *,
                manifest_compaction_threshold: int | None = None
                ) -> ConversionTarget:
    return TARGETS[fmt](
        fs, base_path,
        manifest_compaction_threshold=manifest_compaction_threshold)
