"""Telemetry for the core logic (paper §3.1: "telemetry for monitoring").

Feeds the demo's "timeline view of XTable events and the work done"
utility: every sync phase is recorded with wall time and work counters.
"""

from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field


@dataclass
class Event:
    ts_ms: int
    dataset: str
    target: str
    phase: str          # plan | full | incremental | skip | error
    detail: str = ""
    elapsed_s: float = 0.0


@dataclass
class Telemetry:
    """Thread-safe: sync units report from executor worker threads."""

    events: list = field(default_factory=list)
    counters: dict = field(default_factory=dict)
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def bump(self, key: str, n: int = 1) -> None:
        with self._lock:
            self.counters[key] = self.counters.get(key, 0) + n

    def record(self, dataset: str, target: str, phase: str, detail: str = "",
               elapsed_s: float = 0.0) -> None:
        with self._lock:
            self.events.append(Event(time.time_ns() // 1_000_000, dataset,
                                     target, phase, detail, elapsed_s))

    @contextmanager
    def timed(self, dataset: str, target: str, phase: str, detail: str = ""):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.record(dataset, target, phase, detail,
                        time.perf_counter() - t0)

    def timeline(self) -> list[str]:
        return [f"[{e.ts_ms}] {e.dataset} -> {e.target}: {e.phase} "
                f"{e.detail} ({e.elapsed_s * 1e3:.2f} ms)" for e in self.events]

    def summary(self) -> dict:
        return dict(self.counters)
