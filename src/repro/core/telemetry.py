"""Telemetry for the sync stack (paper §3.1: "telemetry for monitoring").

One thread-safe :class:`Telemetry` instance rides a whole run: every sync
phase appends a timestamped :class:`Event` (dataset, target, phase, wall
time), and named counters accumulate the work done — request/byte counts
from the instrumented storage layer, per-subsystem occurrences from the
daemon (checkpoint saves, breaker trips, catalog publishes/errors).  The
daemon, fleet, executor, and benchmarks all report through it, so a
single object answers both "what happened, in order" (the event
timeline) and "how much did it cost" (the counters).
"""

from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field


@dataclass
class Event:
    ts_ms: int
    dataset: str
    target: str
    phase: str          # plan | full | incremental | skip | error
    detail: str = ""
    elapsed_s: float = 0.0


@dataclass
class Telemetry:
    """Thread-safe: sync units report from executor worker threads."""

    events: list = field(default_factory=list)
    counters: dict = field(default_factory=dict)
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def bump(self, key: str, n: int = 1) -> None:
        with self._lock:
            self.counters[key] = self.counters.get(key, 0) + n

    def record(self, dataset: str, target: str, phase: str, detail: str = "",
               elapsed_s: float = 0.0) -> None:
        with self._lock:
            self.events.append(Event(time.time_ns() // 1_000_000, dataset,
                                     target, phase, detail, elapsed_s))

    @contextmanager
    def timed(self, dataset: str, target: str, phase: str, detail: str = ""):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.record(dataset, target, phase, detail,
                        time.perf_counter() - t0)

    def timeline(self) -> list[str]:
        return [f"[{e.ts_ms}] {e.dataset} -> {e.target}: {e.phase} "
                f"{e.detail} ({e.elapsed_s * 1e3:.2f} ms)" for e in self.events]

    def summary(self) -> dict:
        return dict(self.counters)
