"""Mamba-2 SSD (state-space duality) mixer — XLA path.

Implements the chunked SSD algorithm of Dao & Gu (arXiv:2405.21060):
sequence split into chunks of length Q; the intra-chunk term is a small
quadratic attention-like contraction, the inter-chunk term is a linear
recurrence over per-chunk states carried by ``lax.scan``.

Covers both assigned SSM flavours:
* mamba2-2.7b — multi-head SSD (head_dim 64, d_state 128)
* jamba's Mamba-1-style mixer — modeled as SSD with head_dim 1 (Mamba-1 is
  the head_dim=1 special case of SSD, per the SSD paper's duality argument)

The Pallas kernel (`repro.kernels.ssd`) is the TPU production path for the
same computation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, SSMConfig
from repro.models.param import ParamSpec

f32 = jnp.float32


def ssm_template(cfg: ModelConfig) -> dict:
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    conv_ch = s.d_inner + 2 * s.n_groups * s.d_state
    return {
        "w_in": ParamSpec((d, 2 * s.d_inner), ("embed", "mlp"), cfg.dtype),
        "w_bc": ParamSpec((d, 2 * s.n_groups * s.d_state), ("embed", None),
                          cfg.dtype),
        "w_dt": ParamSpec((d, s.n_heads), ("embed", "heads"), cfg.dtype),
        "dt_bias": ParamSpec((s.n_heads,), ("heads",), "float32", "zeros"),
        "a_log": ParamSpec((s.n_heads,), ("heads",), "float32", "zeros"),
        "conv_w": ParamSpec((s.conv_width, conv_ch), (None, "mlp"),
                            cfg.dtype, "normal", 0.2),
        "skip_d": ParamSpec((s.n_heads,), ("heads",), "float32", "ones"),
        "w_out": ParamSpec((s.d_inner, d), ("mlp", "embed"), cfg.dtype),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv via shifted adds. x: (b,s,c); w: (cw,c).

    state: (b, cw-1, c) trailing context (decode); returns (y, new_state).
    """
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(cw))
    return y, xp[:, -(cw - 1):, :]


def _split_proj(x, p, s: SSMConfig):
    """Project + conv + activations -> (xh, z, B, C, dt)."""
    zi = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xin = jnp.split(zi, 2, axis=-1)
    bc = jnp.einsum("bsd,de->bse", x, p["w_bc"])
    conv_in = jnp.concatenate([xin, bc], axis=-1)
    return z, conv_in


def _post_conv(conv_ed, p, s: SSMConfig):
    conv_ed = jax.nn.silu(conv_ed)
    xin = conv_ed[..., :s.d_inner]
    B = conv_ed[..., s.d_inner:s.d_inner + s.n_groups * s.d_state]
    C = conv_ed[..., s.d_inner + s.n_groups * s.d_state:]
    b, sl = xin.shape[:2]
    xh = xin.reshape(b, sl, s.n_heads, s.head_dim)
    B = B.reshape(b, sl, s.n_groups, s.d_state)
    C = C.reshape(b, sl, s.n_groups, s.d_state)
    return xh, B, C


def ssd_forward(x, p, cfg: ModelConfig, conv_state=None, ssm_state=None,
                return_state: bool = False):
    """Full-sequence SSD. x: (b, s, d_model) -> (y, (conv_state, ssm_state)).

    Chunked: s must be divisible by the chunk length for the scan path
    (padded if not).
    """
    s: SSMConfig = cfg.ssm
    b, seqlen, _ = x.shape
    z, conv_in = _split_proj(x, p, s)
    conv_out, conv_state_new = _causal_conv(conv_in, p["conv_w"], conv_state)
    xh, B, C = _post_conv(conv_out, p, s)

    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"]).astype(f32) +
        p["dt_bias"].astype(f32))                              # (b,s,h)
    A = -jnp.exp(p["a_log"].astype(f32))                       # (h,)

    Q = min(s.chunk, seqlen)
    pad = (-seqlen) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = xh.shape[1] // Q
    hpg = s.n_heads // s.n_groups          # heads per group

    def chunk(a):  # (b, nc*Q, ...) -> (b, nc, Q, ...)
        return a.reshape(a.shape[0], nc, Q, *a.shape[2:])

    xh_c, B_c, C_c, dt_c = chunk(xh), chunk(B), chunk(C), chunk(dt)
    dA = dt_c * A[None, None, None, :]                         # (b,nc,Q,h)
    cum = jnp.cumsum(dA, axis=2)                               # (b,nc,Q,h)
    total = cum[:, :, -1:, :]                                  # (b,nc,1,h)

    # ---- intra-chunk (quadratic within Q) ----
    cb = jnp.einsum("bcqgn,bckgn->bcgqk", C_c, B_c,
                    preferred_element_type=f32)                # (b,nc,g,Q,Q)
    # decay matrix L[q,k] = exp(cum_q - cum_k) for q >= k
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # (b,nc,Q,Q,h)
    ltri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(ltri[None, None, :, :, None], jnp.exp(diff), 0.0)
    xdt = xh_c.astype(f32) * dt_c[..., None]                   # (b,nc,Q,h,p)
    # expand groups->heads on the fly: head h uses group h // hpg
    scores_h = jnp.repeat(cb, hpg, axis=2) if s.n_groups > 1 else \
        jnp.broadcast_to(cb, (b, nc, s.n_heads, Q, Q))
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp",
                         (scores_h * jnp.moveaxis(L, -1, 2)), xdt)

    # ---- inter-chunk state recurrence ----
    # chunk-local state: S_c = sum_k exp(total - cum_k) * dt_k * B_k ⊗ x_k
    w_state = jnp.exp(total - cum)                             # (b,nc,Q,h)
    BX = jnp.einsum("bckgn,bckhp->bchnp",
                    B_c, (xdt * w_state[..., None]).astype(f32))
    decay = jnp.exp(total[:, :, 0, :])                         # (b,nc,h)

    def step(carry, inp):
        bx, dec = inp                                           # (b,h,n,p),(b,h)
        new = carry * dec[..., None, None] + bx
        return new, carry                                       # emit PREV state

    init = ssm_state.astype(f32) if ssm_state is not None else \
        jnp.zeros((b, s.n_heads, s.d_state, s.head_dim), f32)
    final_state, prev_states = jax.lax.scan(
        step, init, (jnp.moveaxis(BX, 1, 0), jnp.moveaxis(decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)              # (b,nc,h,n,p)

    # y_inter[q] = (C_q * exp(cum_q)) . S_prev
    Ch = jnp.repeat(C_c, hpg, axis=3) if s.n_groups > 1 else \
        jnp.broadcast_to(C_c, (b, nc, Q, s.n_heads, s.d_state))
    y_inter = jnp.einsum("bcqhn,bchnp->bcqhp",
                         Ch.astype(f32) * jnp.exp(cum)[..., None], prev_states)

    y = (y_intra + y_inter).reshape(b, nc * Q, s.n_heads, s.head_dim)
    if pad:
        y = y[:, :seqlen]
    y = y + xh.reshape(b, nc * Q, s.n_heads, s.head_dim)[:, :seqlen] * \
        p["skip_d"].astype(f32)[None, None, :, None]
    y = y.reshape(b, seqlen, s.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    if return_state:
        return out, (conv_state_new, final_state.astype(f32))
    return out


def ssd_decode(x, p, cfg: ModelConfig, conv_state, ssm_state):
    """Single-token SSD step. x: (b, 1, d_model) -> (y, (conv', ssm'))."""
    s: SSMConfig = cfg.ssm
    b = x.shape[0]
    z, conv_in = _split_proj(x, p, s)
    conv_out, conv_state_new = _causal_conv(conv_in, p["conv_w"], conv_state)
    xh, B, C = _post_conv(conv_out, p, s)
    xh, B, C = xh[:, 0], B[:, 0], C[:, 0]      # (b,h,p),(b,g,n),(b,g,n)

    dt = jax.nn.softplus(
        jnp.einsum("bd,dh->bh", x[:, 0], p["w_dt"]).astype(f32) +
        p["dt_bias"].astype(f32))                              # (b,h)
    A = -jnp.exp(p["a_log"].astype(f32))
    dA = jnp.exp(dt * A[None, :])                              # (b,h)

    hpg = s.n_heads // s.n_groups
    Bh = jnp.repeat(B, hpg, axis=1) if s.n_groups > 1 else \
        jnp.broadcast_to(B, (b, s.n_heads, s.d_state))
    Ch = jnp.repeat(C, hpg, axis=1) if s.n_groups > 1 else \
        jnp.broadcast_to(C, (b, s.n_heads, s.d_state))

    # h' = h * exp(dt A) + dt * (B ⊗ x)
    upd = dt[..., None, None] * Bh[..., :, None].astype(f32) * \
        xh[..., None, :].astype(f32)                           # (b,h,n,p)
    new_state = ssm_state * dA[..., None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(f32), new_state)
    y = y + xh.astype(f32) * p["skip_d"].astype(f32)[None, :, None]
    y = y.reshape(b, 1, s.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, (conv_state_new, new_state)


def ssm_cache_template(cfg: ModelConfig, batch: int) -> dict:
    s: SSMConfig = cfg.ssm
    conv_ch = s.d_inner + 2 * s.n_groups * s.d_state
    return {
        "conv": ParamSpec((batch, s.conv_width - 1, conv_ch), (("batch",) +
                          (None, None)), cfg.dtype, "zeros"),
        "state": ParamSpec((batch, s.n_heads, s.d_state, s.head_dim),
                           ("batch", "heads", None, None), "float32", "zeros"),
    }
