"""Composable decoder / encoder-decoder model covering all ten architectures.

Layers are grouped into a repeating *cycle* (gemma2: [local, global]; jamba:
[7x mamba + 1x attn, alternating MoE]; dense models: [attn]) and the stack is
a ``lax.scan`` over stacked cycle parameters — bounded HLO size and compile
time at 512 devices regardless of depth.

Three entry points per model (the dry-run lowers each):
* ``forward``     — full teacher-forced pass (train loss path)
* ``prefill``     — forward + KV/SSM cache construction (inference prefill)
* ``decode_step`` — one new token against the cache (inference decode)
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import LayerSpec, ModelConfig
from repro.models.param import ParamSpec, stack_cycle
from repro.parallel.sharding import Sharder

f32 = jnp.float32


# ------------------------------------------------------------- templates
def _attn_template(cfg: ModelConfig) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    t = {"ln": L.norm_template(cfg),
         "wq": ParamSpec((d, h, dh), ("embed", "heads", "head_dim"), cfg.dtype),
         "wk": ParamSpec((d, kv, dh), ("embed", "kv_heads", "head_dim"),
                         cfg.dtype),
         "wv": ParamSpec((d, kv, dh), ("embed", "kv_heads", "head_dim"),
                         cfg.dtype),
         "wo": ParamSpec((h, dh, d), ("heads", "head_dim", "embed"),
                         cfg.dtype)}
    if cfg.qk_norm:
        t["qn"] = {"scale": ParamSpec((dh,), (None,), "float32", "zeros")}
        t["kn"] = {"scale": ParamSpec((dh,), (None,), "float32", "zeros")}
    if cfg.post_block_norm:
        t["post_ln"] = L.norm_template(cfg)
    return t


def _mlp_part_template(cfg: ModelConfig, spec: LayerSpec) -> dict:
    t = {"ln": L.norm_template(cfg)}
    t.update(L.moe_template(cfg) if spec.moe else L.mlp_template(cfg))
    if cfg.post_block_norm:
        t["post_ln"] = L.norm_template(cfg)
    return t


def _block_template(cfg: ModelConfig, spec: LayerSpec) -> dict:
    t = {}
    if spec.kind == "attn":
        t["attn"] = _attn_template(cfg)
    else:
        t["ssm"] = {"ln": L.norm_template(cfg), **S.ssm_template(cfg)}
    if spec.cross_attn:
        t["cross"] = _attn_template(cfg)
    if spec.mlp:
        t["mlp"] = _mlp_part_template(cfg, spec)
    return t


class Model:
    def __init__(self, cfg: ModelConfig, sharder: Sharder | None = None):
        self.cfg = cfg
        self.sh = sharder or Sharder.null()

    # --------------------------------------------------------- param spec
    def param_template(self) -> dict:
        cfg = self.cfg
        tpl = {
            "embed": ParamSpec((cfg.vocab_size, cfg.d_model),
                               ("vocab", "embed"), cfg.dtype, "normal", 0.02),
            "blocks": stack_cycle(
                {f"s{i}": _block_template(cfg, spec)
                 for i, spec in enumerate(cfg.cycle)}, cfg.n_cycles),
            "final_norm": L.norm_template(cfg),
        }
        if not cfg.tie_embeddings:
            tpl["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                       ("embed", "vocab"), cfg.dtype,
                                       "normal", 0.02)
        if cfg.encoder:
            enc_spec = LayerSpec(kind="attn", causal=False)
            tpl["encoder"] = {
                "blocks": stack_cycle(
                    {"s0": _block_template(cfg, enc_spec)},
                    cfg.encoder.n_layers),
                "final_norm": L.norm_template(cfg),
            }
        return tpl

    def cache_template(self, batch: int, cache_len: int) -> dict:
        cfg = self.cfg
        per_cycle = {}
        for i, spec in enumerate(cfg.cycle):
            c = {}
            if spec.kind == "attn":
                sc = min(spec.window, cache_len) if spec.window else cache_len
                kvshape = (batch, sc, cfg.n_kv_heads, cfg.head_dim)
                kvaxes = ("batch", "kvseq", "kv_heads", "head_dim")
                c["k"] = ParamSpec(kvshape, kvaxes, cfg.dtype, "zeros")
                c["v"] = ParamSpec(kvshape, kvaxes, cfg.dtype, "zeros")
                c["kpos"] = ParamSpec((batch, sc), ("batch", "kvseq"),
                                      "int32", "neg_ones")
            else:
                c.update(S.ssm_cache_template(cfg, batch))
            if spec.cross_attn:
                xshape = (batch, cfg.encoder.n_frames, cfg.n_kv_heads,
                          cfg.head_dim)
                c["ck"] = ParamSpec(xshape, ("batch", "frames", "kv_heads",
                                             "head_dim"), cfg.dtype, "zeros")
                c["cv"] = ParamSpec(xshape, ("batch", "frames", "kv_heads",
                                             "head_dim"), cfg.dtype, "zeros")
            per_cycle[f"s{i}"] = c
        return stack_cycle(per_cycle, cfg.n_cycles)

    # ------------------------------------------------------------- blocks
    def _project_qkv(self, h, p, positions, use_rope: bool = True):
        cfg = self.cfg
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
        if cfg.qk_norm:
            q = L.rms_norm(q, p["qn"]["scale"], cfg.norm_eps)
            k = L.rms_norm(k, p["kn"]["scale"], cfg.norm_eps)
        if use_rope:
            q = L.rope(q, positions, cfg.rope_theta)
            k = L.rope(k, positions, cfg.rope_theta)
        q = self.sh(q, "batch", "seq", "heads", None)
        k = self.sh(k, "batch", "seq", "kv_heads", None)
        v = self.sh(v, "batch", "seq", "kv_heads", None)
        return q, k, v

    def _attn_part(self, x, p, spec: LayerSpec, *, mode, cache, pos,
                   cache_len):
        cfg = self.cfg
        b, sq, _ = x.shape
        h = self.sh(L.apply_norm(x, p["ln"], cfg), "batch", "seq", None)
        if mode == "decode":
            positions = pos[:, None]                      # (b,1)
        else:
            positions = jnp.arange(sq)[None, :]
        q, k, v = self._project_qkv(h, p, positions)

        new_cache = None
        if mode == "decode":
            sc = cache["k"].shape[1]
            idx = pos % sc
            barange = jnp.arange(b)
            kc = cache["k"].at[barange, idx].set(k[:, 0])
            vc = cache["v"].at[barange, idx].set(v[:, 0])
            kp = cache["kpos"].at[barange, idx].set(pos)
            kc = self.sh(kc, "batch", "kvseq", "kv_heads", None)
            vc = self.sh(vc, "batch", "kvseq", "kv_heads", None)
            o = L.decode_attention(q, kc, vc, kp, pos, window=spec.window,
                                   cap=cfg.attn_softcap, sh=self.sh)
            new_cache = {"k": kc, "v": vc, "kpos": kp}
        else:
            o = L.blocked_attention(q, k, v, causal=spec.causal,
                                    window=spec.window, cap=cfg.attn_softcap,
                                    q_blocks=cfg.attn_q_blocks, sh=self.sh)
            if mode == "prefill":
                new_cache = self._build_cache(k, v, spec, cache_len)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        if cfg.post_block_norm:
            out = L.apply_norm(out, p["post_ln"], cfg)
        return x + out, new_cache

    def _build_cache(self, k, v, spec: LayerSpec, cache_len: int) -> dict:
        b, s = k.shape[:2]
        sc = min(spec.window, cache_len) if spec.window else cache_len
        take = min(s, sc)
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

        def place(a, fill):
            buf = jnp.full((b, sc) + a.shape[2:], fill, a.dtype)
            return jax.lax.dynamic_update_slice_in_dim(
                buf, jax.lax.slice_in_dim(a, s - take, s, axis=1), 0, axis=1)

        kc, vc = place(k, 0), place(v, 0)
        kp = place(positions.astype(jnp.int32), -1)
        kc = self.sh(kc, "batch", "kvseq", "kv_heads", None)
        vc = self.sh(vc, "batch", "kvseq", "kv_heads", None)
        return {"k": kc, "v": vc, "kpos": kp}

    def _cross_part(self, x, p, *, mode, cache, enc_out):
        cfg = self.cfg
        h = self.sh(L.apply_norm(x, p["ln"], cfg), "batch", "seq", None)
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
        if mode == "decode":
            ck, cv = cache["ck"], cache["cv"]
        else:
            ck = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
            cv = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
        o = L.blocked_attention(q, ck, cv, causal=False,
                                q_blocks=cfg.attn_q_blocks, sh=self.sh)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        new_cache = {"ck": ck, "cv": cv} if mode in ("prefill",) else \
            ({"ck": ck, "cv": cv} if mode == "decode" else None)
        return x + out, new_cache

    def _mlp_part(self, x, p, spec: LayerSpec):
        cfg = self.cfg
        h = self.sh(L.apply_norm(x, p["ln"], cfg), "batch", "seq", None)
        if spec.moe:
            y, aux = L.moe_mlp(h, p, cfg, sh=self.sh)
        else:
            y, aux = L.mlp(h, p, cfg, sh=self.sh), jnp.zeros((), f32)
        y = self.sh(y, "batch", "seq", None)
        if cfg.post_block_norm:
            y = L.apply_norm(y, p["post_ln"], cfg)
        return x + y, aux

    def _ssm_part(self, x, p, *, mode, cache):
        cfg = self.cfg
        h = self.sh(L.apply_norm(x, p["ln"], cfg), "batch", "seq", None)
        if mode == "train":
            return x + S.ssd_forward(h, p, cfg), None
        if mode == "prefill":
            y, (conv, st) = S.ssd_forward(h, p, cfg, return_state=True)
            return x + y, {"conv": conv, "state": st}
        y, (conv, st) = S.ssd_decode(h, p, cfg, cache["conv"], cache["state"])
        return x + y, {"conv": conv, "state": st}

    def apply_block(self, x, p, spec: LayerSpec, *, mode, cache=None,
                    pos=None, enc_out=None, cache_len=None):
        aux = jnp.zeros((), f32)
        new_cache = {}
        if spec.kind == "attn":
            x, c = self._attn_part(x, p["attn"], spec, mode=mode,
                                   cache=cache, pos=pos, cache_len=cache_len)
            if c:
                new_cache.update(c)
        else:
            x, c = self._ssm_part(x, p["ssm"], mode=mode, cache=cache)
            if c:
                new_cache.update(c)
        if spec.cross_attn:
            x, c = self._cross_part(x, p["cross"], mode=mode, cache=cache,
                                    enc_out=enc_out)
            if c:
                new_cache.update(c)
        if spec.mlp:
            x, a = self._mlp_part(x, p["mlp"], spec)
            aux = aux + a
        return x, aux, (new_cache if mode != "train" else None)

    # -------------------------------------------------------------- stacks
    def _run_blocks(self, x, blocks, *, mode, cache=None, pos=None,
                    enc_out=None, cache_len=None, cycle=None):
        cfg = self.cfg
        cycle = cycle or cfg.cycle

        def cycle_fn(carry, cp, cc):
            x = carry
            aux = jnp.zeros((), f32)
            ncache = {}
            for i, spec in enumerate(cycle):
                x, a, nc = self.apply_block(
                    x, cp[f"s{i}"], spec, mode=mode,
                    cache=None if cc is None else cc[f"s{i}"],
                    pos=pos, enc_out=enc_out, cache_len=cache_len)
                aux = aux + a
                if nc is not None:
                    ncache[f"s{i}"] = nc
            x = self.sh(x, "batch", "act_seq", None)
            return x, (aux, ncache)

        if mode == "train" and cfg.remat != "none":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat == "dots" else None)
            body = jax.checkpoint(lambda c, p: cycle_fn(c, p, None),
                                  policy=policy)
        elif cache is None:
            def body(c, p):
                return cycle_fn(c, p, None)
        else:
            body = None

        if cache is not None:
            x, (auxs, new_cache) = jax.lax.scan(
                lambda c, xs: cycle_fn(c, xs[0], xs[1]), x, (blocks, cache))
        else:
            x, (auxs, new_cache) = jax.lax.scan(body, x, blocks)
        return x, jnp.sum(auxs), new_cache

    def encode(self, params, enc_embeds):
        cfg = self.cfg
        x = self.sh(enc_embeds, "batch", "frames", None)
        x, _, _ = self._run_blocks(
            x, params["encoder"]["blocks"], mode="encode",
            cycle=(LayerSpec(kind="attn", causal=False),))
        return L.apply_norm(x, params["encoder"]["final_norm"], cfg)

    def _head(self, x, params):
        cfg = self.cfg
        x = L.apply_norm(x, params["final_norm"], cfg)
        logits = jnp.einsum("bsd,dv->bsv", x, self.head_weights(params))
        logits = L.softcap(logits.astype(f32), cfg.final_softcap)
        return self.sh(logits, "batch", "seq", "vocab")

    def _embed(self, params, tokens):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
        if cfg.embed_scale:
            x = x * math.sqrt(cfg.d_model)
        return self.sh(x, "batch", "act_seq", None)

    def head_weights(self, params):
        """(d_model, vocab) projection used by the chunked loss.

        Constrained to (replicated, vocab-sharded): one cheap all-gather of
        the FSDP axis instead of a per-chunk logits all-reduce over d_model
        partial sums."""
        w = params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]
        return self.sh(w, None, "vocab")

    def forward_hidden(self, params, tokens, enc_embeds=None):
        """Final-norm hidden states (b,s,d) + aux loss — no logits
        materialization (the train loss computes chunked vocab projections)."""
        enc_out = self.encode(params, enc_embeds) if self.cfg.encoder else None
        x = self._embed(params, tokens)
        x, aux, _ = self._run_blocks(x, params["blocks"], mode="train",
                                     enc_out=enc_out)
        x = L.apply_norm(x, params["final_norm"], self.cfg)
        # regather the sequence for the (vocab-parallel) chunked loss
        return self.sh(x, "batch", "seq", None), aux

    # ------------------------------------------------------------ entries
    def forward(self, params, tokens, enc_embeds=None):
        """Teacher-forced pass -> (logits (b,s,V) fp32, aux loss)."""
        enc_out = self.encode(params, enc_embeds) if self.cfg.encoder else None
        x = self._embed(params, tokens)
        x, aux, _ = self._run_blocks(x, params["blocks"], mode="train",
                                     enc_out=enc_out)
        return self._head(x, params), aux

    def prefill(self, params, tokens, cache_len: int | None = None,
                enc_embeds=None):
        """Build the cache; returns (last-position logits (b,V), cache)."""
        cache_len = cache_len or tokens.shape[1]
        enc_out = self.encode(params, enc_embeds) if self.cfg.encoder else None
        x = self._embed(params, tokens)
        x, _, cache = self._run_blocks(x, params["blocks"], mode="prefill",
                                       enc_out=enc_out, cache_len=cache_len)
        logits = self._head(x[:, -1:], params)
        return logits[:, 0], cache

    def decode_step(self, params, cache, tokens, pos):
        """One token step. tokens: (b,), pos: (b,) -> (logits (b,V), cache)."""
        x = self._embed(params, tokens[:, None])
        x, _, new_cache = self._run_blocks(x, params["blocks"], mode="decode",
                                           cache=cache, pos=pos)
        logits = self._head(x, params)
        return logits[:, 0], new_cache
