"""Model configuration: one composable schema covering all ten architectures."""

from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating cycle."""
    kind: str = "attn"          # "attn" | "ssm"
    window: int = 0             # 0 = global causal attention; >0 sliding window
    moe: bool = False           # MoE MLP instead of dense MLP
    mlp: bool = True            # False: mixer-only block (mamba2)
    cross_attn: bool = False    # decoder cross-attention (whisper)
    causal: bool = True         # False for encoder self-attention


@dataclass(frozen=True)
class SSMConfig:
    d_inner: int
    d_state: int
    n_heads: int
    head_dim: int
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256            # SSD chunk length


@dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder over a stub modality frontend."""
    n_layers: int
    n_frames: int = 1500        # precomputed frame embeddings (conv stub)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | vlm | audio | ssm
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    cycle: tuple = (LayerSpec(),)    # repeated n_layers / len(cycle) times
    # --- mlp ---
    mlp_act: str = "silu"
    gated: bool = True
    # --- attention ---
    rope_theta: float = 10_000.0
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    qk_norm: bool = False
    # --- moe ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- ssm / encoder ---
    ssm: SSMConfig | None = None
    encoder: EncoderConfig | None = None
    # --- misc ---
    norm_type: str = "rms"      # rms | ln
    norm_eps: float = 1e-6
    embed_scale: bool = False   # gemma-style sqrt(d) embedding scaling
    post_block_norm: bool = False   # gemma2-style post-norms
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # --- training-time knobs (hillclimb surface) ---
    remat: str = "block"        # none | block | full
    attn_q_blocks: int = 8      # block-causal attention q-splits
    attn_impl: str = "blocked"  # blocked | dense (xla paths) | pallas (tpu)
    long_context_seq_shard: bool = False  # shard KV seq over 'data' in decode

    def __post_init__(self):
        assert self.n_layers % len(self.cycle) == 0, \
            (self.name, self.n_layers, len(self.cycle))

    @property
    def n_cycles(self) -> int:
        return self.n_layers // len(self.cycle)

    def layer_specs(self) -> list:
        return [self.cycle[i % len(self.cycle)]
                for i in range(self.n_layers)]

    def with_updates(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""
    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    step: str                   # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPE_CELLS = (
    ShapeCell("train_4k", "train", 4096, 256),
    ShapeCell("prefill_32k", "prefill", 32768, 32),
    ShapeCell("decode_32k", "decode", 32768, 128),
    ShapeCell("long_500k", "decode", 524288, 1),
)


def get_shape_cell(name: str) -> ShapeCell:
    for c in SHAPE_CELLS:
        if c.name == name:
            return c
    raise KeyError(name)
