"""Model layers: norms, RoPE, block-causal attention, MLP, MoE.

All functions are pure (params passed explicitly) and jit/scan/remat-friendly.

Attention is implemented "blocked": a static python loop over query blocks,
each attending to a statically-sliced key range `[max(0, end-window-qb), end)`.
This is the XLA-native analogue of a flash kernel's block skipping — causal
and sliding-window structure turn into *fewer matmul FLOPs in the HLO*, not
runtime masking of a full S x S score tensor. The Pallas kernel
(`repro.kernels.flash_attention`) is the TPU production path; this module is
the lowering/roofline path and the numerical oracle's substrate.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.param import ParamSpec

f32 = jnp.float32


# --------------------------------------------------------------------- norms
def rms_norm(x, w, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(f32)), axis=-1, keepdims=True)
    return (x.astype(f32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * \
        (1.0 + w.astype(x.dtype))


def layer_norm(x, w, b, eps: float = 1e-6):
    xf = x.astype(f32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * w.astype(x.dtype) + b.astype(x.dtype)


def apply_norm(x, p, cfg: ModelConfig):
    if cfg.norm_type == "ln":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def norm_template(cfg: ModelConfig, d: int | None = None) -> dict:
    d = d or cfg.d_model
    t = {"scale": ParamSpec((d,), (None,), "float32", "zeros")}
    if cfg.norm_type == "ln":
        t = {"scale": ParamSpec((d,), (None,), "float32", "ones"),
             "bias": ParamSpec((d,), (None,), "float32", "zeros")}
    return t


# ---------------------------------------------------------------------- rope
def rope(x, positions, theta: float):
    """x: (..., s, nheads, head_dim); positions: broadcastable to (..., s)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=f32) / half)
    angles = positions.astype(f32)[..., None] * freq          # (..., s, half)
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)        # (..., s, 1, half)
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def softcap(x, cap: float):
    return jnp.tanh(x / cap) * cap if cap else x


# ----------------------------------------------------------------- attention
def _repeat_kv(k, n_heads: int):
    """(b, s, kv, dh) -> (b, s, h, dh): flat-head GQA.

    Keeping attention 4D with a flat head axis avoids 5D (kv, group)
    reshapes whose shardings SPMD cannot transition without involuntary
    replication; the repeated KV is fully head-sharded so the per-device
    footprint matches the query tensor.
    """
    g = n_heads // k.shape[2]
    if g == 1:
        return k
    return jnp.repeat(k, g, axis=2)


def _attend(q, k, v, mask, cap: float, sh=None):
    """q: (b,sq,h,dh) pre-scaled; k/v: (b,sk,h,dh); mask broadcastable to
    (b,h,sq,sk)."""
    s = jnp.einsum("bqhd,bshd->bhqs", q, k, preferred_element_type=f32)
    s = softcap(s, cap)
    if sh is not None:
        s = sh(s, "batch", "heads", "attn_q", None)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", p, v)


def blocked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      cap: float = 0.0, q_blocks: int = 8,
                      q_offset: int = 0, sh=None):
    """Block attention with static per-block key ranges.

    q: (b, sq, h, dh), k/v: (b, sk, kv, dh). Returns (b, sq, h, dh).
    FLOPs scale with the *visible* key range per query block (causal skips
    the future; sliding windows skip the distant past) — matching what the
    Pallas flash kernel does on TPU. Non-causal attention is also q-blocked
    to bound the live score tensor.
    """
    b, sq, h, dh = q.shape
    qs = q * (1.0 / math.sqrt(dh))
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)

    q_blocks = max(1, min(q_blocks, sq))
    while sq % q_blocks:
        q_blocks -= 1
    qb = sq // q_blocks
    outs = []
    for i in range(q_blocks):
        q_lo = q_offset + i * qb
        if causal:
            k_hi = min(q_lo + qb, k.shape[1])
            k_lo = max(0, q_lo - window) if window else 0
        else:
            k_lo, k_hi = 0, k.shape[1]
        qi = jax.lax.slice_in_dim(qs, i * qb, (i + 1) * qb, axis=1)
        ki = jax.lax.slice_in_dim(k, k_lo, k_hi, axis=1)
        vi = jax.lax.slice_in_dim(v, k_lo, k_hi, axis=1)
        if causal:
            qpos = q_lo + jnp.arange(qb)
            kpos = k_lo + jnp.arange(k_hi - k_lo)
            m = kpos[None, :] <= qpos[:, None]
            if window:
                m &= (qpos[:, None] - kpos[None, :]) < window
            m = m[None, None]
        else:
            m = jnp.ones((1, 1, 1, k_hi - k_lo), bool)
        outs.append(_attend(qi, ki, vi, m, cap, sh))
    return jnp.concatenate(outs, axis=1)


def decode_attention(q, k_cache, v_cache, kpos, pos, *, window: int = 0,
                     cap: float = 0.0, sh=None):
    """Single-token attention over a (possibly ring-buffered) KV cache.

    q: (b, 1, h, dh); k/v_cache: (b, S, kv, dh); kpos: (b, S) absolute
    positions of cached keys (-1 = empty); pos: (b,) current positions.
    """
    b, _, h, dh = q.shape
    qs = q * (1.0 / math.sqrt(dh))
    kc = _repeat_kv(k_cache, h)
    vc = _repeat_kv(v_cache, h)
    valid = (kpos >= 0) & (kpos <= pos[:, None])
    if window:
        valid &= (pos[:, None] - kpos) < window
    mask = valid[:, None, None, :]                  # (b,1,1,S)
    return _attend(qs, kc, vc, mask, cap, sh)


# --------------------------------------------------------------- dense MLP
def _silu(x):
    return x * jax.nn.sigmoid(x)


def _gelu_tanh(x):
    # dtype-preserving tanh GELU: jax.nn.gelu upcasts to f32, which
    # materializes (and backward all-gathers) fp32 copies of the d_ff-wide
    # hidden — 2x HBM and 2x collective bytes for zero roofline benefit.
    c = x.dtype.type(0.7978845608028654)
    a = x.dtype.type(0.044715)
    half = x.dtype.type(0.5)
    one = x.dtype.type(1.0)
    return half * x * (one + jnp.tanh(c * (x + a * x * x * x)))


ACTS = {"silu": _silu, "gelu": _gelu_tanh, "relu": jax.nn.relu}


def mlp_template(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    t = {"wi": ParamSpec((d, f), ("embed", "mlp"), cfg.dtype),
         "wo": ParamSpec((f, d), ("mlp", "embed"), cfg.dtype)}
    if cfg.gated:
        t["wg"] = ParamSpec((d, f), ("embed", "mlp"), cfg.dtype)
    return t


def mlp(x, p, cfg: ModelConfig, sh=None):
    act = ACTS[cfg.mlp_act]
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    h = act(h) * jnp.einsum("bsd,df->bsf", x, p["wg"]) if cfg.gated else act(h)
    if sh is not None:
        h = sh(h, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


# ----------------------------------------------------------------- MoE MLP
def moe_template(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    t = {"router": ParamSpec((d, e), ("embed", None), "float32",
                             "normal", 0.02),
         "w_in": ParamSpec((e, d, f), ("experts", "embed", "mlp"), cfg.dtype),
         "w_out": ParamSpec((e, f, d), ("experts", "mlp", "embed"), cfg.dtype)}
    if cfg.gated:
        t["w_gate"] = ParamSpec((e, d, f), ("experts", "embed", "mlp"),
                                cfg.dtype)
    return t


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _dispatch_one(eid, n_experts: int, capacity: int):
    """Sort-based dispatch for one token group.

    eid: (S*k,) expert id per (token, choice). Returns
    * ``gather_tok`` (E*C,): which flat (token,choice) each expert slot reads
    * ``inv``        (S*k,): the slot each (token,choice) landed in
                             (E*C = dropped — points at a zero row)

    The combine step is a *gather* through ``inv`` rather than a scatter-add:
    SPMD partitions gathers along the batch axis cleanly, whereas the
    scatter-add form replicated the (G,S,d) accumulator per device and
    all-reduced it (~16 GiB/device at 32k prefill).
    """
    nk = eid.shape[0]
    order = jnp.argsort(eid, stable=True)
    eid_s = eid[order]
    counts = jnp.bincount(eid, length=n_experts)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(nk) - starts[eid_s]
    keep = rank < capacity
    slot = jnp.where(keep, eid_s * capacity + rank, n_experts * capacity)
    gather_tok = jnp.zeros(n_experts * capacity + 1, jnp.int32) \
        .at[slot].set(order.astype(jnp.int32), mode="drop")
    inv = jnp.full((nk,), n_experts * capacity, jnp.int32) \
        .at[order].set(slot.astype(jnp.int32))
    return gather_tok[:-1], inv


def moe_mlp(x, p, cfg: ModelConfig, sh=None):
    """Top-k token-choice MoE with sort-based dispatch (GShard-style capacity).

    x: (G, S, d) — G groups (per-device batch) routed independently so
    dispatch never crosses the data-parallel axis. Returns (y, aux_loss).
    """
    G, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _round_up(max(1, int(math.ceil(S * k / E * cfg.capacity_factor))), 8)
    C = min(C, S * k)
    act = ACTS[cfg.mlp_act]

    logits = jnp.einsum("gsd,de->gse", x.astype(f32),
                        p["router"].astype(f32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                    # (G,S,k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # flat index n corresponds to (token n // k, choice n % k)
    eid_flat = top_e.reshape(G, S * k)
    gather_tok, inv = jax.vmap(
        lambda e: _dispatch_one(e, E, C))(eid_flat)
    tok_of_slot = gather_tok // k                              # (G, E*C)

    xe = jnp.take_along_axis(x, tok_of_slot[..., None], axis=1)  # (G,E*C,d)
    xe = xe.reshape(G, E, C, d)
    if sh is not None:
        xe = sh(xe, "batch", "experts", None, None)

    h = jnp.einsum("gecd,edf->gecf", xe, p["w_in"])
    if cfg.gated:
        h = act(h) * jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
    else:
        h = act(h)
    if sh is not None:
        h = sh(h, "batch", "experts", None, "mlp")
    out = jnp.einsum("gecf,efd->gecd", h, p["w_out"]).reshape(G, E * C, d)
    # zero row for dropped tokens, then combine by GATHER (see _dispatch_one)
    out = jnp.concatenate([out, jnp.zeros((G, 1, d), out.dtype)], axis=1)
    picked = jnp.take_along_axis(out, inv[..., None], axis=1)  # (G,S*k,d)
    picked = picked.reshape(G, S, k, d)
    y = jnp.einsum("gskd,gsk->gsd", picked, top_w.astype(picked.dtype))
    if sh is not None:
        y = sh(y, "batch", "seq", None)

    # load-balance + router-z auxiliary losses (Switch/GShard standard)
    me = jnp.mean(probs, axis=(0, 1))                                # (E,)
    ce = jnp.mean(jax.nn.one_hot(top_e[..., 0], E, dtype=f32), (0, 1))
    aux = E * jnp.sum(me * ce)
    zloss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return y, cfg.router_aux_weight * aux + 1e-4 * zloss
