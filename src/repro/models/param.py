"""Parameter templates with logical sharding axes.

Every parameter is declared once as a ``ParamSpec`` (shape, dtype, logical
axes, init). The same template drives three consumers:

* ``init_params``      — real initialization (smoke tests, training)
* ``template_shapes``  — ``ShapeDtypeStruct`` stand-ins (multi-pod dry-run)
* ``parallel.sharding.template_pspecs`` — logical axes -> ``PartitionSpec``
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple            # logical axis name (str) or None per dim
    dtype: str = "bfloat16"
    init: str = "normal"   # normal | zeros | ones | small_normal
    scale: float | None = None   # stddev; default fan-in

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def template_shapes(tpl):
    """Template -> pytree of ShapeDtypeStruct (no allocation; dry-run path)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        tpl, is_leaf=is_spec)


def _init_one(spec: ParamSpec, key) -> jax.Array:
    dtype = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "neg_ones":
        return jnp.full(spec.shape, -1, dtype)
    fan_in = spec.shape[0] if spec.shape else 1
    std = spec.scale if spec.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)


def init_params(tpl, key):
    """Template -> pytree of initialized arrays (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree.flatten(tpl, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef,
                              [_init_one(s, k) for s, k in zip(leaves, keys)])


def count_params(tpl) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(tpl, is_leaf=is_spec))


def stack_cycle(tpl, n_cycles: int):
    """Add a leading scan ('layers') dim to every param in a cycle template."""
    return jax.tree.map(
        lambda s: ParamSpec((n_cycles,) + s.shape, ("layers",) + s.axes,
                            s.dtype, s.init, s.scale),
        tpl, is_leaf=is_spec)


@dataclass
class ParamTree:
    """Convenience bundle: template + metadata."""
    template: dict
    n_params: int = field(init=False)

    def __post_init__(self):
        self.n_params = count_params(self.template)
