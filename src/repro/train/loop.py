"""Train step: chunked vocab-parallel loss, grad accumulation, AdamW.

* **Chunked cross-entropy** — the (b, s, V) logits tensor is never
  materialized: the loss scans over sequence chunks, projecting each chunk to
  the vocab and reducing immediately. With a 256k vocab (gemma2) this is the
  difference between ~4 GB/device of logits and ~70 MB.
* **Vocab-parallel** — the head projection is sharded over ``model``; XLA
  turns the per-chunk logsumexp/target-pick into partial reductions +
  small all-reduces (Megatron-style parallel CE emerges from sharding).
* **Gradient accumulation** — microbatch scan with fp32 accumulators.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim import AdamWConfig, adamw_update

f32 = jnp.float32


@dataclass
class TrainState:
    params: dict
    opt: dict
    step: int = 0


def chunked_cross_entropy(hidden, head_w, targets, *, final_softcap: float = 0.0,
                          chunk: int = 512, z_weight: float = 1e-4):
    """Mean CE over valid (target >= 0) tokens, scanning sequence chunks.

    hidden: (b, s, d); head_w: (d, V); targets: (b, s) int32 (-1 = pad).
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    nc = s // chunk
    hc = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, nc, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        h, t = xs                                    # (b,chunk,d), (b,chunk)
        logits = jnp.einsum("bcd,dv->bcv", h, head_w).astype(f32)
        if final_softcap:
            logits = jnp.tanh(logits / final_softcap) * final_softcap
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(t, 0)[..., None], axis=-1)[..., 0]
        valid = (t >= 0).astype(f32)
        ce = jnp.sum((lse - tgt) * valid)
        zl = jnp.sum(jnp.square(lse) * valid)
        n = jnp.sum(valid)
        c_ce, c_zl, c_n = carry
        return (c_ce + ce, c_zl + zl, c_n + n), None

    # remat: recompute each chunk's logits in the backward instead of
    # saving (nc, b, chunk, V) fp32 residuals (~4 GiB/device at 256k vocab)
    (ce, zl, n), _ = jax.lax.scan(
        jax.checkpoint(body), (jnp.zeros((), f32),) * 3, (hc, tc))
    n = jnp.maximum(n, 1.0)
    return ce / n + z_weight * zl / n, ce / n, n


def make_loss_fn(model: Model, *, ce_chunk: int = 512):
    cfg = model.cfg

    def loss_fn(params, batch):
        hidden, aux = model.forward_hidden(
            params, batch["inputs"], enc_embeds=batch.get("enc_embeds"))
        loss, ce, n = chunked_cross_entropy(
            hidden, model.head_weights(params), batch["targets"],
            final_softcap=cfg.final_softcap, chunk=ce_chunk)
        return loss + aux, {"ce": ce, "aux": aux, "tokens": n}

    return loss_fn


def make_train_step(model: Model, opt_cfg: AdamWConfig, *,
                    grad_accum: int = 1, ce_chunk: int = 512,
                    grad_pspecs=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With grad_accum > 1, the global batch is split along axis 0 into
    microbatches processed by a scan with fp32 grad accumulators (collectives
    for the gradient reduce-scatter overlap with the next microbatch's
    backward under XLA's scheduler).

    grad_pspecs: optional PartitionSpec pytree matching params — pins each
    gradient to its parameter's sharding before the optimizer (without it,
    SPMD materialized e.g. the full-vocab fp32 embedding gradient replicated
    on every device).
    """
    loss_fn = make_loss_fn(model, ce_chunk=ce_chunk)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def pin(grads):
        if grad_pspecs is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads,
                            grad_pspecs)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, _aux), grads = grad_fn(params, batch)
            grads = pin(grads)
        else:
            def micro(carry, mb):
                acc, lacc = carry
                (lo, _a), g = grad_fn(params, mb)
                acc = jax.tree.map(lambda a, b: a + b.astype(f32), acc, g)
                return (acc, lacc + lo), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, f32), params)
            mbs = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum) +
                                    x.shape[1:]), batch)
            (gsum, lsum), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), f32)), mbs)
            grads = pin(jax.tree.map(lambda g: g / grad_accum, gsum))
            loss = lsum / grad_accum
        new_params, new_opt, om = adamw_update(params, grads, opt_state,
                                               opt_cfg)
        metrics = {"loss": loss, **om}
        return new_params, new_opt, metrics

    return train_step


def train_state_template(model: Model):
    """ShapeDtypeStruct pytree for (params, opt_state) — dry-run inputs."""
    from repro.models.param import template_shapes
    ptpl = template_shapes(model.param_template())
    opt = {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "m": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, f32), ptpl),
        "v": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, f32), ptpl),
        "master": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, f32),
                               ptpl),
    }
    return ptpl, opt
