from repro.train.loop import (TrainState, chunked_cross_entropy,
                              make_train_step, train_state_template)

__all__ = ["TrainState", "chunked_cross_entropy", "make_train_step",
           "train_state_template"]
