"""Trainer: data-lake input + LST checkpoints + XTable sync + restart.

The fault-tolerance loop this implements (designed for 1000+ nodes, exercised
here at host scale):

1. loader reads token shards from an LST table (any format),
2. every ``save_every`` steps the full train state (params + optimizer +
   loader cursor) is committed as an LST checkpoint; XTable translates the
   metadata to the other formats asynchronously,
3. on (re)start, the trainer restores the latest *committed* snapshot —
   through ANY format — and resumes byte-exactly (loader cursor included),
4. elastic restart: the restored host arrays are ``device_put`` against
   whatever mesh the new job has (the chunk metadata carries global shapes,
   so any device count works).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax

from repro.checkpoint import LSTCheckpointManager
from repro.data import LakeDataLoader
from repro.models.model import Model
from repro.models.param import init_params, template_shapes
from repro.optim import AdamWConfig, adamw_init
from repro.train.loop import make_train_step


@dataclass
class TrainerConfig:
    steps: int = 100
    save_every: int = 20
    log_every: int = 10
    ckpt_format: str = "hudi"
    sync_targets: tuple = ("iceberg", "delta")
    restore_format: str | None = None     # restore via a different connector
    opt: AdamWConfig = field(default_factory=AdamWConfig)
    grad_accum: int = 1
    ce_chunk: int = 128


class Trainer:
    def __init__(self, model: Model, loader: LakeDataLoader, fs,
                 ckpt_path: str, cfg: TrainerConfig = TrainerConfig()):
        self.model = model
        self.loader = loader
        self.cfg = cfg
        self.ckpt = LSTCheckpointManager(
            fs, ckpt_path, fmt=cfg.ckpt_format,
            sync_targets=cfg.sync_targets)
        self.step_fn = jax.jit(make_train_step(
            model, cfg.opt, grad_accum=cfg.grad_accum,
            ce_chunk=cfg.ce_chunk))
        self.params = None
        self.opt_state = None
        self.start_step = 0
        self.history: list = []

    # ------------------------------------------------------------ lifecycle
    def init_or_restore(self, seed: int = 0) -> int:
        tpl = self.model.param_template()
        try:
            fmt = self.cfg.restore_format or self.cfg.ckpt_format
            shapes = template_shapes(tpl)
            state_tpl = {"params": shapes,
                         "opt": _opt_template(shapes)}
            step, state = self.ckpt.restore_pytree(state_tpl, fmt=fmt)
            self.params = jax.tree.map(jax.numpy.asarray, state["params"])
            self.opt_state = jax.tree.map(jax.numpy.asarray, state["opt"])
            cursor = int(self.ckpt.latest_meta(fmt).get("loader.row", 0))
            self.loader.load_state_dict({"row": cursor})
            self.start_step = step + 1
        except (FileNotFoundError, KeyError):
            self.params = init_params(tpl, jax.random.PRNGKey(seed))
            self.opt_state = adamw_init(self.params)
            self.start_step = 0
        return self.start_step

    def save(self, step: int) -> None:
        self.ckpt.save(step, {"params": self.params, "opt": self.opt_state},
                       extra_meta={"loader.row": str(self.loader.row)})

    # ----------------------------------------------------------------- run
    def run(self) -> list:
        if self.params is None:
            self.init_or_restore()
        t0 = time.perf_counter()
        for step in range(self.start_step, self.cfg.steps):
            batch = self.loader.next_batch()
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            self.history.append((step, loss))
            if step % self.cfg.log_every == 0:
                dt = time.perf_counter() - t0
                print(f"step {step:5d} loss {loss:.4f} "
                      f"({dt:.1f}s)", flush=True)
            if self.cfg.save_every and step and \
                    step % self.cfg.save_every == 0:
                self.save(step)
                self.ckpt.gc()
        self.save(self.cfg.steps - 1)
        return self.history


def _opt_template(param_shapes):
    import jax.numpy as jnp
    f32 = jnp.float32
    return {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "m": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, f32),
                          param_shapes),
        "v": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, f32),
                          param_shapes),
        "master": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, f32),
                               param_shapes),
    }
