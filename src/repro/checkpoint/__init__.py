from repro.checkpoint.manager import LSTCheckpointManager

__all__ = ["LSTCheckpointManager"]
