"""Checkpoints as log-structured tables — XTable in action inside the trainer.

Every checkpoint save is an LST commit: immutable tensor-chunk data files +
a small metadata commit, partitioned by ``step``. The trainer writes through
ONE format (Hudi-style timeline: cheapest streaming commits); after each
save the XTable core translates the metadata so evaluators/servers can read
the same files through Iceberg/Delta readers (the paper's Scenario 2/3, with
engines = trainer/evaluator/server):

* save   = write chunks -> atomic commit -> (async) XTable incremental sync
* restore = pick a snapshot through ANY format's reader, reassemble, reshard
* crash-safety = a torn save never commits, so restart sees the previous
  snapshot (the LST ACID story is the checkpoint fault-tolerance story)
* GC     = replace-commit dropping old steps, but only steps already synced
  to every target (translated metadata keeps files alive — deleting a file
  still referenced by a target's snapshot would corrupt that format's view)
"""

from __future__ import annotations

import json
import threading

import numpy as np

from repro.core import SyncConfig, Telemetry, run_sync
from repro.lst import chunkfile
from repro.lst.schema import Field, PartitionSpec, Schema
from repro.lst.table import FORMATS

try:
    import ml_dtypes
    _BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:                                   # pragma: no cover
    _BF16 = None

CKPT_SCHEMA = Schema([Field("tensor", "binary"), Field("step", "int64")])
MAX_CHUNK_BYTES = 64 * 2**20


def _leaf_paths(pytree) -> list:
    import jax
    flat, _ = jax.tree_util.tree_flatten_with_path(pytree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((name, leaf))
    return out


def _encode(arr: np.ndarray) -> tuple[np.ndarray, str]:
    if _BF16 is not None and arr.dtype == _BF16:
        return arr.view(np.uint16), "bfloat16"
    return arr, str(arr.dtype)


def _decode(arr: np.ndarray, logical: str) -> np.ndarray:
    if logical == "bfloat16" and _BF16 is not None:
        return arr.view(_BF16)
    return arr.astype(np.dtype(logical), copy=False) \
        if str(arr.dtype) != logical else arr


class LSTCheckpointManager:
    def __init__(self, fs, base_path: str, *, fmt: str = "hudi",
                 sync_targets: tuple = ("iceberg", "delta"),
                 keep_last: int = 3, async_sync: bool = False,
                 telemetry: Telemetry | None = None):
        self.fs = fs
        self.base = base_path
        self.fmt = fmt
        self.sync_targets = tuple(t for t in sync_targets if t != fmt)
        self.keep_last = keep_last
        self.async_sync = async_sync
        self.telemetry = telemetry or Telemetry()
        self._sync_thread: threading.Thread | None = None
        cls = FORMATS[fmt]
        if cls.exists(fs, base_path):
            self.handle = cls.open(fs, base_path)
        else:
            self.handle = cls.create(fs, base_path, CKPT_SCHEMA,
                                     PartitionSpec(["step"]),
                                     {"table.kind": "checkpoint"})

    # ------------------------------------------------------------------ save
    def save(self, step: int, pytree, extra_meta: dict | None = None) -> str:
        """Write one checkpoint commit; returns the commit id.

        Re-saving an existing step is a replace-commit (old chunk files of
        that step are dropped from the live set atomically with the new
        adds — readers never see a mixed step).
        """
        import uuid
        tag = uuid.uuid4().hex[:8]
        adds = []
        for name, leaf in _leaf_paths(pytree):
            arr = np.asarray(leaf)
            enc, logical = _encode(arr)
            flat = enc.reshape(-1)
            n_shards = max(1, -(-flat.nbytes // MAX_CHUNK_BYTES))
            per = -(-flat.size // n_shards)
            for si in range(n_shards):
                part = flat[si * per:(si + 1) * per]
                rel = (f"step={step}/{name.replace('/', '.')}"
                       f"_{si:03d}_{tag}.chunk")
                meta = chunkfile.write_chunk(
                    self.fs, self.base, rel, {"tensor": part},
                    partition_values={"step": str(step)},
                    extra={"leaf": name, "global_shape": list(arr.shape),
                           "dtype": logical, "offset": si * per,
                           "nshards": n_shards})
                adds.append(meta)
        stale = [p for p, f in self.handle.snapshot().files.items()
                 if int(f.partition_values["step"]) == step]
        commit = self.handle.commit(
            adds, stale, operation="checkpoint",
            extra_meta={"step": str(step), **(extra_meta or {})})
        self.telemetry.record("ckpt", self.fmt, "save",
                              f"step {step}: {len(adds)} chunks")
        self._kick_sync()
        return commit

    # ------------------------------------------------------------------ sync
    def _sync_config(self) -> SyncConfig:
        return SyncConfig.from_dict({
            "sourceFormat": self.fmt.upper(),
            "targetFormats": [t.upper() for t in self.sync_targets],
            "datasets": [{"tableBasePath": self.base}]})

    def sync_now(self):
        """Run the XTable translation (trainer never blocks on this)."""
        if not self.sync_targets:
            return []
        return run_sync(self._sync_config(), self.fs, self.telemetry)

    def _kick_sync(self) -> None:
        if not self.sync_targets:
            return
        if not self.async_sync:
            self.sync_now()
            return
        if self._sync_thread and self._sync_thread.is_alive():
            return          # a sync is already running; next save re-kicks
        self._sync_thread = threading.Thread(target=self.sync_now,
                                             daemon=True)
        self._sync_thread.start()

    def wait_for_sync(self) -> None:
        if self._sync_thread:
            self._sync_thread.join()

    # --------------------------------------------------------------- restore
    def steps(self, fmt: str | None = None) -> list[int]:
        handle = self._reader(fmt)
        st = handle.snapshot()
        return sorted({int(f.partition_values["step"])
                       for f in st.files.values()})

    def _reader(self, fmt: str | None):
        fmt = fmt or self.fmt
        if fmt == self.fmt:
            return self.handle
        return FORMATS[fmt].open(self.fs, self.base)

    def latest_meta(self, fmt: str | None = None) -> dict:
        """User metadata of the newest commit, via any format's reader
        (XTable carries source commit metadata through the IR)."""
        handle = self._reader(fmt)
        out = dict(handle.snapshot().properties)
        if hasattr(handle, "latest_extra_metadata"):
            out.update(handle.latest_extra_metadata())
        else:
            try:
                _, _, _, info = handle.changes(handle.current_version())
                out.update({k: v for k, v in info.items()
                            if isinstance(v, str)})
                if isinstance(info.get("xtable"), dict):
                    out.update(info["xtable"])
            except Exception:
                pass
        return out

    def restore(self, step: int | None = None, *, fmt: str | None = None,
                validate: bool = True, state=None) -> tuple[int, dict]:
        """Reassemble a checkpoint pytree (as a flat {leaf-path: ndarray}).

        ``fmt`` may be any synced format — restoring through a different
        format than was written is the XTable round-trip, exercised by the
        integration tests. Elastic resharding happens on the caller side via
        ``jax.device_put`` with the new mesh's shardings.

        ``state`` restores through a pre-resolved ``TableState`` (a read
        plane's pinned snapshot) instead of replaying the format's
        metadata here — the restore then spends storage requests only on
        the chunk bodies.
        """
        st = state if state is not None else self._reader(fmt).snapshot()
        steps = sorted({int(f.partition_values["step"])
                        for f in st.files.values()})
        if not steps:
            raise FileNotFoundError("no checkpoints")
        step = step if step is not None else steps[-1]
        by_leaf: dict[str, list] = {}
        for f in st.files.values():
            if int(f.partition_values["step"]) != step:
                continue
            by_leaf.setdefault(f.extra["leaf"], []).append(f)
        out = {}
        for leaf, metas in by_leaf.items():
            metas.sort(key=lambda m: m.extra["offset"])
            parts = []
            for m in metas:
                cols, extra = chunkfile.read_chunk(self.fs, self.base, m.path)
                arr = cols["tensor"]
                if validate:
                    st_ = m.column_stats.get("tensor")
                    if st_ is not None and st_.count != arr.shape[0]:
                        raise IOError(f"integrity: {m.path} count mismatch")
                parts.append(arr)
            extra = metas[0].extra
            full = np.concatenate(parts) if len(parts) > 1 else parts[0]
            out[leaf] = _decode(full, extra["dtype"]).reshape(
                [int(x) for x in extra["global_shape"]])
        return step, out

    def restore_pytree(self, template, step: int | None = None,
                       fmt: str | None = None, state=None):
        """Restore into the structure of ``template`` (shape-checked)."""
        import jax
        step, flat = self.restore(step, fmt=fmt, state=state)
        leaves = _leaf_paths(template)
        out = []
        for name, leaf in leaves:
            if name not in flat:
                raise KeyError(f"checkpoint missing leaf {name}")
            arr = flat[name]
            want = tuple(leaf.shape)
            if tuple(arr.shape) != want:
                raise ValueError(f"{name}: shape {arr.shape} != {want}")
            out.append(arr)
        treedef = jax.tree.structure(template)
        return step, jax.tree.unflatten(treedef, out)

    # -------------------------------------------------------------------- gc
    def gc(self) -> list[int]:
        """Drop old steps (keep_last), but never steps the targets still
        reference (GC safety across translated metadata)."""
        steps = self.steps()
        if len(steps) <= self.keep_last:
            return []
        candidates = steps[:-self.keep_last]
        # SAFETY: only collect when every target has been translated up to
        # the CURRENT source head — a lagging target's snapshot still
        # references the candidate steps' files, and deleting them would
        # corrupt that format's view of the single data copy.
        head = self.handle.current_version()
        token_ok = True
        for t in self.sync_targets:
            try:
                reader = self._reader(t)
                props = reader.properties() if t != "hudi" else \
                    reader.latest_extra_metadata()
                tok = props.get("xtable.lastSyncedSourceCommit")
                if tok != head:
                    token_ok = False
            except FileNotFoundError:
                token_ok = False
        if not token_ok:
            self.telemetry.record("ckpt", self.fmt, "gc",
                                  "deferred: targets not fully synced")
            return []
        st = self.handle.snapshot()
        removes = [p for p, f in st.files.items()
                   if int(f.partition_values["step"]) in set(candidates)]
        if removes:
            self.handle.commit([], removes, operation="gc",
                               extra_meta={"gc.steps": json.dumps(candidates)})
            self._kick_sync()
        return candidates
