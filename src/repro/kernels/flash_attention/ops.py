"""Jitted public wrapper for the flash attention kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "bq", "bk",
                                   "interpret"))
def flash_attention_op(q, k, v, *, causal: bool = True, window: int = 0,
                       softcap: float = 0.0, bq: int = 128, bk: int = 128,
                       interpret: bool = False):
    return flash_attention(q, k, v, causal=causal, window=window,
                           softcap=softcap, bq=bq, bk=bk,
                           interpret=interpret)
