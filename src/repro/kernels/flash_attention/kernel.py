"""Flash attention Pallas TPU kernel (fwd): causal/sliding-window/softcap/GQA.

Grid: (batch, q_head, q_blocks, k_blocks) — k innermost, so the online
softmax state (m, l, acc) lives in VMEM scratch and persists across the
k-block sweep for one q block. BlockSpecs stage (bq, dh) query tiles and
(bk, dh) key/value tiles HBM->VMEM; dh is the MXU lane dim (128-aligned).

GQA is handled by the k/v index maps (kv head = q head // group) — no
repeated KV in HBM, the repeat happens implicitly via block addressing.
Causal/window structure is exploited at block granularity: fully-masked
k blocks are skipped under ``pl.when`` (no MXU work issued).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, window: int, softcap: float, scale: float,
            bq: int, bk: int, nk: int, sk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * bq
    k_lo = ki * bk
    run = jnp.bool_(True)
    if causal:
        run = k_lo <= q_lo + bq - 1            # block not fully in the future
        if window:
            run &= (k_lo + bk - 1) >= (q_lo - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale     # (bq, dh)
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # (bk, dh)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < sk
        if causal:
            mask &= kpos <= qpos
            if window:
                mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, bq: int = DEFAULT_BQ,
                    bk: int = DEFAULT_BK, interpret: bool = False):
    """q: (b, sq, h, dh); k/v: (b, sk, kv, dh) -> (b, sq, h, dh)."""
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    bq = min(bq, sq)
    bk = min(bk, sk)
    nq = -(-sq // bq)
    nk = -(-sk // bk)
    scale = 1.0 / math.sqrt(dh)

    kern = functools.partial(
        _kernel, causal=causal, window=window, softcap=softcap, scale=scale,
        bq=bq, bk=bk, nk=nk, sk=sk)
    return pl.pallas_call(
        kern,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, dh),
                         lambda b_, h_, q_, k_: (b_, q_, h_, 0)),
            pl.BlockSpec((1, bk, 1, dh),
                         lambda b_, h_, q_, k_: (b_, k_, h_ // g, 0)),
            pl.BlockSpec((1, bk, 1, dh),
                         lambda b_, h_, q_, k_: (b_, k_, h_ // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, dh),
                               lambda b_, h_, q_, k_: (b_, q_, h_, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
