"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax.numpy as jnp
import jax

f32 = jnp.float32


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0):
    """q: (b, sq, h, dh); k/v: (b, sk, kv, dh) -> (b, sq, h, dh)."""
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    kh = jnp.repeat(k, g, axis=2) if g > 1 else k
    vh = jnp.repeat(v, g, axis=2) if g > 1 else v
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(f32), kh.astype(f32))
    s = s / jnp.sqrt(jnp.asarray(dh, f32))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
        if window:
            mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p, vh.astype(f32)).astype(q.dtype)
