"""Jitted public wrapper for the decode attention kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.decode_attention.kernel import decode_attention


@partial(jax.jit, static_argnames=("window", "softcap", "bk", "interpret"))
def decode_attention_op(q, k_cache, v_cache, lengths, *, window: int = 0,
                        softcap: float = 0.0, bk: int = 256,
                        interpret: bool = False):
    return decode_attention(q, k_cache, v_cache, lengths, window=window,
                            softcap=softcap, bk=bk, interpret=interpret)
