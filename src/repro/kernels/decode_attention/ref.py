"""Pure-jnp oracle for the decode attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32


def decode_attention_ref(q, k_cache, v_cache, lengths, *, window: int = 0,
                         softcap: float = 0.0):
    """q: (b, h, dh); k/v_cache: (b, S, kv, dh); lengths: (b,) valid prefix.

    Attends to cache positions [max(0, len-window), len) per sequence.
    """
    b, h, dh = q.shape
    S, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    kh = jnp.repeat(k_cache, g, axis=2) if g > 1 else k_cache
    vh = jnp.repeat(v_cache, g, axis=2) if g > 1 else v_cache
    s = jnp.einsum("bhd,bshd->bhs", q.astype(f32), kh.astype(f32))
    s = s / jnp.sqrt(jnp.asarray(dh, f32))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    pos = jnp.arange(S)[None, :]
    valid = pos < lengths[:, None]
    if window:
        valid &= pos >= (lengths[:, None] - window)
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, vh.astype(f32)).astype(q.dtype)
