"""Flash-decode Pallas TPU kernel: one query token vs. a long KV cache.

Grid: (batch, head, k_blocks) — the k sweep is innermost and sequential on
TPU, so the online-softmax state lives in VMEM scratch (same structure as
the prefill kernel but with a (1, dh) query tile; the MXU work per block is
a (bk, dh) x (dh,) matvec batched over the 8-sublane q replication).

The valid prefix length arrives via scalar prefetch (SMEM) so block masks
are computed without streaming a position tensor from HBM.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 256
NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            window: int, softcap: float, scale: float, bk: int, nk: int):
    bi = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[bi]
    k_lo = ki * bk
    run = k_lo < length
    if window:
        run &= (k_lo + bk) > jnp.maximum(length - window, 0)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0, :].astype(jnp.float32) * scale       # (dh,)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bk, dh)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.sum(k * q[None, :], axis=1)                  # (bk,)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bk,), 0)
        mask = kpos < length
        if window:
            mask &= kpos >= (length - window)
        s = jnp.where(mask, s, NEG_INF)
        # When S % bk != 0 the last block reads past the cache end; those
        # lanes are masked (kpos >= S >= length) but the padded v rows hold
        # garbage, and 0 * NaN = NaN would poison the accumulator.
        v = jnp.where(mask[:, None], v, 0.0)

        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
        acc_ref[...] = acc_ref[...] * alpha + \
            jnp.sum(p[:, None] * v, axis=0, keepdims=True)
        m_ref[0] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0, :] = (acc_ref[0] /
                          jnp.maximum(l_ref[0], 1e-30)).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, window: int = 0,
                     softcap: float = 0.0, bk: int = DEFAULT_BK,
                     interpret: bool = False):
    """q: (b, h, dh); k/v_cache: (b, S, kv, dh); lengths: (b,) -> (b, h, dh)."""
    b, h, dh = q.shape
    S, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    bk = min(bk, S)
    nk = -(-S // bk)
    scale = 1.0 / math.sqrt(dh)

    kern = functools.partial(_kernel, window=window, softcap=softcap,
                             scale=scale, bk=bk, nk=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h, nk),
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda b_, h_, k_, lens: (b_, h_, 0)),
            pl.BlockSpec((1, bk, 1, dh),
                         lambda b_, h_, k_, lens: (b_, k_, h_ // g, 0)),
            pl.BlockSpec((1, bk, 1, dh),
                         lambda b_, h_, k_, lens: (b_, k_, h_ // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dh),
                               lambda b_, h_, k_, lens: (b_, h_, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(lengths, q, k_cache, v_cache)
