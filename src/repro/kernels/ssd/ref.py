"""Pure-jnp oracle for the SSD (Mamba-2) chunk-scan kernel.

Direct (non-chunked) O(s^2)-free recurrence: sequential state update per
position — the ground truth both the kernel and the chunked XLA path
(models/ssm.py) must match.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32


def ssd_ref(x, dt, A, B, C):
    """Sequential SSD recurrence.

    x: (b, s, h, p); dt: (b, s, h); A: (h,) (negative); B/C: (b, s, g, n).
    Returns y: (b, s, h, p) with y_t = C_t . S_t,
    S_t = S_{t-1} * exp(dt_t A) + dt_t B_t (x) x_t.
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hpg = h // g

    Bh = jnp.repeat(B, hpg, axis=2) if g > 1 else \
        jnp.broadcast_to(B, (b, s, h, n))
    Ch = jnp.repeat(C, hpg, axis=2) if g > 1 else \
        jnp.broadcast_to(C, (b, s, h, n))

    def step(state, inp):
        xt, dtt, bt, ct = inp                      # (b,h,p),(b,h),(b,h,n),..
        decay = jnp.exp(dtt * A[None, :])          # (b,h)
        upd = dtt[..., None, None] * bt[..., :, None] * xt[..., None, :]
        state = state * decay[..., None, None] + upd
        y = jnp.einsum("bhn,bhnp->bhp", ct, state)
        return state, y

    init = jnp.zeros((b, h, n, p), f32)
    xs = (jnp.moveaxis(x.astype(f32), 1, 0), jnp.moveaxis(dt.astype(f32), 1, 0),
          jnp.moveaxis(Bh.astype(f32), 1, 0), jnp.moveaxis(Ch.astype(f32), 1, 0))
    final, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1), final
