"""Jitted public wrapper for the SSD chunk-scan kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssd.kernel import ssd_chunk_scan


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_op(x, dt, A, B, C, *, chunk: int = 256, interpret: bool = False):
    return ssd_chunk_scan(x, dt, A, B, C, chunk=chunk, interpret=interpret)
