"""SSD (Mamba-2 state-space duality) chunk-scan Pallas TPU kernel.

Grid: (batch, head, chunks) — chunks innermost and sequential, so the
inter-chunk state S (n x p) lives in VMEM scratch and is carried across
grid steps (the TPU analogue of mamba2's persistent-state triton kernel;
sequential grid order replaces the GPU's software pipelining).

Per chunk (length Q):
  intra:  Y += ((C B^T) o L) (dt * x)      L = masked cumulative decay
  inter:  Y += (C o exp(cum)) S_prev
  state:  S  = S_prev * exp(total) + B^T ((dt * x) o exp(total - cum))

All contractions are (Q x n)(n x Q)/(Q x Q)(Q x p) MXU shapes with Q, n, p
multiples of the 128-lane granule at production sizes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_out_ref, state_ref,
            *, q: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)        # (Q, p)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)      # (Q,)
    A = a_ref[0].astype(jnp.float32)              # ()
    B = b_ref[0, 0, 0].astype(jnp.float32)        # (Q, n)
    C = c_ref[0, 0, 0].astype(jnp.float32)        # (Q, n)

    dA = dt * A                                   # (Q,) negative
    cum = jnp.cumsum(dA)                          # (Q,)
    total = cum[-1]

    # ---- intra-chunk (quadratic in Q) ----
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))      # (Q,Q)
    diff = cum[:, None] - cum[None, :]                             # (Q,Q)
    iq = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    ik = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(ik <= iq, jnp.exp(diff), 0.0)
    xdt = x * dt[:, None]                                          # (Q,p)
    y = jax.lax.dot_general(cb * L, xdt, (((1,), (0,)), ((), ())))

    # ---- inter-chunk ----
    s_prev = state_ref[...]                                        # (n,p)
    y += jax.lax.dot_general(C * jnp.exp(cum)[:, None], s_prev,
                             (((1,), (0,)), ((), ())))

    # ---- state update ----
    w = jnp.exp(total - cum)                                       # (Q,)
    bx = jax.lax.dot_general(B, xdt * w[:, None],
                             (((0,), (0,)), ((), ())))             # (n,p)
    state_ref[...] = s_prev * jnp.exp(total) + bx

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        s_out_ref[0, 0] = state_ref[...]


def ssd_chunk_scan(x, dt, A, B, C, *, chunk: int = 256,
                   interpret: bool = False):
    """x: (b, s, h, p); dt: (b, s, h); A: (h,); B/C: (b, s, g, n).

    Returns (y: (b, s, h, p), final_state: (b, h, n, p)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hpg = h // g
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    # (b, nc, Q, ...) chunked layouts, head-major for clean block addressing
    xc = x.reshape(b, nc, q, h, p).transpose(0, 3, 1, 2, 4)    # (b,h,nc,Q,p)
    dtc = dt.reshape(b, nc, q, h).transpose(0, 3, 1, 2)        # (b,h,nc,Q)
    Bc = B.reshape(b, nc, q, g, n).transpose(0, 3, 1, 2, 4)    # (b,g,nc,Q,n)
    Cc = C.reshape(b, nc, q, g, n).transpose(0, 3, 1, 2, 4)

    kern = functools.partial(_kernel, q=q, nc=nc)
    y, state = pl.pallas_call(
        kern,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p),
                         lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
            pl.BlockSpec((1, 1, 1, q),
                         lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1,), lambda b_, h_, c_: (h_,)),
            pl.BlockSpec((1, 1, 1, q, n),
                         lambda b_, h_, c_: (b_, h_ // hpg, c_, 0, 0)),
            pl.BlockSpec((1, 1, 1, q, n),
                         lambda b_, h_, c_: (b_, h_ // hpg, c_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, q, p),
                         lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, q, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xc, dtc, A, Bc, Cc)
    y = y.transpose(0, 2, 3, 1, 4).reshape(b, s, h, p)
    return y, state
