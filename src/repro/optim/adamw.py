"""AdamW with fp32 master weights + moments, bf16 compute params.

Optimizer state is sharded identically to the parameters (ZeRO-style: the
FSDP/TP axes of each param shard its moments), which the dry-run verifies at
512 devices. Optional int8 gradient compression (stochastic rounding around
a per-tensor scale) models DCN-frugal cross-pod all-reduce.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

f32 = jnp.float32


@dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    compress_grads: bool = False   # int8 stochastic-rounding all-reduce model


def adamw_init(params):
    def zeros(p):
        return jnp.zeros(p.shape, f32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "master": jax.tree.map(lambda p: p.astype(f32), params),
    }


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(f32)))
                        for g in jax.tree.leaves(tree)))


def compress_int8(g, key):
    """Stochastic-rounding int8 quantization (gradient compression model)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    noise = jax.random.uniform(key, g.shape, f32, -0.5, 0.5)
    q = jnp.clip(jnp.round(g / scale + noise), -127, 127).astype(jnp.int8)
    return q.astype(f32) * scale


def adamw_update(params, grads, state, cfg: AdamWConfig, lr=None):
    """One AdamW step. grads may be bf16; math in fp32."""
    from repro.optim.schedule import warmup_cosine
    step = state["step"] + 1
    if lr is None:
        lr = warmup_cosine(step, peak_lr=cfg.peak_lr,
                           warmup_steps=cfg.warmup_steps,
                           total_steps=cfg.total_steps)
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1c = 1 - cfg.b1 ** step.astype(f32)
    b2c = 1 - cfg.b2 ** step.astype(f32)

    def upd(g, m, v, master):
        g = g.astype(f32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        new_master = master - lr * (mh / (jnp.sqrt(vh) + cfg.eps) +
                                    cfg.weight_decay * master)
        return m, v, new_master

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_w = jax.tree.leaves(state["master"])
    out = [upd(g, m, v, w) for g, m, v, w in
           zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_master = jax.tree.unflatten(tdef, [o[2] for o in out])
    param_dtypes = jax.tree.map(lambda p: p.dtype, params)
    new_params = jax.tree.map(lambda w, d: w.astype(d), new_master,
                              param_dtypes)
    new_state = {"step": step, "m": new_m, "v": new_v, "master": new_master}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
