"""Logical-axis sharding: one rule table maps model semantics to mesh axes.

Every parameter/activation dimension carries a logical name ("embed",
"heads", "mlp", ...). A ``Sharder`` resolves names to mesh axes with
divisibility checking and per-tensor duplicate avoidance, producing
``PartitionSpec``s for:

* parameter templates (FSDP over ``data``, TP over ``model``, EP for MoE)
* activation constraints inside the model (batch over ``pod``+``data``,
  heads/mlp/vocab over ``model``, optional KV-sequence sharding over
  ``data`` for long-context decode)

Rules are *preference chains*: ``"experts": (("model",), ("data",))`` tries
expert-parallelism over ``model`` first, falls back to ``data``, then
replicates — so the same table serves dbrx (16 experts, EP=16) and granite
(40 experts, replicated expert axis but sharded d_ff) without per-arch code.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
from jax.sharding import PartitionSpec as P

# logical axis -> chain of candidate mesh-axis groups (each group is used
# jointly, e.g. batch over pod AND data).
DEFAULT_RULES: dict = {
    # parameters
    "vocab": (("model",),),
    "embed": (("data",),),                  # FSDP axis
    "mlp": (("model",),),                   # TP axis
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "head_dim": (),
    "experts": (("model",), ("data",)),     # EP preference chain
    "layers": (),                           # scan dim never sharded
    # activations
    "batch": (("pod", "data"),),
    "seq": (),
    # cycle-boundary activations (= remat residuals): sequence dim sharded
    # over the TP axis so saved activations are 16x smaller (Megatron-SP)
    "act_seq": (("model",),),
    # score q-dim fallback sharding for archs whose head count does not
    # divide the TP axis (granite: 24 heads on model=16)
    "attn_q": (("model",),),
    "kvseq": (),                            # set to (("data",),) for 500k decode
    "frames": (),
    None: (),
}


def _axes_in_mesh(group, mesh_axes: dict) -> tuple:
    return tuple(a for a in group if a in mesh_axes)


@dataclass
class Sharder:
    mesh_axes: dict                       # name -> size, e.g. {"data":16,...}
    rules: dict = field(default_factory=lambda: dict(DEFAULT_RULES))

    @staticmethod
    def for_mesh(mesh, overrides: dict | None = None) -> "Sharder":
        rules = dict(DEFAULT_RULES)
        rules.update(overrides or {})
        return Sharder(dict(zip(mesh.axis_names, mesh.devices.shape)), rules)

    @staticmethod
    def null() -> "Sharder":
        """Single-device: everything replicated (smoke tests)."""
        return Sharder({})

    # ------------------------------------------------------------ resolution
    def resolve(self, axes: tuple, shape: tuple) -> P:
        """Logical axes tuple -> PartitionSpec, divisible + duplicate-free."""
        used: set = set()
        out = []
        for name, dim in zip(axes, shape):
            chain = self.rules.get(name, ())
            picked = None
            for group in chain:
                grp = tuple(a for a in _axes_in_mesh(group, self.mesh_axes)
                            if a not in used)
                if not grp:
                    continue
                total = math.prod(self.mesh_axes[a] for a in grp)
                if dim % total == 0:
                    picked = grp
                    used.update(grp)
                    break
            out.append(picked if picked is None or len(picked) > 1
                       else picked[0])
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    # -------------------------------------------------------------- helpers
    def template_pspecs(self, tpl):
        """Param template -> pytree of PartitionSpec."""
        from repro.models.param import is_spec
        return jax.tree.map(lambda s: self.resolve(s.axes, s.shape), tpl,
                            is_leaf=is_spec)

    def constrain(self, x, *axes):
        """Sharding constraint on an activation (no-op without a mesh)."""
        if not self.mesh_axes:
            return x
        spec = self.resolve(tuple(axes), x.shape)
        return jax.lax.with_sharding_constraint(x, spec)

    def __call__(self, x, *axes):
        return self.constrain(x, *axes)
