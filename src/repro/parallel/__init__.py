from repro.parallel.sharding import Sharder, DEFAULT_RULES

__all__ = ["Sharder", "DEFAULT_RULES"]
