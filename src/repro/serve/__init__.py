from repro.serve.engine import ServeEngine
from repro.serve.read_plane import (ReadResult, ScanResult, SnapshotServer,
                                    TableSnapshot)

__all__ = ["ServeEngine", "SnapshotServer", "TableSnapshot", "ReadResult",
           "ScanResult"]
