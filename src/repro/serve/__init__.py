"""Read-side serving: snapshot read plane + lake-restoring serve engine.

``read_plane`` is the storage-facing half — conditional-GET snapshot
serving, stats-pushdown scans, and catalog-pinned cross-table group
reads over the shared metadata cache.  ``engine`` is the model-facing
half: a batched decode engine whose weights restore through any
XTable-translated view of a lake checkpoint table, addressed by path or
by catalog name.
"""

from repro.serve.engine import ServeEngine
from repro.serve.read_plane import (GroupSnapshot, ReadResult, ScanResult,
                                    SnapshotServer, TableSnapshot)

__all__ = ["ServeEngine", "SnapshotServer", "TableSnapshot", "ReadResult",
           "ScanResult", "GroupSnapshot"]
