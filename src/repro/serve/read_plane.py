"""Snapshot-serving read plane: memoized head-keyed snapshots over any view.

The paper's interoperability claim is read-side — write once, read in any
format — but the batch pipeline only optimized the *write* path to
O(change).  A naive reader fleet still replays metadata per reader, so
read traffic scales O(readers x history) in storage requests.  This
module is the read-side counterpart (ROADMAP open item 3): a
:class:`SnapshotServer` layered on the shared
:class:`~repro.core.metadata_cache.MetadataCache` that serves
**immutable table snapshots keyed by head token** in any format view,
with HTTP-conditional-GET economics:

* **Not-modified is free.**  A reader presenting its last-seen token gets
  ``not_modified`` for an unchanged table at zero storage requests; the
  server itself spends at most ONE O(1) head probe per table per
  ``ttlMs`` window, amortized across every reader of that table.  A
  co-located daemon removes even that probe: its post-drain
  :meth:`SnapshotServer.publish` hands the just-synced head token over,
  resetting the window.
* **Change is paid once.**  A moved head costs one tail-only index
  refresh (O(new commits)) shared by every waiting reader — the index's
  single-flight :meth:`~repro.core.metadata_cache.TableMetadataIndex
  .refresh_to` serializes racing readers so N concurrent cold readers
  trigger exactly 1 replay, not N.
* **Snapshots are immutable.**  A served :class:`TableSnapshot` never
  changes under the reader, however many commits the daemon lands
  mid-read; new heads become NEW snapshots in a ``maxSnapshots``-bounded
  LRU.

Catalog-pinned reads extend the same machinery across *tables*:
:meth:`SnapshotServer.read_group` resolves every table of a dataset at
ONE catalog generation (``lst/catalog/``) and serves each member pinned
at its published ``(token, commit)`` — the token is the LRU key shared
with the conditional-GET path (a co-located daemon's eager publish means
group members are usually already memoized), and the commit pins the
exact published state via the index's ``state_at`` even after the table
has moved on.  A reader joining orders against customers through a
:class:`GroupSnapshot` can never observe tables from different publish
generations.

On top of snapshots, :meth:`SnapshotServer.scan` adds predicate pushdown
into the chunkfile stats footers: chunks whose min/max/nan_count refute
the predicate are pruned without touching their column data, footers are
fetched through the two-round batched footer read and cached immutably
by chunk path (chunks are write-once — the footer cache never
invalidates), and the surviving bodies come back in one pipelined batch
round.

With the CHK3 column-offset index (which rides for free in the cached
footer entries) the scan also pushes **projection** below the storage
round trip: only the requested + predicate columns' byte ranges are
fetched, adjacent ranges coalesced, all files per phase in one pipelined
``read_many_ranges`` round.  Predicated scans are **late-materializing**
by default (``readPlane.lateMaterialization``): phase 1 fetches just the
predicate columns and evaluates the row masks, chunks whose mask comes
back all-False are dropped before their remaining columns are ever
fetched (the data refutes what the stats could not), and phase 2 fetches
only the surviving chunks' projected columns.  Results stay
byte-identical to a full-body scan; CHK2 files transparently fall back
to full-body reads inside the same batch rounds.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from repro.core.config import ReadPlaneOptions
from repro.core.metadata_cache import MetadataCache
from repro.lst import chunkfile
from repro.lst.schema import TableState
from repro.lst.table import Predicate

__all__ = ["OK", "NOT_MODIFIED", "TableSnapshot", "ReadResult",
           "ScanResult", "GroupSnapshot", "ReadPlaneStats",
           "SnapshotServer"]

OK = "ok"
NOT_MODIFIED = "not_modified"


@dataclass(frozen=True)
class TableSnapshot:
    """One immutable, head-token-keyed view of a table.

    ``token`` is the opaque head token of the ``view_format`` log at
    serve time (the conditional-GET ETag); ``head_commit`` is the
    format-native commit id the ``state`` was folded at.  The state is
    shared with the metadata index's memo and is never mutated after
    construction — later commits produce new snapshots.
    """
    base_path: str
    view_format: str
    token: str
    head_commit: str
    state: TableState
    created_at: float = 0.0

    @property
    def files(self) -> dict:
        return self.state.files

    @property
    def schema(self):
        return self.state.schema


@dataclass(frozen=True)
class ReadResult:
    """``status == "not_modified"`` carries no snapshot (the reader's own
    copy is current); ``"ok"`` carries the served snapshot."""
    status: str
    token: str
    snapshot: TableSnapshot | None = None


@dataclass(frozen=True)
class GroupSnapshot:
    """A consistent multi-table read: every member resolved at ONE
    catalog generation.

    ``generation`` is the catalog generation every member was resolved
    from; ``snapshots`` maps table name -> pinned :class:`TableSnapshot`.
    Like its members, a group snapshot never changes under the reader —
    later catalog publishes produce new groups.
    """
    generation: int
    snapshots: dict        # table name -> TableSnapshot

    def __getitem__(self, name: str) -> TableSnapshot:
        return self.snapshots[name]

    def __contains__(self, name: str) -> bool:
        return name in self.snapshots

    def __len__(self) -> int:
        return len(self.snapshots)

    def table_names(self) -> list:
        return sorted(self.snapshots)


@dataclass
class ScanResult:
    """Rows + the pruning census of one pushed-down scan.

    ``bytes_scanned`` counts body bytes actually FETCHED — with the CHK3
    column index a projected or late-materialized scan moves only the
    needed columns' ranges, and ``bytes_projected_away`` is what the
    index let it skip (candidate body bytes minus fetched bytes).
    ``files_pruned_late`` counts chunks whose phase-1 predicate columns
    proved no row matches (all-False mask), so their remaining columns
    were never fetched; such chunks were still touched, so they stay in
    ``files_scanned`` and the census invariant ``files_scanned +
    files_pruned_stats + files_pruned_meta == files_total`` is unchanged.
    """
    token: str
    rows: dict = field(default_factory=dict)   # column -> np.ndarray
    files_total: int = 0
    files_pruned_meta: int = 0     # refuted by metadata-layer stats
    files_pruned_stats: int = 0    # refuted by chunk footer stats
    files_pruned_late: int = 0     # all-False phase-1 mask: phase 2 skipped
    files_scanned: int = 0         # chunks whose data was touched
    bytes_scanned: int = 0         # body bytes actually fetched
    bytes_projected_away: int = 0  # candidate body bytes the index skipped
    bytes_skipped: int = 0         # body bytes stats pruning avoided


@dataclass
class ReadPlaneStats:
    """Thread-safe serving counters (the bench/test instrumentation)."""
    reads: int = 0             # read() calls answered
    not_modified: int = 0      # answered "your token is current"
    snapshot_hits: int = 0     # served straight from the snapshot LRU
    snapshot_builds: int = 0   # new snapshot materialized
    probes: int = 0            # head probes actually issued
    published: int = 0         # tokens handed over by a co-located daemon
    evictions: int = 0         # snapshots dropped by the LRU bound
    group_reads: int = 0       # catalog-pinned read_group() calls
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def bump(self, name: str, n: int = 1) -> None:
        with self._lock:
            setattr(self, name, getattr(self, name) + n)

    @property
    def hit_rate(self) -> float:
        """Fraction of reads that cost zero metadata work (not-modified
        answers + LRU snapshot hits)."""
        if not self.reads:
            return 0.0
        return (self.not_modified + self.snapshot_hits) / self.reads


@dataclass
class _TableEntry:
    """Per-(format, table) serving state: the freshest known token and
    when it goes stale."""
    lock: threading.Lock = field(default_factory=threading.Lock)
    token: str | None = None
    fresh_until: float = float("-inf")   # clock time the token expires


class SnapshotServer:
    """Conditional-GET snapshot serving over the shared metadata cache.

    One server instance fronts any number of tables in any format view;
    readers address tables by ``(base_path, fmt)``.  Construction is
    cheap — all state builds lazily on first read.  ``clock`` is any
    object with a ``now() -> float`` (the daemon's injected clocks fit);
    wall time by default.

    Thread-safety: reader calls may come from any thread.  Token
    freshness is guarded per table (so one probe per TTL window is a hard
    bound, not a fast path), snapshot materialization rides the metadata
    index's own single-flight lock, and the snapshot LRU has a server
    lock of its own.  Lock order is entry -> index -> server; no lock is
    held while storage is touched except the index's (which is exactly
    the single-flight contract).
    """

    def __init__(self, fs, *, options: ReadPlaneOptions | None = None,
                 cache: MetadataCache | None = None, clock=None):
        self.fs = fs
        self.options = options or ReadPlaneOptions()
        self.cache = cache or MetadataCache(fs)
        self._now = clock.now if clock is not None else time.monotonic
        self.stats = ReadPlaneStats()
        self._lock = threading.Lock()
        self._tables: dict[tuple[str, str], _TableEntry] = {}
        # (fmt, base_path, token) -> TableSnapshot; end = most recent
        self._snapshots: OrderedDict[tuple[str, str, str], TableSnapshot] = \
            OrderedDict()
        self.stats_cache = chunkfile.ChunkStatsCache(
            self.options.stats_cache_bytes)

    # ------------------------------------------------------------- serving
    def read(self, base_path: str, fmt: str, *,
             if_token: str | None = None) -> ReadResult:
        """Serve the table's current snapshot, conditional-GET style.

        A reader passing its last-seen token as ``if_token`` gets
        ``not_modified`` (no snapshot payload) when the table is
        unchanged — at zero storage requests within the probe window.
        Otherwise the freshest snapshot is served, from the LRU when
        memoized, else materialized once (single-flight) and memoized.
        """
        self.stats.bump("reads")
        token = self._current_token(base_path, fmt)
        if if_token is not None and if_token == token:
            self.stats.bump("not_modified")
            return ReadResult(NOT_MODIFIED, token)
        return ReadResult(OK, token, self._snapshot_for(base_path, fmt,
                                                        token))

    def scan(self, base_path: str, fmt: str,
             predicates: tuple[Predicate, ...] = (), *,
             columns: list[str] | None = None) -> ScanResult:
        """Snapshot-pinned scan with stats pushdown (see module doc).

        Row semantics match ``LakeTable.scan`` exactly — same file order
        (state insertion order), same metadata pruning, same row masks —
        the footer-stats layer only removes chunk-body reads the stats
        *prove* cannot contribute rows, so the result is byte-identical
        to an unpruned scan.
        """
        snap = self.read(base_path, fmt).snapshot
        return self.scan_snapshot(snap, predicates, columns=columns)

    def scan_snapshot(self, snap: TableSnapshot,
                      predicates: tuple[Predicate, ...] = (), *,
                      columns: list[str] | None = None) -> ScanResult:
        """``scan()`` against a snapshot the reader already holds (the
        pinned-view variant: immune to concurrent commits).

        ``columns`` projects the result, pushed below the round trip via
        the CHK3 column index; with predicates and
        ``readPlane.lateMaterialization`` on (default) the fetch is
        two-phase (see module doc).  A no-predicate, no-projection scan
        keeps the single pipelined full-body round.
        """
        predicates = tuple(predicates)
        res = ScanResult(token=snap.token)
        metas = list(snap.state.files.values())
        res.files_total = len(metas)
        candidates = [f for f in metas
                      if all(p.may_match_file(f) for p in predicates)]
        res.files_pruned_meta = len(metas) - len(candidates)
        project = bool(columns)
        late = bool(predicates) and self.options.late_materialization
        want_stats = any(p.column not in f.partition_values
                         for p in predicates for f in candidates)
        footers = None
        # ONE (cached, batched) footer fetch powers BOTH the stats
        # refutation and the column index the projected phases need
        if candidates and (want_stats or project or late):
            footers = self.stats_cache.get_many(
                self.fs, snap.base_path, [f.path for f in candidates])
            if want_stats:
                kept = []
                for f, ftr in zip(candidates, footers):
                    if any(chunkfile.stats_refute(ftr.stats, p.column,
                                                  p.op, p.value)
                           for p in predicates
                           if p.column not in f.partition_values):
                        res.files_pruned_stats += 1
                        res.bytes_skipped += f.size_bytes
                    else:
                        kept.append((f, ftr))
                candidates = [f for f, _ in kept]
                footers = [ftr for _, ftr in kept]
        res.files_scanned = len(candidates)
        full_bytes = sum(f.size_bytes for f in candidates)
        if not candidates:
            return res
        if not project and not late:
            # the pre-index path, unchanged: ONE pipelined full-body round
            res.bytes_scanned = full_bytes
            bodies = chunkfile.read_chunks(self.fs, snap.base_path,
                                           [f.path for f in candidates])
            batches = [self._finish(cols, predicates, columns)
                       for cols, _extra in bodies]
        elif late:
            batches = self._scan_late(snap.base_path, candidates, footers,
                                      predicates, columns, res)
        else:
            # projection without predicates (or knob off): one ranged
            # round over the needed columns of every candidate
            need = sorted({*columns, *(p.column for p in predicates)})
            fetched = chunkfile.read_chunks_columns(
                self.fs, snap.base_path, [f.path for f in candidates],
                need, footers=footers)
            batches = []
            for cols, nbytes in fetched:
                res.bytes_scanned += nbytes
                batches.append(self._finish(cols, predicates, columns))
        res.bytes_projected_away = full_bytes - res.bytes_scanned
        if batches:
            res.rows = {c: np.concatenate([b[c] for b in batches])
                        for c in batches[0]}
        return res

    @staticmethod
    def _finish(cols: dict, predicates, columns) -> dict:
        """Mask + project one file's columns.  The mask is sized from the
        data, not the metadata record_count — a stats-poor metadata layer
        may carry 0 there."""
        nrows = next(iter(cols.values())).shape[0] if cols else 0
        mask = np.ones(nrows, bool)
        for p in predicates:
            if p.column in cols:
                mask &= p.mask(cols[p.column])
        if columns:
            cols = {c: cols[c] for c in columns if c in cols}
        return {c: a[mask] if a.shape[:1] == mask.shape else a
                for c, a in cols.items()}

    def _scan_late(self, base_path: str, candidates, footers, predicates,
                   columns, res: ScanResult) -> list:
        """Two-phase late materialization over one scan's candidates.

        Phase 1 fetches ONLY the predicate columns of every candidate
        (one ranged batch round; CHK2 files fall back to full bodies in
        the same round) and evaluates the row masks.  A CHK3 chunk whose
        mask comes back all-False is dropped — the data refuted what its
        stats could not — contributing a zero-row batch synthesized from
        its footer schema (so concatenation dtypes match the full-body
        scan exactly) and never paying for its remaining columns.  Phase
        2 fetches the survivors' still-missing output columns in one
        more ranged batch round.
        """
        project = bool(columns)
        pred_cols = sorted({p.column for p in predicates})
        phase1 = chunkfile.read_chunks_columns(
            self.fs, base_path, [f.path for f in candidates], pred_cols,
            footers=footers)
        batches: list = [None] * len(candidates)
        work = []                          # (index, cols1, mask) for phase 2
        p2_paths, p2_footers = [], []
        for i, (f, ftr, (cols1, nbytes)) in enumerate(
                zip(candidates, footers, phase1)):
            res.bytes_scanned += nbytes
            nrows = (next(iter(cols1.values())).shape[0] if cols1
                     else ftr.nrows)
            mask = np.ones(nrows, bool)
            for p in predicates:
                if p.column in cols1:
                    mask &= p.mask(cols1[p.column])
            if not ftr.projectable:
                # CHK2: phase 1 was already the whole body — finish now
                cols = cols1
                if project:
                    cols = {c: cols[c] for c in columns if c in cols}
                batches[i] = {c: a[mask] if a.shape[:1] == mask.shape else a
                              for c, a in cols.items()}
                continue
            out_names = ([c for c in columns if c in ftr.schema] if project
                         else [n for n, _o, _l in ftr.columns])
            if not mask.any() and all(
                    tuple(ftr.schema[c]["shape"][:1]) == (nrows,)
                    for c in out_names):
                res.files_pruned_late += 1
                batches[i] = {c: chunkfile.empty_column(ftr.schema[c])
                              for c in out_names}
                continue
            work.append((i, cols1, mask))
            p2_paths.append(f.path)
            p2_footers.append(ftr)
        if work:
            fetched2 = chunkfile.read_chunks_columns(
                self.fs, base_path, p2_paths,
                columns if project else None,
                footers=p2_footers, exclude=set(pred_cols))
            for (i, cols1, mask), (cols2, nbytes) in zip(work, fetched2):
                res.bytes_scanned += nbytes
                merged = {**cols1, **cols2}
                if project:
                    cols = {c: merged[c] for c in columns if c in merged}
                else:
                    # restore the file's schema order (phase-1 predicate
                    # columns came first in `merged`)
                    cols = {n: merged[n] for n, _o, _l in footers[i].columns
                            if n in merged}
                batches[i] = {c: a[mask] if a.shape[:1] == mask.shape else a
                              for c, a in cols.items()}
        return batches

    # ------------------------------------------------- catalog-pinned reads
    def read_at(self, base_path: str, fmt: str, token: str,
                commit: str) -> TableSnapshot:
        """Serve the snapshot pinned at a published ``(token, commit)``.

        The catalog-pinned building block: ``token`` keys the same LRU
        the conditional-GET path fills (a co-located daemon's eager
        post-drain publish makes this a pure memo hit), and ``commit``
        pins the exact published state through the index's ``state_at``
        — correct even when the table has moved past the pointer, which
        a head-chasing ``refresh_to`` would not be.  Beyond the index's
        one-time build, a pinned read costs ZERO storage requests while
        memoized and at most one tail refresh when not.
        """
        self.stats.bump("reads")
        key = (fmt, base_path, token)
        with self._lock:
            snap = self._snapshots.get(key)
            if snap is not None:
                self._snapshots.move_to_end(key)
                self.stats.bump("snapshot_hits")
                return snap
        index = self.cache.index(fmt, base_path)
        state = index.state_at(commit)
        snap = TableSnapshot(base_path=base_path, view_format=fmt,
                             token=token, head_commit=commit, state=state,
                             created_at=self._now())
        with self._lock:
            if key in self._snapshots:
                self._snapshots.move_to_end(key)
                return self._snapshots[key]
            self._snapshots[key] = snap
            self.stats.bump("snapshot_builds")
            while len(self._snapshots) > self.options.max_snapshots:
                self._snapshots.popitem(last=False)
                self.stats.bump("evictions")
        return snap

    def read_group(self, catalog, tables=None, *, group: str | None = None,
                   fmt: str | None = None) -> GroupSnapshot:
        """Consistent multi-table read through a catalog (see module doc).

        Resolves ONE catalog generation up front (one LIST, plus one GET
        only when the generation moved) and serves every requested table
        pinned at that generation's published ``(token, commit)`` — the
        members can never mix publish generations, however many group
        commits land while the reader iterates.

        ``group`` selects a published dataset group, ``tables`` an
        explicit name list; neither means every registered table.
        ``fmt`` picks a specific format view (default: each table's
        source view); a table without that published view raises
        ``KeyError`` rather than silently serving a differently pinned
        one.
        """
        cat = catalog.snapshot()
        if group is not None:
            names = cat.group(group)
        elif tables is not None:
            names = tuple(tables)
        else:
            names = tuple(cat.table_names())
        snaps = {}
        for name in names:
            ptr = cat.resolve(name)
            ref = ptr.view(fmt)
            snaps[name] = self.read_at(ptr.base_path,
                                       fmt or ptr.source_format,
                                       ref.token, ref.commit)
        self.stats.bump("group_reads")
        return GroupSnapshot(generation=cat.generation, snapshots=snaps)

    # ---------------------------------------------------- daemon co-location
    def publish(self, base_path: str, fmt: str, token: str) -> None:
        """Co-located daemon hook: install a just-synced head token.

        Called post-drain with the cycle's probed token, while the index
        still carries that cycle's head hint — so the eager snapshot
        build below costs zero storage requests (the daemon's replay
        already indexed the head), and every reader inside the next TTL
        window is served without even the probe.
        """
        entry = self._entry(base_path, fmt)
        with entry.lock:
            entry.token = token
            entry.fresh_until = self._now() + self.options.ttl_ms / 1000.0
        self.stats.bump("published")
        try:
            self._snapshot_for(base_path, fmt, token)
        except Exception:
            # eager materialization is an optimization; the first reader
            # retries it with real error propagation
            pass

    # ------------------------------------------------------------ internals
    def _entry(self, base_path: str, fmt: str) -> _TableEntry:
        with self._lock:
            return self._tables.setdefault((fmt, base_path), _TableEntry())

    def _current_token(self, base_path: str, fmt: str) -> str:
        """The freshest head token, probing at most once per TTL window.

        The entry lock is held across the probe on purpose: concurrent
        readers of a stale window serialize here and all but the first
        find the refreshed deadline — "<= 1 probe per window per table"
        is a guarantee, not an expectation.
        """
        entry = self._entry(base_path, fmt)
        with entry.lock:
            now = self._now()
            if entry.token is not None and now < entry.fresh_until:
                return entry.token
            index = self.cache.index(fmt, base_path)
            entry.token = index.probe()
            entry.fresh_until = now + self.options.ttl_ms / 1000.0
            self.stats.bump("probes")
            return entry.token

    def _snapshot_for(self, base_path: str, fmt: str,
                      token: str) -> TableSnapshot:
        key = (fmt, base_path, token)
        with self._lock:
            snap = self._snapshots.get(key)
            if snap is not None:
                self._snapshots.move_to_end(key)
                self.stats.bump("snapshot_hits")
                return snap
        index = self.cache.index(fmt, base_path)
        # single-flight: racing builders serialize on the index lock and
        # at most one pays the (tail-only) replay
        index.refresh_to(token)
        head, state = index.pinned_state()
        snap = TableSnapshot(base_path=base_path, view_format=fmt,
                             token=token, head_commit=head, state=state,
                             created_at=self._now())
        with self._lock:
            if key in self._snapshots:
                # a racing builder won; serve its (identical) snapshot
                self._snapshots.move_to_end(key)
                return self._snapshots[key]
            self._snapshots[key] = snap
            self.stats.bump("snapshot_builds")
            while len(self._snapshots) > self.options.max_snapshots:
                self._snapshots.popitem(last=False)
                self.stats.bump("evictions")
        return snap

    def snapshot_count(self) -> int:
        with self._lock:
            return len(self._snapshots)
