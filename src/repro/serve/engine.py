"""Batched serving engine restoring weights through an XTable-translated view.

Scenario 3 transplanted: the trainer commits checkpoints in one format's
metadata; the *server* opens the same directory through ANY translated
view (e.g. Iceberg, whose snapshot+manifest metadata with file statistics
is the right shape for a serving fleet's scan planning).  No weight files
are copied.  :meth:`ServeEngine.from_lake` can restore three ways: from a
raw base path, through the read plane's pinned snapshots
(``read_plane=``), or by catalog NAME (``catalog=`` + ``table=``) — the
latter pins the restore at the catalog's published (token, commit), not
whatever head a concurrent sync may have half-landed.

The engine itself: synchronous batched decode with greedy/temperature
sampling over prefill + step functions built from the model zoo.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import LSTCheckpointManager
from repro.models.model import Model
from repro.models.param import template_shapes


@dataclass
class Request:
    prompt: list            # token ids
    max_new: int = 16


class ServeEngine:
    def __init__(self, model: Model, params, *, cache_len: int = 256):
        self.model = model
        self.params = params
        self.cache_len = cache_len
        self._prefill = jax.jit(
            lambda p, t, e=None: model.prefill(
                p, t, cache_len=cache_len,
                **({"enc_embeds": e} if model.cfg.encoder else {})))
        self._step = jax.jit(model.decode_step)

    @classmethod
    def from_lake(cls, model: Model, fs, ckpt_path: str | None = None, *,
                  fmt: str = "iceberg", cache_len: int = 256,
                  read_plane=None, catalog=None,
                  table: str | None = None) -> "ServeEngine":
        """Restore weights through the translated ``fmt`` view.

        With a ``read_plane`` (:class:`~repro.serve.read_plane
        .SnapshotServer`) the checkpoint table resolves through a
        memoized head-keyed snapshot instead of a private metadata
        replay — a fleet of servers restoring the same checkpoint shares
        ONE replay (single-flight) and each later restore's metadata
        cost is a cache hit.

        With a ``catalog`` (:class:`~repro.lst.catalog.Catalog`) the
        table is addressed by registered ``table`` *name* instead of a
        storage path: the catalog pointer supplies the base path and the
        published ``(token, commit)`` pin for the requested view, so the
        restore observes exactly the atomically published head — not
        whatever a concurrent sync has half-landed since.  (The pin
        itself rides the read plane; a catalog without a ``read_plane``
        still resolves the path by name but restores the live head.)
        """
        table_state = None
        if catalog is not None:
            if table is None:
                raise ValueError("catalog-based restore needs table=<name>")
            ptr = catalog.resolve(table)
            ckpt_path = ptr.base_path
            ref = ptr.view(fmt)
            if read_plane is not None:
                table_state = read_plane.read_at(ckpt_path, fmt,
                                                 ref.token, ref.commit).state
        elif ckpt_path is None:
            raise ValueError("need ckpt_path (or catalog= + table=)")
        elif read_plane is not None:
            table_state = read_plane.read(ckpt_path, fmt).snapshot.state
        mgr = LSTCheckpointManager(fs, ckpt_path, fmt=fmt, sync_targets=())
        shapes = template_shapes(model.param_template())
        _, state = mgr.restore_pytree({"params": shapes}, fmt=fmt,
                                      state=table_state)
        return cls(model, jax.tree.map(jnp.asarray, state["params"]),
                   cache_len=cache_len)

    def generate(self, requests: list, *, temperature: float = 0.0,
                 seed: int = 0) -> list:
        """Synchronous batched generation (greedy when temperature == 0)."""
        b = len(requests)
        max_prompt = max(len(r.prompt) for r in requests)
        max_new = max(r.max_new for r in requests)
        pad = self.model.cfg.vocab_size - 1
        toks = np.full((b, max_prompt), pad, np.int32)
        for i, r in enumerate(requests):
            toks[i, -len(r.prompt):] = r.prompt      # left-pad
        enc = None
        if self.model.cfg.encoder:
            enc = jnp.zeros((b, self.model.cfg.encoder.n_frames,
                             self.model.cfg.d_model), self.model.cfg.dtype)
        args = (self.params, jnp.asarray(toks)) + \
            ((enc,) if enc is not None else ())
        logits, cache = self._prefill(*args)
        key = jax.random.PRNGKey(seed)
        outs = [[] for _ in range(b)]
        pos = jnp.full((b,), max_prompt, jnp.int32)
        tok = self._sample(logits, temperature, key)
        for step in range(max_new):
            for i in range(b):
                if step < requests[i].max_new:
                    outs[i].append(int(tok[i]))
            if step + 1 >= max_new:
                # every request has its tokens; the trailing decode step
                # would be sampled and thrown away
                break
            key, sub = jax.random.split(key)
            logits, cache = self._step(self.params, cache, tok, pos)
            tok = self._sample(logits, temperature, sub)
            pos = pos + 1
        return outs

    @staticmethod
    def _sample(logits, temperature: float, key):
        if temperature == 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, -1) \
            .astype(jnp.int32)
