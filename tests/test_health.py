"""Per-table circuit breakers: state machine + daemon integration.

The breaker sits ON TOP of exponential backoff: backoff spaces retries out,
the breaker *gives up* — ``closed -> open`` after ``failureThreshold``
consecutive failures, one ``half_open`` trial per elapsed cooldown,
``quarantined`` after ``quarantineAfter`` consecutive opens.  Everything
here runs on a manual clock, so every window is crossed by advancing time,
never by sleeping through it.

The daemon half pins the contracts that matter operationally: an open
breaker spends ZERO storage requests on the sick table while healthy
neighbors keep syncing, a recovered table walks back to ``closed`` through
a half-open trial, a quarantined backlog cannot hold ``stop(drain=True)``
hostage, and breaker states ride the durable checkpoint across restarts.
"""

import numpy as np
import pytest

from repro.core import ManualClock, SyncConfig, SyncDaemon
from repro.core.health import (ALLOW, CLOSED, COOLING, HALF_OPEN, OPEN,
                               PARKED, HealthTracker)
from repro.core.config import HealthOptions
from repro.lst import LakeTable
from repro.lst.schema import Field, PartitionSpec, Schema
from repro.lst.storage import MemoryFS, TransientStorageError, layer_fs

SCHEMA = Schema([Field("k", "int64"), Field("part", "string")])


def _mk_table(fs, base, fmt="delta", n_commits=3):
    t = LakeTable.create(fs, base, SCHEMA, fmt, PartitionSpec(["part"]),
                         {"delta.checkpointInterval": "100000"})
    for i in range(n_commits):
        t.append({"k": np.array([i, i + 100], np.int64),
                  "part": np.array([f"p{i % 2}", "p0"])})
    return t


def _append(t, k=1):
    for i in range(k):
        t.append({"k": np.array([7 + i], np.int64),
                  "part": np.array(["p0"])})


def _cfg(bases, **kw):
    d = {"sourceFormat": "DELTA", "targetFormats": ["ICEBERG"],
         "datasets": [{"tableBasePath": b} for b in bases],
         "backoff": {"baseDelayMs": 1.0, "maxDelayMs": 2.0, "jitter": 0.0}}
    d.update(kw)
    return SyncConfig.from_dict(d)


def _opts(**kw):
    base = dict(failure_threshold=2, open_cooldown_ms=10_000.0,
                half_open_probes=1, quarantine_after=3,
                quarantine_cooldown_ms=100_000.0)
    base.update(kw)
    return HealthOptions(**base)


class _SickPrefixFS:
    """Delegating FS that fails requests under ``prefix`` while ``sick``.

    ``writes_only=True`` scopes the failure to puts (the probe still sees
    the table; the drain dies), which is how a table gets a *pending*
    backlog and a tripped breaker at the same time.
    """

    def __init__(self, inner, prefix, *, writes_only=False):
        self.inner = inner
        self.prefix = prefix
        self.writes_only = writes_only
        self.sick = True
        self.attempts = 0           # requests that reached the sick prefix

    def _check(self, path, *, write):
        if path.startswith(self.prefix):
            self.attempts += 1
            if self.sick and (write or not self.writes_only):
                raise TransientStorageError(f"503 injected ({path})")

    def read_bytes(self, path):
        self._check(path, write=False)
        return self.inner.read_bytes(path)

    def read_bytes_range(self, path, offset, length):
        self._check(path, write=False)
        return self.inner.read_bytes_range(path, offset, length)

    def read_many(self, paths):
        return [self.read_bytes(p) for p in paths]

    def read_many_ranges(self, requests):
        return [self.read_bytes_range(p, o, n) for p, o, n in requests]

    def write_bytes(self, path, data, *, overwrite=False):
        self._check(path, write=True)
        self.inner.write_bytes(path, data, overwrite=overwrite)

    def write_many(self, items, *, overwrite=False):
        for p, data in items:
            self.write_bytes(p, data, overwrite=overwrite)

    def exists(self, path):
        self._check(path, write=False)
        return self.inner.exists(path)

    def list_dir(self, path):
        self._check(path, write=False)
        return self.inner.list_dir(path)

    def size(self, path):
        self._check(path, write=False)
        return self.inner.size(path)

    def delete(self, path):
        self._check(path, write=True)
        self.inner.delete(path)


# ---------------------------------------------------------- state machine
def test_breaker_opens_after_consecutive_failures():
    h = HealthTracker(_opts())
    assert h.admit("t", 0.0) == ALLOW
    h.record_failure("t", 0.0)
    assert h.state("t") == CLOSED           # 1 < threshold
    h.record_failure("t", 1.0)
    assert h.state("t") == OPEN
    assert h.admit("t", 2.0) == COOLING     # cooldown (10s) still running
    assert h.admit("t", 11.5) == ALLOW      # elapsed: half-open trial
    assert h.state("t") == HALF_OPEN


def test_success_resets_the_consecutive_counter():
    h = HealthTracker(_opts())
    for t in range(10):                     # fail, heal, fail, heal ...
        h.record_failure("t", float(t))
        h.record_success("t")
    assert h.state("t") == CLOSED


def test_half_open_success_closes_failure_reopens():
    h = HealthTracker(_opts())
    h.record_failure("t", 0.0)
    h.record_failure("t", 0.0)              # -> open
    assert h.admit("t", 11.0) == ALLOW      # trial 1
    h.record_failure("t", 11.0)             # ONE failure in half_open trips
    assert h.state("t") == OPEN
    assert h.admit("t", 22.0) == ALLOW      # trial 2
    h.record_success("t")
    assert h.state("t") == CLOSED
    # a full close resets the opens streak: the quarantine counter restarts
    assert h.admit("t", 23.0) == ALLOW


def test_quarantine_after_consecutive_opens_then_parole():
    h = HealthTracker(_opts(quarantine_after=2, open_cooldown_ms=1000.0,
                            quarantine_cooldown_ms=50_000.0))
    now = 0.0
    h.record_failure("t", now)
    h.record_failure("t", now)              # open #1
    now += 2.0
    assert h.admit("t", now) == ALLOW       # half-open trial
    h.record_failure("t", now)              # open #2 -> quarantined
    assert h.is_quarantined("t")
    assert h.admit("t", now + 10.0) == PARKED    # 50s cooldown: parked
    now += 51.0
    assert h.admit("t", now) == ALLOW       # parole trial
    h.record_success("t")
    assert h.state("t") == CLOSED


def test_states_reports_only_interesting_tables():
    h = HealthTracker(_opts())
    h.admit("quiet", 0.0)                   # seen but never failed
    h.record_failure("sick", 0.0)
    h.record_failure("sick", 0.0)
    assert h.states() == {"sick": OPEN}


def test_snapshot_restore_round_trip_live_wins():
    h = HealthTracker(_opts())
    h.record_failure("a", 0.0)
    h.record_failure("a", 0.0)
    snap = h.snapshot()

    h2 = HealthTracker(_opts())
    h2.record_success("a")                  # live observation before restore
    h2.restore(snap)
    assert h2.state("a") == CLOSED          # live wins over the checkpoint

    h3 = HealthTracker(_opts())
    h3.restore(snap)
    assert h3.state("a") == OPEN
    assert h3.snapshot()["a"] == snap["a"]


# ----------------------------------------------------------------- daemon
def test_open_breaker_spends_zero_requests_and_spares_neighbors():
    raw = MemoryFS()
    good = _mk_table(raw, "bkt/good", n_commits=2)
    _mk_table(raw, "bkt/bad", n_commits=2)
    sick = _SickPrefixFS(raw, "bkt/bad")
    clock = ManualClock()
    cfg = _cfg(["bkt/good", "bkt/bad"],
               health={"failureThreshold": 2, "openCooldownMs": 1e9})
    d = SyncDaemon(cfg, layer_fs(sick), clock=clock)

    rep = d.run_cycle()                     # bad probe fails (1/2)
    assert rep.table_errors == 1 and rep.units_drained == 1
    clock.advance(1.0)                      # past backoff, cooldown forever
    rep = d.run_cycle()                     # bad probe fails (2/2) -> OPEN
    assert rep.table_errors == 1 and d.health.state("bkt/bad") == OPEN

    frozen = sick.attempts
    _append(good, 2)
    for _ in range(3):
        clock.advance(1.0)
        rep = d.run_cycle()
        assert rep.breaker_open == 1        # skipped, not even probed
        assert rep.table_errors == 0
        assert rep.health == {"bkt/bad": OPEN}
        assert not rep.idle                 # an open breaker is not "done"
    assert sick.attempts == frozen          # ZERO requests while open
    got = LakeTable.open(raw, "bkt/good", "iceberg").read_all()
    assert sorted(got["k"].tolist()) == sorted(good.read_all()["k"].tolist())


def test_breaker_recovers_through_half_open_trial():
    raw = MemoryFS()
    _mk_table(raw, "bkt/t", n_commits=2)
    sick = _SickPrefixFS(raw, "bkt/t")
    clock = ManualClock()
    cfg = _cfg(["bkt/t"], health={"failureThreshold": 1,
                                  "openCooldownMs": 5000.0,
                                  "quarantineAfter": 100})
    d = SyncDaemon(cfg, layer_fs(sick), clock=clock)
    d.run_cycle()                           # fails -> open immediately
    assert d.health.state("bkt/t") == OPEN

    clock.advance(1.0)
    assert d.run_cycle().breaker_open == 1  # still cooling

    sick.sick = False                       # the table heals
    clock.advance(6.0)                      # cooldown elapsed
    rep = d.run_cycle()                     # half-open trial: full sync
    assert rep.units_drained == 1 and rep.breaker_open == 0
    assert d.health.state("bkt/t") == CLOSED


def test_quarantined_backlog_does_not_hold_drain_stop_hostage():
    raw = MemoryFS()
    good = _mk_table(raw, "bkt/good", n_commits=2)
    _mk_table(raw, "bkt/bad", n_commits=2)
    # probe sees bkt/bad fine; every write dies -> pending backlog + trips
    sick = _SickPrefixFS(raw, "bkt/bad", writes_only=True)
    clock = ManualClock()
    cfg = _cfg(["bkt/good", "bkt/bad"],
               health={"failureThreshold": 1, "quarantineAfter": 1,
                       "quarantineCooldownMs": 1e12})
    d = SyncDaemon(cfg, layer_fs(sick), clock=clock)
    rep = d.run_cycle()
    assert rep.units_errored == 1 and d.health.is_quarantined("bkt/bad")
    assert d.lag()["bkt/bad"] is True       # the backlog is real ...
    assert not d._pending()                 # ... but quarantine waives it

    clock.advance(1.0)
    assert d.run_cycle().quarantined == 1   # parked, not probed

    d.stop(drain=True)                      # must NOT spin on bkt/bad
    reports = d.run()
    assert len(reports) <= 2
    got = LakeTable.open(raw, "bkt/good", "iceberg").read_all()
    assert sorted(got["k"].tolist()) == sorted(good.read_all()["k"].tolist())


def test_breaker_state_rides_the_checkpoint_across_restarts():
    raw = MemoryFS()
    _mk_table(raw, "bkt/good", n_commits=2)
    _mk_table(raw, "bkt/bad", n_commits=2)
    sick = _SickPrefixFS(raw, "bkt/bad", writes_only=True)
    clock = ManualClock()
    cfg = _cfg(["bkt/good", "bkt/bad"],
               health={"failureThreshold": 1, "quarantineAfter": 1,
                       "quarantineCooldownMs": 1e12},
               checkpoint={"enabled": True})
    d1 = SyncDaemon(cfg, layer_fs(sick), clock=clock)
    rep = d1.run_cycle()
    assert d1.health.is_quarantined("bkt/bad") and rep.checkpoint_gen == 1

    # restart: the quarantine survives — the fleet does NOT hammer a table
    # it had already given up on before the crash
    d2 = SyncDaemon(cfg, layer_fs(sick), clock=ManualClock())
    assert d2.restored_from_checkpoint
    assert d2.health.is_quarantined("bkt/bad")
    frozen = sick.attempts
    rep = d2.run_cycle()
    assert rep.quarantined == 1 and sick.attempts == frozen


def test_health_disabled_keeps_probing_forever():
    raw = MemoryFS()
    _mk_table(raw, "bkt/bad", n_commits=2)
    sick = _SickPrefixFS(raw, "bkt/bad")
    clock = ManualClock()
    cfg = _cfg(["bkt/bad"], health={"enabled": False,
                                    "failureThreshold": 1})
    d = SyncDaemon(cfg, layer_fs(sick), clock=clock)
    assert d.health is None
    for _ in range(4):
        rep = d.run_cycle()
        clock.advance(60.0)
    assert rep.breaker_open == 0 and rep.table_errors == 1   # still trying


def test_health_options_parse_and_validate():
    cfg = _cfg(["bkt/t"], health={
        "failureThreshold": 7, "openCooldownMs": 1234.0,
        "halfOpenProbes": 2, "quarantineAfter": 9,
        "quarantineCooldownMs": 7e6})
    h = cfg.health
    assert h.enabled and h.failure_threshold == 7
    assert h.open_cooldown_ms == 1234.0 and h.half_open_probes == 2
    assert h.quarantine_after == 9 and h.quarantine_cooldown_ms == 7e6
    assert _cfg(["bkt/t"]).health.enabled       # breaker is on by default
    with pytest.raises(ValueError):
        _cfg(["bkt/t"], health={"failureThreshold": 0})
