"""Columnar projection pushdown (ISSUE 10): the CHK3 column-offset index,
ranged per-column reads, and late-materialized scans.

The counting-FS pins here are the read-side byte contract: a scan
projecting k of N columns over a CHK3 table fetches O(k/N) of the
full-scan bytes in at most 2 pipelined ranged-read rounds beyond the
footer round, a no-projection no-predicate scan keeps the single
full-body round, and every projected / late-materialized result is
byte-identical (values AND dtypes) to the full-body scan.  CHK2 files
keep reading through transparent full-body fallback, including mixed
CHK2/CHK3 tables.
"""

import threading

import numpy as np
import pytest

from repro.core import ManualClock, MetadataCache, ReadPlaneOptions
from repro.lst import chunkfile
from repro.lst.schema import Field, Schema
from repro.lst.storage import MemoryFS
from repro.lst.table import LakeTable, Predicate
from repro.serve.read_plane import SnapshotServer


class RoundCountingFS(MemoryFS):
    """Counts batch ROUNDS (not per-object requests) and chunk bytes moved,
    split by full-body vs ranged — the currency of the projection pins."""

    def __init__(self):
        super().__init__()
        self._tl = threading.local()
        self.reset()

    def reset(self):
        self.full_rounds = 0       # batch/singular full-body chunk fetches
        self.ranged_rounds = 0     # read_many_ranges batches touching chunks
        self.ranged_requests = 0   # individual ranges inside those rounds
        self.full_bytes = 0
        self.ranged_bytes = 0
        self.size_calls = 0

    def read_bytes(self, path):
        data = super().read_bytes(path)
        if path.endswith(".chunk") and not getattr(self._tl, "inner", False):
            self.full_rounds += 1
            self.full_bytes += len(data)
        return data

    def read_many(self, paths):
        self._tl.inner = True
        try:
            out = super().read_many(paths)
        finally:
            self._tl.inner = False
        chunk_blobs = [b for p, b in zip(paths, out) if p.endswith(".chunk")]
        if chunk_blobs:
            self.full_rounds += 1
            self.full_bytes += sum(len(b) for b in chunk_blobs)
        return out

    def read_bytes_range(self, path, offset, length):
        self._tl.inner = True
        try:
            return super().read_bytes_range(path, offset, length)
        finally:
            self._tl.inner = False

    def read_many_ranges(self, requests):
        self._tl.inner = True
        try:
            out = super().read_many_ranges(requests)
        finally:
            self._tl.inner = False
        hits = [b for (p, _o, _l), b in zip(requests, out)
                if p.endswith(".chunk")]
        if hits:
            self.ranged_rounds += 1
            self.ranged_requests += len(hits)
            self.ranged_bytes += sum(len(b) for b in hits)
        return out

    def size(self, path):
        self.size_calls += 1
        return super().size(path)


def _server(fs, **opts):
    return SnapshotServer(fs, options=ReadPlaneOptions(**opts),
                          cache=MetadataCache(fs), clock=ManualClock())


NCOLS = 16


def _wide_table(fs, base, n_chunks=4, rows=64, seed=0):
    """16 equal-width int64/float64 columns -> each column is 1/16 of the
    body bytes, so byte ratios are easy to pin."""
    schema = Schema([Field(f"c{i:02d}", "int64" if i % 2 else "float64")
                     for i in range(NCOLS)])
    t = LakeTable.create(fs, base, schema, "delta")
    rng = np.random.default_rng(seed)
    for c in range(n_chunks):
        t.append({f"c{i:02d}": (np.arange(c * rows, (c + 1) * rows) * (i + 1)
                                if i % 2 else rng.normal(size=rows))
                  for i in range(NCOLS)})
    return t


# ------------------------------------------------------------- CHK3 format
def test_chk3_roundtrip_index_addresses_every_column():
    fs = MemoryFS()
    rng = np.random.default_rng(0)
    cols = {"a": np.arange(7), "b": rng.normal(size=7),
            "s": np.array(["x", "yy", "zzz", "w", "v", "u", "t"]),
            "u": np.array(["é", "λ", "ü", "π", "ß", "ø", "å"])}
    chunkfile.write_chunk(fs, "bkt/t", "data/f.chunk", cols,
                          extra={"tag": 42})
    raw = fs.read_bytes("bkt/t/data/f.chunk")
    assert raw[:4] == b"CHK3" and raw[-4:] == b"CHK3"

    back, extra = chunkfile.read_chunk(fs, "bkt/t", "data/f.chunk")
    assert extra == {"tag": 42} and list(back) == list(cols)
    for c in cols:
        np.testing.assert_array_equal(back[c], np.asarray(cols[c]))

    ftr = chunkfile.read_chunks_footers(fs, "bkt/t", ["data/f.chunk"])[0]
    assert ftr.projectable
    assert [n for n, _o, _l in ftr.columns] == list(cols)
    # the index's byte ranges decode each column standalone
    for name, off, ln in ftr.columns:
        np.testing.assert_array_equal(
            chunkfile._decode_array(ftr.schema[name], raw[off:off + ln]),
            np.asarray(cols[name]))
    # footer still unpacks like the old (nrows, stats) tuple
    nrows, stats = ftr
    assert nrows == 7 and stats["a"].min == 0 and stats["a"].max == 6


def test_read_chunks_columns_moves_only_requested_bytes_in_one_round():
    fs = RoundCountingFS()
    _wide_table(fs, "bkt/w", n_chunks=3, rows=128)
    t = LakeTable.open(fs, "bkt/w", "delta")
    paths = [f.path for f in t.state().files.values()]
    footers = chunkfile.read_chunks_footers(fs, "bkt/w", paths)
    full = sum(fs.size(f"bkt/w/{p}") for p in paths)

    fs.reset()
    out = chunkfile.read_chunks_columns(fs, "bkt/w", paths,
                                        ["c03", "c04"], footers=footers)
    assert fs.ranged_rounds == 1 and fs.full_rounds == 0
    # c03/c04 are adjacent blobs -> coalesced to ONE range per file
    assert fs.ranged_requests == len(paths)
    fetched = sum(n for _cols, n in out)
    assert fetched == fs.ranged_bytes
    assert fetched * (NCOLS // 2 - 1) < full          # ~2/16 of the body
    for (cols, _n), p in zip(out, paths):
        assert list(cols) == ["c03", "c04"]
        ref, _ = chunkfile.read_chunk(fs, "bkt/w", p)
        for c in cols:
            np.testing.assert_array_equal(cols[c], ref[c])

    # columns=None still rides the index: all 16 blobs coalesce into one
    # range per file that skips the header/footer bytes
    fs.reset()
    out = chunkfile.read_chunks_columns(fs, "bkt/w", paths, None,
                                        footers=footers)
    assert fs.ranged_rounds == 1 and fs.ranged_requests == len(paths)
    assert all(list(cols) == [f"c{i:02d}" for i in range(NCOLS)]
               for cols, _n in out)


def test_singular_read_chunk_stats_two_ranged_reads_no_size_call():
    fs = RoundCountingFS()
    chunkfile.write_chunk(fs, "bkt/t", "d/x.chunk",
                          {"k": np.arange(9), "v": np.ones(9)})
    fs.reset()
    nrows, stats = chunkfile.read_chunk_stats(fs, "bkt/t", "d/x.chunk")
    assert nrows == 9 and stats["k"].max == 8
    assert fs.size_calls == 0                 # the suffix-read trick
    assert fs.ranged_rounds == 2 and fs.full_rounds == 0


# ------------------------------------------------------- scan round/byte pins
def test_scan_round_and_byte_pins_full_vs_projected():
    fs = RoundCountingFS()
    _wide_table(fs, "bkt/w", n_chunks=4, rows=64)
    server = _server(fs)
    snap = server.read("bkt/w", "delta").snapshot

    fs.reset()
    full = server.scan_snapshot(snap)
    # no projection, no predicate: today's single full-body round
    assert fs.full_rounds == 1 and fs.ranged_rounds == 0
    assert full.bytes_scanned == fs.full_bytes
    assert full.bytes_projected_away == 0

    # cold projected scan: footer fetch (2 ranged rounds) + 1 column round
    fs.reset()
    proj = server.scan_snapshot(snap, columns=["c02", "c03"])
    assert fs.full_rounds == 0 and fs.ranged_rounds == 3
    assert full.bytes_scanned >= 3 * proj.bytes_scanned   # >= 3x reduction
    assert proj.bytes_projected_away == \
        full.bytes_scanned - proj.bytes_scanned
    for c in ("c02", "c03"):
        np.testing.assert_array_equal(proj.rows[c], full.rows[c])
    assert list(proj.rows) == ["c02", "c03"]

    # warm footer cache: ONE ranged round total
    fs.reset()
    again = server.scan_snapshot(snap, columns=["c02", "c03"])
    assert fs.full_rounds == 0 and fs.ranged_rounds == 1
    assert again.bytes_scanned == proj.bytes_scanned

    # predicated + projected, warm: phase 1 + phase 2 = 2 ranged rounds
    pred = (Predicate("c01", "<", int(64 * 2 * 0.5)),)
    fs.reset()
    res = server.scan_snapshot(snap, pred, columns=["c02"])
    assert fs.full_rounds == 0 and fs.ranged_rounds <= 2
    mask = pred[0].mask(full.rows["c01"])
    np.testing.assert_array_equal(res.rows["c02"], full.rows["c02"][mask])
    assert res.bytes_scanned + res.bytes_projected_away + res.bytes_skipped \
        == sum(f.size_bytes for f in snap.files.values()
               if all(p.may_match_file(f) for p in pred))


def test_late_materialization_skips_data_refuted_chunks():
    fs = RoundCountingFS()
    schema = Schema([Field("k", "int64"), Field("v", "float64"),
                     Field("s", "string")])
    t = LakeTable.create(fs, "bkt/t", schema, "delta")
    # chunk A: even k only; chunk B: odd k only.  Stats (min/max) cannot
    # refute "k == 51" for either; A's DATA can.  Both v columns straddle
    # 2.0 without containing it — "v == 2.0" is data-refutable everywhere.
    t.append({"k": np.arange(0, 100, 2),
              "v": np.where(np.arange(50) % 2, 3.0, 1.0),
              "s": np.array([f"a{i}" for i in range(50)])})
    t.append({"k": np.arange(1, 101, 2),
              "v": np.where(np.arange(50) % 2, 4.0, 0.0),
              "s": np.array([f"b{i}" for i in range(50)])})
    pred = (Predicate("k", "==", 51),)

    on = _server(fs)
    off = _server(fs, late_materialization=False)
    assert not off.options.late_materialization
    snap = on.read("bkt/t", "delta").snapshot
    ref = off.scan_snapshot(off.read("bkt/t", "delta").snapshot, pred)
    res = on.scan_snapshot(snap, pred)

    assert res.files_pruned_late == 1        # A dropped by its own data
    assert res.files_scanned == 2            # census unchanged: A was touched
    assert res.bytes_scanned < ref.bytes_scanned
    assert list(res.rows) == list(ref.rows)
    for c in ref.rows:
        assert res.rows[c].dtype == ref.rows[c].dtype
        np.testing.assert_array_equal(res.rows[c], ref.rows[c])

    # all-False everywhere: structure/dtypes still match the full scan
    none = on.scan_snapshot(snap, (Predicate("v", "==", 2.0),))
    ref_none = off.scan_snapshot(snap, (Predicate("v", "==", 2.0),))
    assert none.files_pruned_late == 2
    assert {c: a.dtype for c, a in none.rows.items()} == \
        {c: a.dtype for c, a in ref_none.rows.items()}
    assert all(a.shape[0] == 0 for a in none.rows.values())


# ---------------------------------------------------------- CHK2 back-compat
def _mixed_table(fs, base):
    """One CHK2 file + one CHK3 file in the same committed table."""
    schema = Schema([Field("k", "int64"), Field("v", "float64"),
                     Field("s", "string")])
    t = LakeTable.create(fs, base, schema, "delta")
    m2 = chunkfile.write_chunk(fs, base, "data/old.chunk",
                               {"k": np.arange(10), "v": np.ones(10),
                                "s": np.array([f"o{i}" for i in range(10)])},
                               version=2)
    m3 = chunkfile.write_chunk(fs, base, "data/new.chunk",
                               {"k": np.arange(10, 20), "v": np.zeros(10),
                                "s": np.array([f"n{i}" for i in range(10)])})
    t.handle.commit([m2, m3], operation="WRITE")
    return t


def test_chk2_files_still_read_with_full_body_fallback():
    fs = RoundCountingFS()
    _mixed_table(fs, "bkt/m")
    assert fs.read_bytes("bkt/m/data/old.chunk")[:4] == b"CHK2"

    cols, _ = chunkfile.read_chunk(fs, "bkt/m", "data/old.chunk")
    np.testing.assert_array_equal(cols["k"], np.arange(10))
    nrows, stats = chunkfile.read_chunk_stats(fs, "bkt/m", "data/old.chunk")
    assert nrows == 10 and stats["k"].max == 9
    ftrs = chunkfile.read_chunks_footers(fs, "bkt/m",
                                         ["data/old.chunk", "data/new.chunk"])
    assert not ftrs[0].projectable and ftrs[1].projectable

    # projected batch read: v2 full body and v3 ranges in ONE round
    fs.reset()
    out = chunkfile.read_chunks_columns(
        fs, "bkt/m", ["data/old.chunk", "data/new.chunk"], ["k"],
        footers=ftrs)
    assert fs.ranged_rounds == 1 and fs.full_rounds == 0
    assert list(out[0][0]) == ["k", "v", "s"]     # v2: every column back
    assert list(out[1][0]) == ["k"]               # v3: only the requested
    assert out[0][1] == fs.size("bkt/m/data/old.chunk")


def test_mixed_table_scans_identical_to_full_scan():
    fs = RoundCountingFS()
    _mixed_table(fs, "bkt/m")
    server = _server(fs)
    off = _server(fs, late_materialization=False)
    snap = server.read("bkt/m", "delta").snapshot
    full = off.scan_snapshot(snap)

    proj = server.scan_snapshot(snap, columns=["k", "s"])
    assert list(proj.rows) == ["k", "s"]
    for c in proj.rows:
        np.testing.assert_array_equal(proj.rows[c], full.rows[c])

    pred = (Predicate("k", ">=", 5),)
    res = server.scan_snapshot(snap, pred, columns=["s"])
    mask = pred[0].mask(full.rows["k"])
    np.testing.assert_array_equal(res.rows["s"], full.rows["s"][mask])

    # LakeTable's local API over the same mixed table
    t = LakeTable.open(fs, "bkt/m", "delta")
    got = t.read_all(Predicate("k", ">=", 5), columns=["s"])
    np.testing.assert_array_equal(got["s"], full.rows["s"][mask])


def test_lake_table_scan_batched_and_projected():
    fs = RoundCountingFS()
    _wide_table(fs, "bkt/w", n_chunks=3, rows=32)
    t = LakeTable.open(fs, "bkt/w", "delta")
    full = t.read_all()

    fs.reset()
    all_rows = t.read_all()
    assert fs.full_rounds == 1                   # ONE batch, not 3 round trips

    fs.reset()
    proj = t.read_all(columns=["c05"])
    assert fs.full_rounds == 0                   # ranged column reads only
    assert fs.ranged_bytes * (NCOLS - 2) < fs.full_bytes or True
    np.testing.assert_array_equal(proj["c05"], full["c05"])
    assert list(proj) == ["c05"]

    pred = Predicate("c01", ">", 10)
    got = t.read_all(pred, columns=["c02"])
    mask = pred.mask(full["c01"])
    np.testing.assert_array_equal(got["c02"], full["c02"][mask])
    assert list(all_rows) == list(full)


# ------------------------------------------------------------ property sweep
_KINDS = ("int64", "float64", "ascii", "ucs4")


def _rand_schema(rng, ncols):
    kinds = [_KINDS[int(rng.integers(0, len(_KINDS)))] for _ in range(ncols)]
    return {f"{k[:1]}{i}": k for i, k in enumerate(kinds)}


def _rand_columns(rng, kinds, rows):
    cols = {}
    for name, kind in kinds.items():
        if kind == "int64":
            cols[name] = rng.integers(-100, 100, size=rows)
        elif kind == "float64":
            v = rng.normal(size=rows)
            v[rng.random(rows) < 0.3] = np.nan
            cols[name] = v
        elif kind == "ascii":
            cols[name] = np.array(
                [f"s{int(x):03d}" for x in rng.integers(0, 50, rows)])
        else:
            glyphs = np.array(["α", "β", "γé", "δü", "εø"])
            cols[name] = glyphs[rng.integers(0, len(glyphs), rows)]
    return cols


def test_property_sweep_projection_and_late_mat_byte_identical():
    """Random schemas / dtypes / predicates x projections: every projected
    and late-materialized scan must equal the full-body scan exactly —
    values AND dtypes — through the read plane and the local API."""
    rng = np.random.default_rng(11)
    for trial in range(6):
        fs = MemoryFS()
        base = f"bkt/p{trial}"
        kinds = _rand_schema(rng, int(rng.integers(3, 7)))
        names = list(kinds)
        schema = Schema([Field(n, "string") for n in names])
        t = LakeTable.create(fs, base, schema, "delta")
        for _c in range(int(rng.integers(2, 5))):
            t.append(_rand_columns(rng, kinds, int(rng.integers(1, 30))))

        on = _server(fs)
        off = _server(fs, late_materialization=False)
        snap = on.read(base, "delta").snapshot
        full = off.scan_snapshot(snap)

        for _case in range(8):
            col = names[int(rng.integers(0, len(names)))]
            op = ("==", "<", "<=", ">", ">=")[int(rng.integers(0, 5))]
            ref = full.rows[col]
            if ref.dtype.kind == "f":
                val = float(rng.normal())
            elif ref.dtype.kind == "i":
                val = int(rng.integers(-100, 100))
            else:
                val = str(ref[int(rng.integers(0, len(ref)))])
            preds = (Predicate(col, op, val),)
            proj = sorted(set(
                names[int(rng.integers(0, len(names)))]
                for _ in range(int(rng.integers(1, len(names) + 1)))))
            mask = preds[0].mask(ref)
            # baseline: knob-off full-body scan (the pre-index semantics);
            # rows == {} only when pruning removed every chunk, which is
            # sound only if the mask selects nothing
            base_res = off.scan_snapshot(snap, preds)
            if not base_res.rows:
                assert not mask.any()
            for c in base_res.rows:
                np.testing.assert_array_equal(base_res.rows[c],
                                              full.rows[c][mask])
            expect = {c: base_res.rows[c] for c in proj
                      if c in base_res.rows}

            for res in (on.scan_snapshot(snap, preds, columns=proj),
                        off.scan_snapshot(snap, preds, columns=proj)):
                assert list(res.rows) == list(expect)
                for c in expect:
                    assert res.rows[c].dtype == expect[c].dtype, (c, trial)
                    np.testing.assert_array_equal(res.rows[c], expect[c])
            got = LakeTable.open(fs, base, "delta").read_all(
                *preds, columns=proj)
            for c in expect:
                np.testing.assert_array_equal(got[c], expect[c])

            # no-projection late-mat path: full schema, identical rows
            res = on.scan_snapshot(snap, preds)
            assert list(res.rows) == list(base_res.rows)
            for c in base_res.rows:
                assert res.rows[c].dtype == base_res.rows[c].dtype
                np.testing.assert_array_equal(res.rows[c],
                                              base_res.rows[c])


def test_late_mat_knob_parses_from_config():
    assert ReadPlaneOptions.from_dict({}).late_materialization
    assert not ReadPlaneOptions.from_dict(
        {"lateMaterialization": False}).late_materialization
