"""Executable documentation: every fenced ``python`` block in README.md
and docs/*.md runs, so the docs cannot rot.

The extractor is doctest-shaped but file-granular: all ``python`` blocks
of one document execute sequentially in ONE shared namespace (so a
walkthrough can build state across blocks, exactly as a reader following
along would), and each document gets a fresh namespace. Blocks that are
deliberately not runnable (YAML configs, shell commands, illustrative
signatures) use ``yaml`` / ``sh`` / ``text`` fences and are skipped by
construction. A failure reports the document and the offending block's
line number so the fix is one click away.
"""

import re
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parent.parent

_FENCE = re.compile(r"^```(\w*)\s*$")


def _python_blocks(path):
    """[(start_line, source)] for every fenced ``python`` block."""
    blocks, lang, buf, start = [], None, [], 0
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        m = _FENCE.match(line.strip())
        if m and lang is None:
            lang, buf, start = m.group(1) or "text", [], lineno + 1
        elif line.strip() == "```" and lang is not None:
            if lang == "python":
                blocks.append((start, "\n".join(buf)))
            lang = None
        elif lang is not None:
            buf.append(line)
    assert lang is None, f"{path.name}: unterminated ``` fence"
    return blocks


def _documents():
    docs = [REPO_ROOT / "README.md"]
    docs += sorted((REPO_ROOT / "docs").glob("*.md"))
    return [d for d in docs if d.exists()]


@pytest.mark.parametrize("doc", _documents(), ids=lambda d: d.name)
def test_documented_python_runs(doc, monkeypatch):
    blocks = _python_blocks(doc)
    assert blocks, f"{doc.name} has no runnable python blocks"
    # blocks open with the reader-facing `sys.path.insert(0, "src")`,
    # which is cwd-relative — run them from the repo root like a reader
    monkeypatch.chdir(REPO_ROOT)
    namespace = {"__name__": f"docs_{doc.stem}"}
    for start, source in blocks:
        code = compile(source, f"{doc.name}:{start}", "exec")
        exec(code, namespace)


def test_every_document_is_indexed():
    """docs/*.md must be reachable from the README (no orphan docs)."""
    readme = (REPO_ROOT / "README.md").read_text()
    for doc in (REPO_ROOT / "docs").glob("*.md"):
        assert doc.name in readme, f"docs/{doc.name} is not linked in README.md"
