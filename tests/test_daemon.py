"""Continuous-sync daemon: watch -> replan -> drain cycle behavior.

What this file pins (all on a fake clock — no test ever wall-sleeps):

* ``head_token()`` on every LST handle costs exactly ONE storage request
  and moves iff the table head moved;
* an idle daemon cycle costs exactly one head probe per source table and
  ZERO target reads (counting-FS census);
* a cycle with N new commits costs O(N) source reads — the tail-only index
  refresh — plus O(1) target reads per drained unit;
* an N-commit backlog drains in exactly ceil(N / maxCommitsPerSync)
  cycles under backpressure, with per-cycle lag reported;
* a transient 503 on one table backs that table off (jittered, seeded,
  escalating) without stalling the others, and the table recovers once
  the window passes;
* ``run()`` paces cycles by the configured poll interval on the injected
  clock, stops after ``maxCyclesIdle`` consecutive idle cycles, and
  ``stop(drain=True)`` finishes the backlog before stopping.
"""

import math

import numpy as np
import pytest

from repro.core import ManualClock, SyncConfig, SyncDaemon, run_daemon
from repro.core.targets import TOKEN_KEY, make_target
from repro.lst import LakeTable
from repro.lst.schema import Field, PartitionSpec, Schema
from repro.lst.storage import MemoryFS, TransientStorageError, layer_fs
from repro.lst.table import FORMATS

SCHEMA = Schema([Field("k", "int64"), Field("part", "string")])


def _mk_table(fs, base, fmt="delta", n_commits=3):
    t = LakeTable.create(fs, base, SCHEMA, fmt, PartitionSpec(["part"]),
                         {"delta.checkpointInterval": "100000"})
    for i in range(n_commits):
        t.append({"k": np.array([i, i + 100], np.int64),
                  "part": np.array([f"p{i % 2}", "p0"])})
    return t


def _append(t, k=1):
    for i in range(k):
        t.append({"k": np.array([7 + i], np.int64),
                  "part": np.array(["p0"])})


def _cfg(bases, src="delta", targets=("iceberg",), **kw):
    d = {"sourceFormat": src.upper(),
         "targetFormats": [t.upper() for t in targets],
         "datasets": [{"tableBasePath": b} for b in bases]}
    d.update(kw)
    return SyncConfig.from_dict(d)


# --------------------------------------------------------------- head probes
@pytest.mark.parametrize("fmt", ["delta", "iceberg", "hudi"])
def test_head_token_is_one_request_and_tracks_head(fmt):
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/t", fmt, n_commits=2)
    fs = layer_fs(raw)
    handle = FORMATS[fmt].open(fs, "bkt/t")

    before = fs.stats().requests
    tok1 = handle.head_token()
    assert fs.stats().requests - before == 1     # exactly one storage request
    assert tok1 == handle.head_token()           # stable while quiet

    _append(t)                                   # writer moves the head
    tok2 = handle.head_token()
    assert tok2 != tok1


@pytest.mark.parametrize("fmt", ["delta", "iceberg", "hudi"])
def test_head_matches_current_version(fmt):
    raw = MemoryFS()
    _mk_table(raw, "bkt/t", fmt, n_commits=2)
    handle = FORMATS[fmt].open(raw, "bkt/t")
    assert handle.head() == handle.current_version()


# ------------------------------------------------------------- idle steady state
def test_idle_cycle_costs_one_probe_per_table_and_zero_target_reads():
    raw = MemoryFS()
    bases = [f"bkt/t{i}" for i in range(3)]
    for b in bases:
        _mk_table(raw, b)
    fs = layer_fs(raw)
    daemon = SyncDaemon(_cfg(bases, targets=("iceberg", "hudi")), fs,
                        clock=ManualClock())

    rep0 = daemon.run_cycle()                    # bootstrap: 3 x 2 FULL syncs
    assert rep0.units_drained == 6 and not rep0.idle

    for _ in range(3):                           # steady state: quiet tables
        rep = daemon.run_cycle()
        assert rep.idle and rep.quiet == 3 and rep.probed == 3
        ops = rep.storage_ops
        # exactly one head probe per source table (a delta log-tail LIST),
        # and nothing else — no planning reads, no target reads at all
        assert ops["list"] == 3
        assert ops["get"] == 0 and ops["head"] == 0
        assert ops["put"] == 0 and ops["delete"] == 0
        assert ops["requests"] == 3


def test_changed_cycle_costs_o_new_source_reads_o1_target_reads():
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/t")
    fs = layer_fs(raw)
    daemon = SyncDaemon(_cfg(["bkt/t"]), fs, clock=ManualClock())
    daemon.run_cycle()                           # FULL bootstrap
    assert daemon.run_cycle().idle               # cache warm, table quiet

    gets = {}
    for n in (4, 8):
        _append(t, n)
        rep = daemon.run_cycle()
        assert rep.units_drained == 1
        assert rep.results[0].commits_synced == n
        gets[n] = rep.storage_ops["get"]
        # the drained unit itself reads O(1) from the target (txn begin)
        # and nothing from the source (changes served from the warm index)
        assert rep.results[0].storage_ops["get"] <= 6

    # cycle GETs = N tail-refresh source reads + a constant target term:
    # doubling N adds exactly N more reads
    assert gets[8] - gets[4] == 4
    assert gets[4] <= 4 + 8


class _HeadReadCounter:
    """Delegating wrapper counting *head-discovery* requests on one source
    table: log/timeline listings (delta ``_delta_log/``, hudi ``.hoodie/``),
    ``version-hint.text`` reads, and iceberg metadata existence probes —
    the requests a head probe or a head re-read costs, as opposed to
    content reads of log segments / instant payloads / metadata JSONs."""

    def __init__(self, inner, base, fmt):
        self.inner = inner
        self.base = base
        self.fmt = fmt
        self.head_reads = 0

    def list_dir(self, path):
        probe_dir = {"delta": "_delta_log", "iceberg": "metadata",
                     "hudi": ".hoodie"}[self.fmt]
        if path.startswith(self.base) and path.rstrip("/").endswith(probe_dir):
            self.head_reads += 1
        return self.inner.list_dir(path)

    def read_bytes(self, path):
        if path.startswith(self.base) and self.fmt == "iceberg" and \
                path.endswith("version-hint.text"):
            self.head_reads += 1
        return self.inner.read_bytes(path)

    def exists(self, path):
        if path.startswith(self.base) and self.fmt == "iceberg" and \
                ("version-hint" in path or ".metadata.json" in path):
            self.head_reads += 1
        return self.inner.exists(path)

    def __getattr__(self, name):
        return getattr(self.inner, name)


@pytest.mark.parametrize("fmt,targets", [("delta", ("iceberg",)),
                                         ("iceberg", ("delta",)),
                                         ("hudi", ("delta",))])
def test_changed_cycle_reads_source_head_exactly_once(fmt, targets):
    """The daemon's probe doubles as the cycle's head hint: planner
    ``current_commit()`` and the index tail refresh consume that one probe,
    so a CHANGED cycle costs exactly ONE source-head read per table —
    previously ~3 (probe, planner head, refresh head)."""
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/src", fmt)
    counter = _HeadReadCounter(raw, "bkt/src", fmt)
    fs = layer_fs(counter)
    daemon = SyncDaemon(_cfg(["bkt/src"], src=fmt, targets=targets), fs,
                        clock=ManualClock())
    daemon.run_cycle()                           # FULL bootstrap
    assert daemon.run_cycle().idle               # warm + quiet

    _append(t, 3)
    counter.head_reads = 0
    rep = daemon.run_cycle()
    assert rep.changed == 1 and rep.units_drained == len(targets)
    assert rep.results[0].commits_synced == 3
    assert counter.head_reads == 1, counter.head_reads

    # and the hint is scoped to the cycle: the NEXT cycle's probe is a
    # fresh head read (one), not a stale cache hit
    counter.head_reads = 0
    assert daemon.run_cycle().idle
    assert counter.head_reads == 1


def test_hinted_refresh_detects_head_behind_anchor():
    """A probed head BEHIND the index anchor (restore / divergent rewrite)
    must trigger a full rebuild, not silently splice an empty tail and keep
    serving the vanished head."""
    from repro.core import MetadataCache

    raw = MemoryFS()
    _mk_table(raw, "bkt/t", "delta", n_commits=6)       # v0 .. v6
    idx = MetadataCache(raw).index("delta", "bkt/t")
    idx.ensure_built()
    assert idx.head() == "6"
    for v in range(4, 7):                               # rewind to v3
        raw.delete(f"bkt/t/_delta_log/{v:020d}.json")
    token = idx.probe()
    assert token == "3"
    idx.refresh()
    try:
        assert idx.head() == "3"
        assert idx.versions()[-1] == "3"
    finally:
        idx.end_cycle()


# ----------------------------------------------------- bounded drain backpressure
def test_backlog_drains_in_ceil_n_over_k_cycles():
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/t")
    fs = layer_fs(raw)
    n, k = 7, 3
    daemon = SyncDaemon(_cfg(["bkt/t"], maxCommitsPerSync=k), fs,
                        clock=ManualClock())
    daemon.run_cycle()                           # FULL bootstrap
    _append(t, n)

    lags, applied, drain_cycles = [], 0, 0
    while True:
        rep = daemon.run_cycle()
        if rep.idle:
            break
        drain_cycles += 1
        applied += rep.commits_applied
        lags.append(rep.total_lag)
        assert drain_cycles <= n                 # safety against livelock

    assert drain_cycles == math.ceil(n / k)      # 3 cycles for 7 commits
    assert applied == n
    assert lags == [4, 1, 0]                     # backlog shrinks by k a cycle

    # the target genuinely caught up to the source head
    target = make_target("iceberg", raw, "bkt/t")
    assert target.get_sync_token() == \
        FORMATS["delta"].open(raw, "bkt/t").head()


def test_pending_backlog_survives_quiet_head():
    """A capped drain keeps the dataset pending: the next cycle continues
    from the sync token even though the source head did not move again."""
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/t")
    fs = layer_fs(raw)
    daemon = SyncDaemon(_cfg(["bkt/t"], maxCommitsPerSync=2), fs,
                        clock=ManualClock())
    daemon.run_cycle()
    _append(t, 4)

    rep1 = daemon.run_cycle()
    assert rep1.commits_applied == 2 and rep1.total_lag == 2
    rep2 = daemon.run_cycle()                    # head token unchanged...
    assert rep2.changed == 1                     # ...but the backlog drains
    assert rep2.commits_applied == 2 and rep2.total_lag == 0


# -------------------------------------------------------------- fault isolation
class _FlakyFS:
    """Delegating wrapper that 503s every request touching ``match``."""

    def __init__(self, inner, match):
        self.inner = inner
        self.match = match
        self.armed = False

    def _guard(self, path):
        if self.armed and self.match in path:
            raise TransientStorageError(f"503 SlowDown ({path})")

    def __getattr__(self, name):
        fn = getattr(self.inner, name)
        if not callable(fn):
            return fn

        def wrapped(*args, **kw):
            if args and isinstance(args[0], str):
                self._guard(args[0])
            return fn(*args, **kw)
        return wrapped


def test_transient_503_backs_off_one_table_without_stalling_others():
    raw = MemoryFS()
    t0 = _mk_table(raw, "bkt/t0")
    t1 = _mk_table(raw, "bkt/t1")
    flaky = _FlakyFS(raw, "bkt/t0")
    fs = layer_fs(flaky)
    clock = ManualClock()
    cfg = _cfg(["bkt/t0", "bkt/t1"],
               daemon={"backoff": {"baseDelayMs": 1000, "jitter": 0.0,
                                   "multiplier": 2.0}})
    daemon = SyncDaemon(cfg, fs, clock=clock)
    daemon.run_cycle()                           # both bootstrap FULL

    flaky.armed = True
    _append(t0), _append(t1)
    rep = daemon.run_cycle()
    # t0's probe 503s and is backed off; t1 drains normally in the SAME cycle
    assert rep.table_errors == 1
    assert rep.failures[0][0] == "t0" and rep.failures[0][1] == "probe"
    assert rep.units_drained == 1 and rep.commits_applied == 1

    # inside the backoff window t0 is not even probed
    rep = daemon.run_cycle()
    assert rep.backed_off == 1 and rep.probed == 1 and rep.quiet == 1

    # still failing after the window: the backoff escalates (1s -> 2s)
    clock.advance(1.5)
    rep = daemon.run_cycle()
    assert rep.table_errors == 1
    w = daemon._watch["bkt/t0"]
    assert w.failures == 2
    assert w.not_before - clock.now() == pytest.approx(2.0)

    # recovery: disarm, let the window pass, and t0 catches up
    flaky.armed = False
    clock.advance(2.5)
    rep = daemon.run_cycle()
    assert rep.table_errors == 0 and rep.units_drained == 1
    assert rep.commits_applied == 1 and rep.total_lag == 0
    assert daemon._watch["bkt/t0"].failures == 0


def test_backoff_jitter_is_seeded_and_bounded():
    opts_cfg = _cfg(["bkt/t"], daemon={
        "backoff": {"baseDelayMs": 1000, "maxDelayMs": 4000,
                    "multiplier": 2.0, "jitter": 0.25, "seed": 42}})
    opts = opts_cfg.daemon
    assert opts.backoff_delay_s(1) == 1.0
    assert opts.backoff_delay_s(2) == 2.0
    assert opts.backoff_delay_s(5) == 4.0        # capped at maxDelayMs

    def delays():
        raw = MemoryFS()
        _mk_table(raw, "bkt/t")
        flaky = _FlakyFS(raw, "bkt/t")
        flaky.armed = True
        daemon = SyncDaemon(opts_cfg, layer_fs(flaky), clock=ManualClock())
        daemon.run_cycle()
        w = daemon._watch["bkt/t"]
        return w.not_before

    d1, d2 = delays(), delays()
    assert d1 == d2                              # seeded == reproducible
    assert 1.0 <= d1 <= 1.25                     # jitter within +25%


# ------------------------------------------------------------- run() scheduling
def test_run_paces_cycles_by_poll_interval_on_injected_clock():
    raw = MemoryFS()
    _mk_table(raw, "bkt/t")
    clock = ManualClock()
    cfg = _cfg(["bkt/t"], daemon={"pollIntervalMs": 250})
    daemon = SyncDaemon(cfg, layer_fs(raw), clock=clock)
    reports = daemon.run(cycles=5)
    assert len(reports) == 5
    # 4 sleeps between 5 cycles, each exactly the poll interval — and the
    # ManualClock means none of them were wall sleeps
    assert clock.now() == pytest.approx(4 * 0.25)
    assert [r.started_at for r in reports] == \
        pytest.approx([0.0, 0.25, 0.5, 0.75, 1.0])


def test_run_stops_after_max_cycles_idle():
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/t")
    cfg = _cfg(["bkt/t"], daemon={"pollIntervalMs": 10, "maxCyclesIdle": 3})
    reports = run_daemon(cfg, layer_fs(raw), clock=ManualClock())
    # cycle 0 drains (FULL), then exactly 3 consecutive idle cycles
    assert len(reports) == 4
    assert [r.idle for r in reports] == [False, True, True, True]

    # the idle counter is *consecutive*: new commits reset it
    daemon = SyncDaemon(cfg, layer_fs(raw), clock=ManualClock())
    daemon.run_cycle()
    daemon.run_cycle()                           # idle 1
    _append(t)
    reports = daemon.run(max_cycles_idle=2)
    assert [r.idle for r in reports] == [False, True, True]


def test_stop_drain_finishes_backlog_then_stops():
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/t")
    fs = layer_fs(raw)
    daemon = SyncDaemon(_cfg(["bkt/t"], maxCommitsPerSync=2), fs,
                        clock=ManualClock())
    daemon.run_cycle()
    _append(t, 6)
    daemon.run_cycle()                           # first bounded drain: 2 of 6
    assert daemon.lag() == {"bkt/t": True}

    daemon.stop(drain=True)
    reports = daemon.run()                       # drains 4 more, then stops
    assert sum(r.commits_applied for r in reports) == 4
    assert daemon.lag() == {"bkt/t": False}
    target = make_target("iceberg", raw, "bkt/t")
    assert target.get_sync_token() == \
        FORMATS["delta"].open(raw, "bkt/t").head()

    daemon2 = SyncDaemon(_cfg(["bkt/t"]), fs, clock=ManualClock())
    daemon2.stop()                               # hard stop before any cycle
    assert daemon2.run() == []


def test_repeated_stop_drain_keeps_draining_plain_stop_downgrades():
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/t")
    fs = layer_fs(raw)

    def backlogged_daemon():
        daemon = SyncDaemon(_cfg(["bkt/t"], maxCommitsPerSync=1), fs,
                            clock=ManualClock())
        daemon.run_cycle()
        _append(t, 3)
        daemon.run_cycle()                       # 1 of 3 drained -> pending
        return daemon

    d = backlogged_daemon()
    d.stop(drain=True)
    d.stop(drain=True)                           # idempotent: still draining
    assert sum(r.commits_applied for r in d.run()) == 2
    assert d.lag() == {"bkt/t": False}

    d = backlogged_daemon()
    d.stop(drain=True)
    d.stop()                                     # downgrade: stop NOW
    assert d.run() == []
    assert d.lag() == {"bkt/t": True}


def test_stop_interrupts_system_clock_poll_sleep():
    import threading
    import time as _time

    raw = MemoryFS()
    _mk_table(raw, "bkt/t")
    # a poll interval far longer than the test: without the interruptible
    # wait, stop() would strand run() inside time.sleep for 60s
    daemon = SyncDaemon(_cfg(["bkt/t"], daemon={"pollIntervalMs": 60_000}),
                        layer_fs(raw))
    threading.Timer(0.05, daemon.stop).start()
    t0 = _time.monotonic()
    reports = daemon.run()
    assert _time.monotonic() - t0 < 10.0
    assert len(reports) >= 1


def test_unbounded_run_retains_a_bounded_report_window():
    from repro.core import daemon as daemon_mod

    raw = MemoryFS()
    _mk_table(raw, "bkt/t")
    cfg = _cfg(["bkt/t"], daemon={"pollIntervalMs": 1})
    d = SyncDaemon(cfg, layer_fs(raw), clock=ManualClock())
    want = daemon_mod.MAX_RETAINED_REPORTS
    # stop once enough cycles have run to overflow the retention window
    orig = d.run_cycle

    def counted():
        rep = orig()
        if d.cycles_run >= want + 50:
            d.stop()
        return rep

    d.run_cycle = counted
    reports = d.run()                            # unbounded: rolling window
    assert d.cycles_run == want + 50
    assert len(reports) == want
    assert reports[-1].cycle == want + 49        # newest kept, oldest dropped


# ------------------------------------------------------------------- config
def test_daemon_config_block_parses():
    cfg = SyncConfig.from_dict({
        "sourceFormat": "DELTA", "targetFormats": ["HUDI"],
        "datasets": [{"tableBasePath": "bkt/t"}],
        "daemon": {"pollIntervalMs": 500, "maxCyclesIdle": 7,
                   "backoff": {"baseDelayMs": 25, "maxDelayMs": 800,
                               "multiplier": 3.0, "jitter": 0.5, "seed": 9}}})
    o = cfg.daemon
    assert o.poll_interval_ms == 500 and o.max_cycles_idle == 7
    assert o.backoff_base_delay_ms == 25 and o.backoff_max_delay_ms == 800
    assert o.backoff_multiplier == 3.0 and o.backoff_jitter == 0.5
    assert o.seed == 9

    with pytest.raises(ValueError):
        SyncConfig.from_dict({
            "sourceFormat": "DELTA", "targetFormats": ["HUDI"],
            "datasets": [], "daemon": {"maxCyclesIdle": 0}})


def test_daemon_multi_format_matrix_round_trip():
    """End to end on a hudi source: the daemon keeps BOTH targets fresh
    through several writer rounds, and every format reads the same rows."""
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/sales", "hudi", n_commits=2)
    fs = layer_fs(raw)
    daemon = SyncDaemon(_cfg(["bkt/sales"], src="hudi",
                             targets=("delta", "iceberg")), fs,
                        clock=ManualClock())
    daemon.run_cycle()
    for _ in range(3):
        _append(t, 2)
        rep = daemon.run_cycle()
        assert rep.units_drained == 2 and rep.total_lag == 0
        want = t.state().total_records()
        for fmt in ("delta", "iceberg"):
            got = LakeTable.open(raw, "bkt/sales", fmt).state().total_records()
            assert got == want, fmt
    # sync state rides in the targets' own metadata
    tgt = make_target("delta", raw, "bkt/sales")
    assert tgt._read_state()[TOKEN_KEY] == t.handle.head()
