"""Metadata-cache and executor tests.

The headline guarantee: an incremental sync of an N-commit backlog performs
exactly ONE log replay of the source table — verified with a counting
filesystem (every source log object read at most once during the run) and
with the index's own replay counter.  Plus: index == handle equivalence per
format, and concurrent multi-target execution producing the same state as
serial.
"""

import tempfile

import numpy as np
import pytest

from repro.core import (MetadataCache, SyncConfig, TableMetadataIndex,
                        run_sync)
from repro.lst import LakeTable, LocalFS
from repro.lst.fs import join
from repro.lst.schema import Field, PartitionSpec, Schema

SCHEMA = Schema([Field("k", "int64"), Field("part", "string")])
ALL = ("delta", "iceberg", "hudi")


class CountingFS(LocalFS):
    """LocalFS that counts read_bytes calls per path."""

    def __init__(self):
        super().__init__()
        self.reads = {}

    def read_bytes(self, path):
        self.reads[path] = self.reads.get(path, 0) + 1
        return super().read_bytes(path)

    def reset(self):
        self.reads = {}


def _mk_table(fs, fmt, n_commits, base=None):
    base = base or tempfile.mkdtemp() + "/t"
    t = LakeTable.create(fs, base, SCHEMA, fmt, PartitionSpec(["part"]))
    for i in range(n_commits):
        t.append({"k": np.array([i, i + 100], np.int64),
                  "part": np.array([f"p{i % 2}", "p0"])})
    return base, t


def _cfg(bases, src, targets):
    return SyncConfig.from_dict({
        "sourceFormat": src.upper(),
        "targetFormats": [t.upper() for t in targets],
        "datasets": [{"tableBasePath": b} for b in bases]})


# ------------------------------------------------------------- index == handle
@pytest.mark.parametrize("fmt", ALL)
def test_index_state_matches_handle_snapshot(fmt, fs):
    base, t = _mk_table(fs, fmt, n_commits=4)
    t.evolve_schema(SCHEMA.add_field(Field("extra", "float64")))
    idx = TableMetadataIndex(t.handle)
    for v in t.handle.versions():
        want = t.handle.snapshot(v)
        got = idx.state_at(v)
        assert set(got.files) == set(want.files), (fmt, v)
        assert got.schema.logical_eq(want.schema), (fmt, v)
        assert got.timestamp_ms == want.timestamp_ms, (fmt, v)
    head = idx.state_at()
    assert set(head.files) == set(t.handle.snapshot().files)
    assert idx.replays == 1          # every question answered from one pass


@pytest.mark.parametrize("fmt", ALL)
def test_index_entries_match_handle_changes(fmt, fs):
    base, t = _mk_table(fs, fmt, n_commits=3)
    idx = TableMetadataIndex(t.handle)
    for v in t.handle.versions():
        adds, removes, op, _ = t.handle.changes(v)
        e = idx.entry(v)
        assert sorted(f.path for f in e.adds) == sorted(f.path for f in adds)
        assert sorted(e.removes) == sorted(removes)
        assert e.operation == op
    assert idx.replays == 1


def test_index_refreshes_after_new_commits(fs):
    base, t = _mk_table(fs, "delta", n_commits=2)
    idx = TableMetadataIndex(t.handle)
    n0 = len(idx.versions())
    t.append({"k": np.array([7], np.int64), "part": np.array(["p0"])})
    # head moved -> only the tail is replayed, never the whole log again
    assert len(idx.versions()) == n0 + 1
    assert idx.replays == 1 and idx.tail_replays == 1


@pytest.mark.parametrize("fmt", ALL)
def test_tail_refresh_reads_only_new_commits(fmt):
    """After the index is built, k new commits cost O(k) metadata reads to
    refresh — not a rebuild of the whole history."""
    fs = CountingFS()
    base, t = _mk_table(fs, fmt, n_commits=10)
    idx = TableMetadataIndex(t.handle)
    before = dict(idx.state_at().files)      # build: one full replay
    news = [t.append({"k": np.array([200 + i], np.int64),
                      "part": np.array(["p0"])}) for i in range(3)]
    fs.reset()
    versions = idx.versions()                # head moved -> tail replay
    # the refresh read only tail-sized metadata: no old commit object was
    # touched again (delta/hudi); iceberg re-reads only the single metadata
    # JSON + the new snapshots' own manifests and manifest lists
    meta_reads = sum(n for p, n in fs.reads.items()
                     if "_delta_log" in p or ".hoodie" in p or
                     "/metadata/" in p)
    assert meta_reads <= 3 * 3 + 2, fs.reads
    assert versions[-3:] == news
    assert idx.replays == 1 and idx.tail_replays == 1
    # every entry (old + new) still served correctly after the tail splice
    head = idx.state_at()
    assert set(head.files) == set(t.handle.snapshot().files)
    assert set(before) <= set(head.files)


# --------------------------------------------------------- one replay per run
def test_incremental_backlog_replays_source_log_once():
    """N-commit backlog, 2 targets: every source log object is read at most
    once during the sync run — one replay total, not one per commit/target."""
    fs = CountingFS()
    base, t = _mk_table(fs, "delta", n_commits=4)
    run_sync(_cfg([base], "delta", ["iceberg", "hudi"]), fs)   # bootstrap
    for i in range(6):                                         # the backlog
        t.append({"k": np.array([50 + i], np.int64),
                  "part": np.array(["p1"])})
    fs.reset()
    res = run_sync(_cfg([base], "delta", ["iceberg", "hudi"]), fs)
    assert [r.mode for r in res] == ["INCREMENTAL", "INCREMENTAL"]
    assert all(r.commits_synced == 6 for r in res)
    log_dir = join(base, "_delta_log")
    log_reads = {p: n for p, n in fs.reads.items()
                 if p.startswith(log_dir) and p.endswith(".json")
                 and not p.endswith(".checkpoint.json")}
    assert log_reads, "no source log reads observed?"
    over_read = {p: n for p, n in log_reads.items() if n > 1}
    assert not over_read, f"source log objects read repeatedly: {over_read}"


def test_incremental_backlog_replays_hudi_timeline_once():
    fs = CountingFS()
    base, t = _mk_table(fs, "hudi", n_commits=3)
    run_sync(_cfg([base], "hudi", ["delta", "iceberg"]), fs)
    for i in range(5):
        t.append({"k": np.array([50 + i], np.int64),
                  "part": np.array(["p1"])})
    fs.reset()
    res = run_sync(_cfg([base], "hudi", ["delta", "iceberg"]), fs)
    assert all(r.mode == "INCREMENTAL" and r.commits_synced == 5 for r in res)
    hdir = join(base, ".hoodie")
    instant_reads = {p: n for p, n in fs.reads.items()
                     if p.startswith(hdir) and
                     (p.endswith(".commit") or p.endswith(".replacecommit"))}
    over_read = {p: n for p, n in instant_reads.items() if n > 1}
    assert not over_read, f"instants read repeatedly: {over_read}"


def test_shared_cache_reports_single_replay():
    fs = LocalFS()
    base, t = _mk_table(fs, "delta", n_commits=3)
    run_sync(_cfg([base], "delta", ["iceberg", "hudi"]), fs)
    for i in range(4):
        t.append({"k": np.array([9 + i], np.int64), "part": np.array(["p0"])})
    cache = MetadataCache(fs)
    run_sync(_cfg([base], "delta", ["iceberg", "hudi"]), fs, cache=cache)
    assert cache.total_replays() == 1


# ----------------------------------------------------- omni-direction sweep
@pytest.mark.parametrize("src", ALL)
def test_omni_full_then_incremental_with_evolution(src, fs):
    """Deterministic mini-sweep (the hypothesis suite's core invariant):
    FULL bootstrap, then an incremental batch containing a delete and a
    schema evolution, lands every target on the source's logical state."""
    from repro.lst.table import Predicate
    base, t = _mk_table(fs, src, n_commits=3)
    targets = [f for f in ALL if f != src]
    cfg = _cfg([base], src, targets)
    res = run_sync(cfg, fs)
    assert all(r.ok and r.mode == "FULL" for r in res), res
    t.delete_where(Predicate("k", "==", 1))
    t.evolve_schema(SCHEMA.add_field(Field("extra", "float64")))
    t.append({"k": np.array([500], np.int64), "part": np.array(["p1"]),
              "extra": np.array([1.5])})
    res = run_sync(cfg, fs)
    assert all(r.ok and r.mode == "INCREMENTAL" for r in res), res
    want_rows = sorted(t.read_all()["k"].tolist())
    want_schema = [(f.name, f.type) for f in t.state().schema.fields]
    for tf in targets:
        tt = LakeTable.open(fs, base, tf)
        assert sorted(tt.read_all()["k"].tolist()) == want_rows, (src, tf)
        assert [(f.name, f.type) for f in tt.state().schema.fields] == \
            want_schema, (src, tf)
        assert set(tt.state().files) == set(t.state().files), (src, tf)


# ------------------------------------------------------------- concurrency
def test_concurrent_matches_serial_multi_dataset():
    """2 datasets x 2 targets, serial vs thread-pool: identical end states."""
    fs = LocalFS()

    def build():
        bases = []
        for i in range(2):
            base, t = _mk_table(fs, "delta", n_commits=3)
            bases.append(base)
        return bases

    bases_serial, bases_conc = build(), build()
    rs = run_sync(_cfg(bases_serial, "delta", ["iceberg", "hudi"]), fs,
                  max_workers=1)
    rc = run_sync(_cfg(bases_conc, "delta", ["iceberg", "hudi"]), fs,
                  max_workers=4)
    assert len(rs) == len(rc) == 4
    assert all(r.ok for r in rs + rc)
    assert [(r.dataset, r.target_format, r.mode) for r in rc] == \
        [(r.dataset, r.target_format, r.mode) for r in rs]
    for bs, bc in zip(bases_serial, bases_conc):
        for tf in ("iceberg", "hudi"):
            a = LakeTable.open(fs, bs, tf)
            b = LakeTable.open(fs, bc, tf)
            assert sorted(a.read_all()["k"].tolist()) == \
                sorted(b.read_all()["k"].tolist())
            # uuid-named chunks differ between the two builds; shape must not
            assert len(a.state().files) == len(b.state().files)
            # each target references its own source's data files verbatim
            assert set(a.state().files) == \
                set(LakeTable.open(fs, bs, "delta").state().files)


def test_concurrent_incremental_correctness():
    """Concurrent incremental sync of a backlog lands every target on the
    source head with the exact source row set."""
    fs = LocalFS()
    base, t = _mk_table(fs, "hudi", n_commits=2)
    cfg = _cfg([base], "hudi", ["delta", "iceberg"])
    run_sync(cfg, fs, max_workers=4)
    for i in range(4):
        t.append({"k": np.array([70 + i], np.int64),
                  "part": np.array(["p1"])})
    res = run_sync(cfg, fs, max_workers=4)
    assert all(r.mode == "INCREMENTAL" and r.ok for r in res)
    want = sorted(t.read_all()["k"].tolist())
    for tf in ("delta", "iceberg"):
        got = sorted(LakeTable.open(fs, base, tf).read_all()["k"].tolist())
        assert got == want
