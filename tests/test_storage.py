"""Storage subsystem: simulated object store, retries, batching, counters.

What this file pins:

* ``MemoryFS`` has real object-store semantics — atomic put-if-absent,
  prefix listings, suffix/to-EOF ranged reads;
* racing conditional puts yield exactly ONE winner (the commit primitive),
  at the raw-store level and through concurrent handle commits;
* a transient throttle mid-sync is retried to success and an *ambiguous*
  put (applied, response lost) is resolved as success, while a genuine
  lost race still surfaces as a conflict;
* a writer crashing mid-drain leaves a valid prefix on the simulated store
  and a clean re-run completes from it;
* batch reads are pipelined (a replay at RTT costs ~1 round of round
  trips, not one per object);
* the instrumented FS gives a per-unit request census, and the census is
  PINNED: target-side requests per incremental unit are O(1) in target
  history, total run requests are O(new commits) in source history — a
  request-count regression fails here;
* URI resolution keeps the bucket: two buckets with the same key path are
  different tables.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import MetadataCache, SyncConfig, Telemetry, run_sync
from repro.core.targets import TOKEN_KEY
from repro.lst import LakeTable
from repro.lst.schema import Field, PartitionSpec, Schema
from repro.lst.storage import (InstrumentedFS, LocalFS, MemoryFS,
                               PutIfAbsentError, RetryPolicy, RetryingFS,
                               SimulatedObjectStore, StorageProfile,
                               StorageRetryExhausted, TransientStorageError,
                               layer_fs, make_fs, resolve_uri)

SCHEMA = Schema([Field("k", "int64"), Field("part", "string")])
NO_SLEEP = dict(sleep=lambda s: None)


def _mk_table(fs, base, fmt="delta", n_commits=3, properties=None):
    t = LakeTable.create(fs, base, SCHEMA, fmt, PartitionSpec(["part"]),
                         properties)
    for i in range(n_commits):
        t.append({"k": np.array([i, i + 100], np.int64),
                  "part": np.array([f"p{i % 2}", "p0"])})
    return t


def _cfg(base_uri, src, targets, **kw):
    d = {"sourceFormat": src.upper(),
         "targetFormats": [t.upper() for t in targets],
         "datasets": [{"tableBasePath": base_uri}]}
    d.update(kw)
    return SyncConfig.from_dict(d)


# ------------------------------------------------------------ MemoryFS core
def test_memoryfs_object_store_semantics():
    fs = MemoryFS()
    fs.write_bytes("bkt/t/a/x", b"one")
    fs.write_bytes("bkt/t/a/y", b"two")
    fs.write_bytes("bkt/t/b", b"three")
    assert fs.read_bytes("bkt/t/a/x") == b"one"
    assert fs.list_dir("bkt/t") == ["a", "b"]
    assert fs.list_dir("bkt/t/a") == ["x", "y"]
    assert fs.list_dir("bkt/nope") == []
    assert fs.exists("bkt/t/a") and fs.exists("bkt/t/a/x")
    assert not fs.exists("bkt/t/c")
    assert fs.size("bkt/t/b") == 5
    with pytest.raises(PutIfAbsentError):
        fs.write_bytes("bkt/t/b", b"clobber")
    fs.write_bytes("bkt/t/b", b"clobber", overwrite=True)
    assert fs.read_bytes("bkt/t/b") == b"clobber"
    fs.delete("bkt/t/b")
    assert not fs.exists("bkt/t/b")
    with pytest.raises(FileNotFoundError):
        fs.read_bytes("bkt/t/b")


@pytest.mark.parametrize("make", [MemoryFS, LocalFS])
def test_ranged_reads_suffix_and_to_eof(make, tmp_path):
    fs = make()
    path = ("bkt/obj" if isinstance(fs, MemoryFS)
            else str(tmp_path / "obj"))
    fs.write_bytes(path, b"0123456789")
    assert fs.read_bytes_range(path, 2, 3) == b"234"
    assert fs.read_bytes_range(path, -4, 4) == b"6789"     # suffix
    assert fs.read_bytes_range(path, 6, -1) == b"6789"     # to EOF
    assert fs.read_bytes_range(path, -20, 20) == b"0123456789"


# --------------------------------------------------- put-if-absent races
def test_racing_conditional_puts_one_winner():
    fs = MemoryFS()
    outcomes = []

    def racer(i):
        try:
            fs.write_bytes("bkt/commit-7", b"writer-%d" % i)
            outcomes.append(("win", i))
        except PutIfAbsentError:
            outcomes.append(("lose", i))

    threads = [threading.Thread(target=racer, args=(i,)) for i in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    wins = [o for o in outcomes if o[0] == "win"]
    assert len(wins) == 1
    assert fs.read_bytes("bkt/commit-7") == b"writer-%d" % wins[0][1]


def test_two_concurrent_executors_commit_race():
    """Two handle writers racing the same next version: put-if-absent makes
    one win the slot, the loser retries onto the next — both commits land,
    no version is written twice."""
    fs = MemoryFS()
    _mk_table(fs, "bkt/t", "delta", 1)
    results = []

    def committer(tag):
        h = LakeTable.open(fs, "bkt/t", "delta").handle
        from repro.lst.chunkfile import DataFileMeta
        add = DataFileMeta(path=f"data/{tag}.chunk", size_bytes=1,
                           record_count=1)
        results.append(h.commit([add], []))

    threads = [threading.Thread(target=committer, args=(f"w{i}",))
               for i in range(2)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert len(set(results)) == 2          # distinct versions, both landed
    st = LakeTable.open(fs, "bkt/t", "delta").state()
    assert {"data/w0.chunk", "data/w1.chunk"} <= set(st.files)


# ------------------------------------------------ transient faults + retry
def test_transient_throttle_mid_unit_retried_to_success():
    """A sync unit whose requests get probabilistically 503'd completes via
    the retry layer, lands the exact source state, and the retry counter
    shows the faults were real."""
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/t", "delta", 6)
    fs = layer_fs(raw, profile=StorageProfile(fault_rate=0.15, seed=11),
                  retry=RetryPolicy(max_attempts=10, base_delay_s=1e-4))
    cfg = _cfg("mem://bkt/t", "delta", ["iceberg", "hudi"])
    res = run_sync(cfg, fs)
    assert all(r.ok for r in res), res
    assert fs.retries() > 0
    assert fs.inner.inner.injected_faults > 0
    for tgt in ("iceberg", "hudi"):
        got = LakeTable.open(raw, "bkt/t", tgt).read_all()
        assert sorted(got["k"].tolist()) == \
            sorted(t.read_all()["k"].tolist()), tgt


def test_ambiguous_put_resolved_as_success():
    """A conditional put that APPLIES but whose response is lost must not be
    reported as a conflict: the retry layer reads the object back and
    recognizes its own write."""
    raw = MemoryFS()
    sim = SimulatedObjectStore(raw, StorageProfile(ambiguous_put_rate=1.0))
    fs = RetryingFS(sim, RetryPolicy(max_attempts=3), **NO_SLEEP)
    fs.write_bytes("bkt/v7.json", b"commit-payload")
    assert raw.read_bytes("bkt/v7.json") == b"commit-payload"
    # a genuinely lost race is still a conflict
    with pytest.raises(PutIfAbsentError):
        fs.write_bytes("bkt/v7.json", b"other-writer")


def test_retry_exhaustion_is_not_a_conflict():
    raw = MemoryFS()
    sim = SimulatedObjectStore(raw, StorageProfile(fault_rate=1.0, seed=0))
    fs = RetryingFS(sim, RetryPolicy(max_attempts=3), **NO_SLEEP)
    with pytest.raises(StorageRetryExhausted):
        fs.read_bytes("bkt/x")
    with pytest.raises(StorageRetryExhausted):
        fs.write_bytes("bkt/x", b"data")


def test_batch_reads_retry_only_failed_items():
    """A throttled batch refetches its 503'd items, not the whole batch."""
    raw = MemoryFS()
    paths = [f"bkt/o{i}" for i in range(32)]
    for i, p in enumerate(paths):
        raw.write_bytes(p, b"payload-%d" % i)
    sim = SimulatedObjectStore(raw, StorageProfile(fault_rate=0.3, seed=5))
    fs = RetryingFS(sim, RetryPolicy(max_attempts=10), **NO_SLEEP)
    out = fs.read_many(paths)
    assert out == [b"payload-%d" % i for i in range(32)]
    assert fs.retries > 0
    # requests ~= N + retried items, far below N * attempts
    assert sim.requests < 2 * len(paths)


# -------------------------------------------------- crash-prefix recovery
class _DieAfterPuts:
    """Pass-through FS whose writes start failing hard after a budget —
    a deterministic 'process died mid-drain' for recovery tests."""

    def __init__(self, inner, puts_allowed: int):
        self.inner = inner
        self.puts_allowed = puts_allowed

    def write_bytes(self, path, data, *, overwrite=False):
        if self.puts_allowed <= 0:
            raise TransientStorageError("simulated crash (connection gone)")
        self.puts_allowed -= 1
        return self.inner.write_bytes(path, data, overwrite=overwrite)

    def __getattr__(self, name):
        return getattr(self.inner, name)


def test_crash_prefix_recovery_on_simulated_store():
    """Kill a drain after a few target commits: the store holds a valid
    prefix (every flushed commit is atomic), and re-running the sync
    resumes from the recorded token and converges — no duplicates."""
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/t", "delta", 2)
    cfg = _cfg("mem://bkt/t", "delta", ["hudi"])
    assert run_sync(cfg, layer_fs(raw))[0].ok           # bootstrap
    for i in range(6):
        t.append({"k": np.array([500 + i], np.int64),
                  "part": np.array(["p1"])})

    # hudi writes 3 objects per instant; allow ~2.5 commits then die
    dying = RetryingFS(_DieAfterPuts(raw, 8),
                       RetryPolicy(max_attempts=1), **NO_SLEEP)
    res = run_sync(cfg, dying)
    assert not res[0].ok                                 # the unit died
    prefix = LakeTable.open(raw, "bkt/t", "hudi")
    token = prefix.handle.latest_extra_metadata().get(TOKEN_KEY)
    assert token is not None                             # a valid prefix

    res = run_sync(cfg, layer_fs(raw))                   # recovery = rerun
    assert res[0].ok and res[0].mode == "INCREMENTAL"
    got = LakeTable.open(raw, "bkt/t", "hudi").read_all()
    assert sorted(got["k"].tolist()) == sorted(t.read_all()["k"].tolist())


# ------------------------------------------------ pipelined batch WRITES
def test_write_many_matches_sequential_semantics():
    fs = MemoryFS()
    fs.write_many([("bkt/a", b"1"), ("bkt/b", b"2")])
    assert fs.read_bytes("bkt/a") == b"1" and fs.read_bytes("bkt/b") == b"2"
    with pytest.raises(PutIfAbsentError):        # put-if-absent by default
        fs.write_many([("bkt/c", b"3"), ("bkt/a", b"clobber")])
    fs.write_many([("bkt/a", b"new")], overwrite=True)
    assert fs.read_bytes("bkt/a") == b"new"


def test_write_many_retries_only_failed_items():
    """A throttled staged flush re-puts its 503'd items, not the batch."""
    raw = MemoryFS()
    items = [(f"bkt/o{i}", b"payload-%d" % i) for i in range(32)]
    sim = SimulatedObjectStore(raw, StorageProfile(fault_rate=0.3, seed=5))
    fs = RetryingFS(sim, RetryPolicy(max_attempts=10), **NO_SLEEP)
    fs.write_many(items)
    assert [raw.read_bytes(p) for p, _ in items] == [d for _, d in items]
    assert fs.retries > 0
    # requests ~= N + retried items, far below N * attempts
    assert sim.requests < 2 * len(items)


def test_write_many_ambiguous_put_mid_pipeline_resolved():
    """A staged put that APPLIES but loses its response mid-pipeline is
    recognized as our own write via per-item read-back — while a genuine
    lost race in the same batch still surfaces as a conflict."""
    raw = MemoryFS()
    sim = SimulatedObjectStore(raw, StorageProfile(ambiguous_put_rate=1.0))
    fs = RetryingFS(sim, RetryPolicy(max_attempts=3), **NO_SLEEP)
    items = [(f"bkt/m{i}", b"manifest-%d" % i) for i in range(8)]
    fs.write_many(items)                          # every response is lost
    assert [raw.read_bytes(p) for p, _ in items] == [d for _, d in items]
    # a pre-existing object with FOREIGN content is a real conflict
    raw.write_bytes("bkt/taken", b"foreign-writer")
    with pytest.raises(PutIfAbsentError):
        fs.write_many([("bkt/fresh", b"x"), ("bkt/taken", b"mine")])
    assert raw.read_bytes("bkt/taken") == b"foreign-writer"


def test_write_many_is_pipelined_under_rtt():
    raw = MemoryFS()
    items = [(f"bkt/w{i}", b"x") for i in range(12)]
    rtt = 0.010

    def timed(depth):
        fs = SimulatedObjectStore(
            raw, StorageProfile(rtt_ms=rtt * 1000, pipeline_depth=depth))
        t0 = time.perf_counter()
        fs.write_many([(f"{p}.d{depth}", d) for p, d in items])
        return time.perf_counter() - t0, fs.requests, fs.serial_rounds()

    seq_dt, seq_reqs, seq_rounds = timed(1)
    bat_dt, bat_reqs, bat_rounds = timed(16)
    assert seq_reqs == bat_reqs == len(items)   # same request count...
    assert seq_dt >= len(items) * rtt           # ...serial pays every RTT
    assert bat_dt < seq_dt / 2                  # ...pipelined overlaps them
    assert seq_rounds == len(items) and bat_rounds == 1


# --------------------------------------------------------- batch pipelining
def test_read_many_is_pipelined_under_rtt():
    raw = MemoryFS()
    paths = [f"bkt/o{i}" for i in range(12)]
    for p in paths:
        raw.write_bytes(p, b"x")
    rtt = 0.010

    def timed(depth):
        fs = SimulatedObjectStore(
            raw, StorageProfile(rtt_ms=rtt * 1000, pipeline_depth=depth))
        t0 = time.perf_counter()
        out = fs.read_many(paths)
        assert out == [b"x"] * len(paths)
        return time.perf_counter() - t0, fs.requests

    seq_dt, seq_reqs = timed(1)
    bat_dt, bat_reqs = timed(16)
    assert seq_reqs == bat_reqs == len(paths)   # same request count...
    assert seq_dt >= len(paths) * rtt           # ...serial pays every RTT
    assert bat_dt < seq_dt / 2                  # ...pipelined overlaps them


# ------------------------------------------------- URI registry resolution
def test_resolve_uri_keeps_bucket():
    assert resolve_uri("/plain/path") == "/plain/path"
    assert resolve_uri("file:///tmp/x") == "/tmp/x"
    assert resolve_uri("file://localhost/tmp/x") == "/tmp/x"
    assert resolve_uri("mem://bucket-a/sales") == "bucket-a/sales"
    assert resolve_uri("s3sim://bucket-b/sales") == "bucket-b/sales"
    assert resolve_uri("abfs://c@acct.dfs.core.windows.net/sales") == \
        "c@acct.dfs.core.windows.net/sales"
    # the seed bug: both buckets collapsed to "/sales" and collided
    assert resolve_uri("mem://bucket-a/sales") != \
        resolve_uri("mem://bucket-b/sales")


def test_same_key_in_two_buckets_does_not_collide():
    fs = MemoryFS()
    _mk_table(fs, resolve_uri("mem://bucket-a/sales"), "delta", 1)
    _mk_table(fs, resolve_uri("mem://bucket-b/sales"), "delta", 2)
    a = LakeTable.open(fs, "bucket-a/sales", "delta")
    b = LakeTable.open(fs, "bucket-b/sales", "delta")
    assert len(a.history()) == 2 and len(b.history()) == 3


def test_make_fs_registry():
    assert isinstance(make_fs("file:///tmp/x"), LocalFS)
    assert isinstance(make_fs("/plain/path"), LocalFS)
    assert isinstance(make_fs("mem://b/t"), MemoryFS)
    assert isinstance(make_fs("s3sim://b/t"), SimulatedObjectStore)
    # mem:// views share one store (that's what makes it "a bucket")
    assert make_fs("mem://b/t") is make_fs("mem://c/u")
    with pytest.raises(ValueError, match="unknown storage scheme"):
        make_fs("gopher://b/t")


def test_config_storage_options_parse_and_build():
    cfg = _cfg("s3sim://bkt/t", "delta", ["iceberg"],
               storage={"rttMs": 2.5, "faultRate": 0.01, "pipelineDepth": 4,
                        "seed": 9, "retry": {"maxAttempts": 7}})
    assert cfg.storage.rtt_ms == 2.5
    assert cfg.storage.retry_max_attempts == 7
    fs = cfg.build_fs(Telemetry())
    assert isinstance(fs, InstrumentedFS)
    assert isinstance(fs.inner, RetryingFS)
    sim = fs.inner.inner
    assert isinstance(sim, SimulatedObjectStore)
    assert sim.profile.rtt_ms == 2.5 and sim.profile.pipeline_depth == 4
    assert isinstance(sim.inner, MemoryFS)
    # pipelineDepth/seed are honored on s3sim even with NO injection knobs
    # (the sequential comparison arm is exactly {"pipelineDepth": 1})
    seq = _cfg("s3sim://bkt/t", "delta", ["iceberg"],
               storage={"pipelineDepth": 1, "seed": 7}).build_fs()
    assert seq.inner.inner.profile.pipeline_depth == 1
    assert seq.inner.inner.profile.seed == 7
    # mixed schemes are rejected (one FileSystem per run)
    bad = SyncConfig.from_dict({
        "sourceFormat": "DELTA", "targetFormats": ["ICEBERG"],
        "datasets": [{"tableBasePath": "mem://a/t"},
                     {"tableBasePath": "s3sim://b/t"}]})
    with pytest.raises(ValueError, match="multiple storage schemes"):
        bad.build_fs()


# ------------------------------------------- instrumented request censuses
def _warm_drain(history: int, backlog: int):
    """Bootstrap + grow + warm-cache drain; returns (result, run_stats)."""
    raw = MemoryFS()
    tel = Telemetry()
    fs = layer_fs(raw, telemetry=tel)
    t = _mk_table(raw, "bkt/t", "delta", 1,
                  properties={"delta.checkpointInterval": "1000"})
    cfg = _cfg("mem://bkt/t", "delta", ["iceberg"])
    cache = MetadataCache(fs)
    assert run_sync(cfg, fs, cache=cache)[0].mode == "FULL"
    for i in range(history):
        t.append({"k": np.array([i], np.int64), "part": np.array(["p0"])})
    assert run_sync(cfg, fs, cache=cache)[0].ok
    for i in range(backlog):
        t.append({"k": np.array([9000 + i], np.int64),
                  "part": np.array(["p1"])})
    before = fs.stats()
    res = run_sync(cfg, fs, cache=cache)
    after = fs.stats()
    assert res[0].ok and res[0].commits_synced == backlog
    run_reqs = {k: getattr(after, k) - getattr(before, k)
                for k in ("get", "put", "list", "head")}
    run_reqs["requests"] = sum(run_reqs.values())
    return res[0], run_reqs


def test_per_unit_storage_census_pinned():
    """The per-unit census (target-side: the unit runs the drain, planning
    reads happen outside the scope) stays flat as the TARGET history grows,
    and the whole run's requests stay flat as the SOURCE history grows —
    i.e. reads are O(1) per unit / O(new commits) per run.  The absolute
    numbers are pinned so a request-count regression fails loudly; update
    them only with a storage-architecture change that explains the delta.
    """
    r8, run8 = _warm_drain(history=8, backlog=4)
    r32, run32 = _warm_drain(history=32, backlog=4)
    assert r8.storage_ops is not None
    # unit census flat in history
    assert r8.storage_ops["requests"] == r32.storage_ops["requests"], \
        (r8.storage_ops, r32.storage_ops)
    # whole-run requests flat in history too (tail-only refresh)
    assert run8["requests"] == run32["requests"], (run8, run32)
    # and the pinned absolute numbers (see docstring)
    assert r8.storage_ops["requests"] == PER_UNIT_REQUESTS_4_COMMIT_DRAIN, \
        r8.storage_ops
    assert run8["requests"] == PER_RUN_REQUESTS_4_COMMIT_DRAIN, run8


def test_backlog_scaling_is_linear_in_new_commits():
    _, run4 = _warm_drain(history=8, backlog=4)
    _, run8 = _warm_drain(history=8, backlog=8)
    # each extra source commit costs a bounded number of extra requests
    per_commit = (run8["requests"] - run4["requests"]) / 4
    assert per_commit <= MAX_REQUESTS_PER_NEW_COMMIT, (run4, run8)


# ------------------------------------------------ batched chunkfile stats
def test_read_chunks_stats_batched_matches_single():
    from repro.lst import chunkfile

    raw = MemoryFS()
    fs = layer_fs(raw)
    rels, want = [], []
    for i in range(5):
        cols = {"a": np.arange(i, i + 1000, dtype=np.int64),
                "b": np.linspace(-i, i, 1000)}
        rel = f"d/f{i}.chunk"
        chunkfile.write_chunk(raw, "bkt/t", rel, cols)
        rels.append(rel)
        want.append(chunkfile.read_chunk_stats(raw, "bkt/t", rel))
    before = fs.stats()
    got = chunkfile.read_chunks_stats(fs, "bkt/t", rels)
    after = fs.stats()
    assert got == want
    # two batched range rounds (trailer + footer) per file, no size() calls,
    # and the column data is never fetched
    assert after.get - before.get == 2 * len(rels)
    assert after.head - before.head == 0
    total = sum(raw.size(f"bkt/t/{r}") for r in rels)
    assert after.bytes_read - before.bytes_read < total / 10


def test_verify_stats_across_sync_and_detects_corruption():
    """Metadata-vs-footer integrity holds in the source AND in every synced
    target (metadata-only translation preserves pruning stats), and a
    metadata lie is caught."""
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/t", "delta", 3)
    run_sync(_cfg("mem://bkt/t", "delta", ["iceberg", "hudi"]), layer_fs(raw))
    assert t.verify_stats() == []
    for tgt in ("iceberg", "hudi"):
        assert LakeTable.open(raw, "bkt/t", tgt).verify_stats() == [], tgt
    # corrupt one commit's recorded stats in the delta log: caught
    log = "bkt/t/_delta_log"
    name = [n for n in raw.list_dir(log) if n.endswith("00001.json")][0]
    # add.stats is an escaped JSON string inside the action line
    doctored = raw.read_bytes(f"{log}/{name}").decode().replace(
        '\\"numRecords\\": 2', '\\"numRecords\\": 3')
    raw.write_bytes(f"{log}/{name}", doctored.encode(), overwrite=True)
    assert LakeTable.open(raw, "bkt/t", "delta").verify_stats() != []


# ------------------------------------------------ chunkfile string codec
def test_chunk_string_roundtrip_vectorized_paths():
    """The fixed-width C-cast string codec round-trips every column shape
    the table layer produces: ascii, non-ascii (UCS4 buffer), empty
    strings, embedded NULs, 2D, and explicit padded widths — with and
    without compression."""
    from repro.lst.chunkfile import _decode_array, _encode_array

    cases = [
        np.array(["alpha", "b", "", "part-042/file-00000007"]),   # ascii
        np.array(["héllo", "wörld", "día"]),                      # ucs4
        np.array(["a\x00b", "c"]),                     # embedded (non-trailing) NUL
        np.array([["aa", "bb"], ["cc", "dd"]]),                   # 2D
        np.array(["x"], dtype="U16"),                             # padded width
    ]
    for arr in cases:
        for compress in (False, True):
            decl, raw = _encode_array(arr, compress)
            back = _decode_array(decl, raw)
            assert back.shape == arr.shape
            assert (back == arr).all(), arr

    decl, _ = _encode_array(cases[0], False)
    assert decl["enc"] == "ascii"                 # 1 byte/char on the wire
    decl, _ = _encode_array(cases[1], False)
    assert decl["enc"] == "ucs4"                  # native buffer memcpy


def test_chunk_string_legacy_decode_compat():
    """Chunks written by the legacy msgpack-list codec (decl carries no
    ``enc`` key) still decode byte-identically."""
    from repro.lst.chunkfile import _decode_array, _encode_str_legacy

    arr = np.array([["a", "bb"], ["ccc", "dddd"]])
    raw = _encode_str_legacy(arr)
    back = _decode_array({"dtype": "str", "shape": list(arr.shape)}, raw)
    assert back.shape == arr.shape and (back == arr).all()


def test_chunk_string_stats_match_builtin_ordering():
    from repro.lst.chunkfile import _column_stats

    arr = np.array(["p3", "p0", "p10", "p2"])
    st = _column_stats(arr)
    assert (st.min, st.max, st.count) == ("p0", "p3", 4)


# Pinned censuses for the scenario in _warm_drain (delta source -> iceberg
# target, warm shared cache, 4-commit backlog, transactional drain):
# unit = 1 GET (the parent manifest-list — the plan-time metadata read now
# seeds the transaction, so begin re-reads NOTHING) + 13 PUT (4 commits x
# manifest/manifest-list/metadata, staged + serial, plus ONE deferred
# version-hint move per flush — PR 5's write pipelining, down from 25
# requests when begin re-discovered the head and every commit rewrote the
# hint); run adds the planner's tail refresh (one GET per new source
# commit), the plan-time target state read, and head/list probes.
PER_UNIT_REQUESTS_4_COMMIT_DRAIN = 14
PER_RUN_REQUESTS_4_COMMIT_DRAIN = 27
MAX_REQUESTS_PER_NEW_COMMIT = 6
