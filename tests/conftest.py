import os
import sys
import tempfile

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# smoke tests and benches must see the single real device, NOT 512 fake ones
# (the dry-run sets XLA_FLAGS itself, in a subprocess).
os.environ.pop("XLA_FLAGS", None)


@pytest.fixture()
def fs():
    from repro.lst import LocalFS
    return LocalFS()


@pytest.fixture()
def tmp_table_path():
    return tempfile.mkdtemp() + "/table"


@pytest.fixture()
def sales_columns():
    return {
        "s_id": np.array([1, 2, 3, 4, 5, 6], np.int64),
        "s_type": np.array(["a", "a", "b", "b", "c", "c"]),
        "price": np.array([10.0, 20.0, 30.0, 40.0, 50.0, 60.0]),
    }
