"""Snapshot-serving read plane: conditional-GET economics, single-flight,
snapshot immutability, LRU bounds, and stats-footer scan pruning.

The counting-FS pins here are the read-side complexity contract (ISSUE 8):
an unchanged table costs a reader ZERO storage requests inside the probe
window (and the window itself costs ONE probe shared across all readers);
a changed table costs one tail-only refresh shared by every concurrent
reader; stats pruning never changes scan results and never reads a chunk
body its footer refutes.
"""

import threading

import numpy as np
import pytest

from repro.core import (ManualClock, MetadataCache, ReadPlaneOptions,
                        SyncConfig, SyncDaemon)
from repro.lst import chunkfile
from repro.lst.chunkfile import ChunkStatsCache, ColumnStats, stats_refute
from repro.lst.schema import Field, Schema
from repro.lst.storage import MemoryFS, layer_fs
from repro.lst.table import LakeTable, Predicate
from repro.serve.read_plane import NOT_MODIFIED, OK, SnapshotServer

SCHEMA = Schema([Field("k", "int64"), Field("v", "float64"),
                 Field("s", "string")])


def _mk_table(fs, base, fmt="delta", n_commits=3, rows=20, seed=0):
    """Each commit's ``k`` lives in a disjoint [c*1000, c*1000+rows) band,
    so value predicates are selective per chunk."""
    t = LakeTable.create(fs, base, SCHEMA, fmt)
    rng = np.random.default_rng(seed)
    for c in range(n_commits):
        t.append({"k": np.arange(c * 1000, c * 1000 + rows),
                  "v": rng.normal(size=rows),
                  "s": np.array([f"s{c:02d}_{i:03d}" for i in range(rows)])})
    return t


def _server(raw, ttl_ms=1000.0, **opts):
    fs = layer_fs(raw)
    clock = ManualClock()
    server = SnapshotServer(
        fs, options=ReadPlaneOptions(ttl_ms=ttl_ms, **opts),
        cache=MetadataCache(fs), clock=clock)
    return server, fs, clock


def _cfg(base, src="delta", targets=("iceberg",)):
    return SyncConfig.from_dict({
        "sourceFormat": src.upper(),
        "targetFormats": [t.upper() for t in targets],
        "datasets": [{"tableBasePath": base}]})


# ------------------------------------------------------------ config block
def test_read_plane_config_parses():
    cfg = SyncConfig.from_dict({
        "sourceFormat": "DELTA", "targetFormats": ["ICEBERG"],
        "datasets": [{"tableBasePath": "bkt/t"}],
        "readPlane": {"ttlMs": 250, "maxSnapshots": 8,
                      "statsCacheBytes": 4096}})
    assert cfg.read_plane.ttl_ms == 250.0
    assert cfg.read_plane.max_snapshots == 8
    assert cfg.read_plane.stats_cache_bytes == 4096
    # defaults
    assert _cfg("bkt/t").read_plane == ReadPlaneOptions()


@pytest.mark.parametrize("bad", [{"ttlMs": -1}, {"maxSnapshots": 0},
                                 {"statsCacheBytes": -5}])
def test_read_plane_config_validates(bad):
    with pytest.raises(ValueError):
        ReadPlaneOptions.from_dict(bad)


# ------------------------------------------------- conditional-GET economics
def test_unchanged_read_is_zero_requests_inside_probe_window():
    raw = MemoryFS()
    _mk_table(raw, "bkt/t")
    server, fs, clock = _server(raw, ttl_ms=1000.0)

    first = server.read("bkt/t", "delta")
    assert first.status == OK and len(first.snapshot.files) == 3

    # inside the window: conditional read AND full read are both free
    before = fs.stats().requests
    assert server.read("bkt/t", "delta",
                       if_token=first.token).status == NOT_MODIFIED
    again = server.read("bkt/t", "delta")
    assert again.snapshot is first.snapshot      # memoized, not rebuilt
    assert fs.stats().requests == before         # ZERO storage requests

    # past the window: exactly ONE probe, still no replay/snapshot work
    clock.advance(2.0)
    before = fs.stats().requests
    res = server.read("bkt/t", "delta", if_token=first.token)
    assert res.status == NOT_MODIFIED
    assert fs.stats().requests - before == 1     # the head probe, nothing else


def test_probe_is_shared_across_readers_per_window():
    raw = MemoryFS()
    _mk_table(raw, "bkt/t")
    server, fs, clock = _server(raw, ttl_ms=1000.0)
    tok = server.read("bkt/t", "delta").token

    clock.advance(2.0)                           # expire the window
    before = fs.stats().requests
    done = threading.Barrier(8)

    def reader():
        done.wait()
        for _ in range(5):
            assert server.read("bkt/t", "delta",
                               if_token=tok).status == NOT_MODIFIED

    threads = [threading.Thread(target=reader) for _ in range(8)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    # 40 reads across 8 threads -> ONE probe for the whole window
    assert fs.stats().requests - before == 1
    assert server.stats.not_modified == 40


def test_concurrent_cold_readers_single_flight_one_replay():
    raw = MemoryFS()
    _mk_table(raw, "bkt/t", n_commits=4)
    server, fs, _clock = _server(raw)
    start = threading.Barrier(8)
    snaps = []

    def reader():
        start.wait()
        snaps.append(server.read("bkt/t", "delta").snapshot)

    threads = [threading.Thread(target=reader) for _ in range(8)]
    [t.start() for t in threads]
    [t.join() for t in threads]

    idx = server.cache.index("delta", "bkt/t")
    assert idx.replays == 1                      # exactly one replay, not 8
    assert idx.tail_replays == 0
    assert server.stats.probes == 1
    assert len({s.token for s in snaps}) == 1
    assert all(len(s.files) == 4 for s in snaps)


def test_changed_table_pays_one_shared_tail_refresh():
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/t", n_commits=2)
    server, fs, clock = _server(raw)
    old = server.read("bkt/t", "delta")
    idx = server.cache.index("delta", "bkt/t")
    assert idx.replays == 1

    t.append({"k": np.arange(9000, 9005), "v": np.zeros(5),
              "s": np.array(["x"] * 5)})
    clock.advance(2.0)                           # expire the window
    start = threading.Barrier(8)
    out = []

    def reader():
        start.wait()
        out.append(server.read("bkt/t", "delta", if_token=old.token))

    threads = [threading.Thread(target=reader) for _ in range(8)]
    [t_.start() for t_ in threads]
    [t_.join() for t_ in threads]

    assert all(r.status == OK for r in out)      # everyone got the new head
    assert len({r.token for r in out}) == 1
    assert all(len(r.snapshot.files) == 3 for r in out)
    assert idx.replays == 1                      # no full rebuild...
    assert idx.tail_replays == 1                 # ...ONE shared tail refresh
    assert server.stats.probes == 2              # one per window


def test_snapshot_immutable_while_daemon_commits_mid_read():
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/t", n_commits=2)
    fs = layer_fs(raw)
    clock = ManualClock()
    cache = MetadataCache(fs)
    server = SnapshotServer(fs, cache=cache, clock=clock)
    daemon = SyncDaemon(_cfg("bkt/t"), fs, cache=cache, clock=clock,
                        read_plane=server)
    daemon.run_cycle()

    pinned = server.read("bkt/t", "delta").snapshot
    files_before = dict(pinned.files)
    # the daemon lands two more commits while the reader holds `pinned`
    t.append({"k": np.arange(5), "v": np.zeros(5), "s": np.array(["a"] * 5)})
    t.append({"k": np.arange(5), "v": np.ones(5), "s": np.array(["b"] * 5)})
    clock.advance(2.0)
    daemon.run_cycle()

    fresh = server.read("bkt/t", "delta").snapshot
    assert len(fresh.files) == 4 and fresh.token != pinned.token
    # the pinned snapshot did not move underneath the reader
    assert pinned.files == files_before
    assert len(pinned.files) == 2
    assert pinned.head_commit != fresh.head_commit


def test_snapshot_lru_evicts_at_max_snapshots():
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/t", n_commits=1)
    server, fs, clock = _server(raw, ttl_ms=0.0, max_snapshots=2)
    tokens = [server.read("bkt/t", "delta").token]
    for i in range(3):
        t.append({"k": np.arange(3), "v": np.zeros(3),
                  "s": np.array(["x"] * 3)})
        clock.advance(1.0)
        tokens.append(server.read("bkt/t", "delta").token)
    assert len(set(tokens)) == 4
    assert server.snapshot_count() == 2          # bounded by maxSnapshots
    assert server.stats.evictions == 2
    assert server.stats.snapshot_builds == 4


def test_daemon_publish_makes_co_located_reads_free():
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/t", n_commits=2)
    fs = layer_fs(raw)
    clock = ManualClock()
    cache = MetadataCache(fs)
    server = SnapshotServer(fs, cache=cache, clock=clock)
    daemon = SyncDaemon(_cfg("bkt/t"), fs, cache=cache, clock=clock,
                        read_plane=server)
    daemon.run_cycle()
    assert server.stats.published == 1

    # post-drain reads of the source view: no probe, no replay, nothing
    before = fs.stats().requests
    res = server.read("bkt/t", "delta")
    assert res.status == OK and len(res.snapshot.files) == 2
    assert server.read("bkt/t", "delta",
                       if_token=res.token).status == NOT_MODIFIED
    assert fs.stats().requests == before
    assert server.stats.probes == 0

    # the next cycle's publish refreshes the token — readers see the new
    # head, still without a single probe of their own
    t.append({"k": np.arange(4), "v": np.zeros(4), "s": np.array(["y"] * 4)})
    clock.advance(2.0)
    daemon.run_cycle()
    before = fs.stats().requests
    res2 = server.read("bkt/t", "delta", if_token=res.token)
    assert res2.status == OK and len(res2.snapshot.files) == 3
    assert fs.stats().requests == before
    assert server.stats.probes == 0


# --------------------------------------------------------- stats pushdown
def test_stats_refute_rules():
    st = {"k": ColumnStats(10, 20, 5, 0)}
    assert stats_refute(st, "k", "==", 9) and stats_refute(st, "k", "==", 21)
    assert not stats_refute(st, "k", "==", 10)
    assert stats_refute(st, "k", "<", 10)        # min >= value
    assert not stats_refute(st, "k", "<", 11)
    assert stats_refute(st, "k", "<=", 9)
    assert not stats_refute(st, "k", "<=", 10)
    assert stats_refute(st, "k", ">", 20)        # max <= value
    assert not stats_refute(st, "k", ">", 19)
    assert stats_refute(st, "k", ">=", 21)
    assert not stats_refute(st, "k", ">=", 20)
    # conservative keeps: missing column, None min/max, type mismatch
    assert not stats_refute(st, "missing", "==", 1)
    assert not stats_refute({"k": ColumnStats(None, None, 5, 5)},
                            "k", "==", 1)
    assert not stats_refute(st, "k", "==", "a string")
    assert not stats_refute(st, "k", "!=", 1)    # unknown op


class _BodyCountingFS(MemoryFS):
    """Counts full-object chunk reads (bodies); ranged footer reads pass
    through uncounted — exactly the split the pruning invariant is about.
    (MemoryFS serves ranged reads through ``read_bytes``, so counting is
    suppressed while a ranged call is on the stack.)"""

    def __init__(self):
        super().__init__()
        self.body_reads: list[str] = []
        self._ranged = threading.local()

    def read_bytes(self, path):
        if path.endswith(".chunk") and \
                not getattr(self._ranged, "on", False):
            self.body_reads.append(path)
        return super().read_bytes(path)

    def read_many(self, paths):
        if not getattr(self._ranged, "on", False):
            self.body_reads.extend(p for p in paths
                                   if p.endswith(".chunk"))
        return super().read_many(paths)

    def read_bytes_range(self, path, offset, length):
        self._ranged.on = True
        try:
            return super().read_bytes_range(path, offset, length)
        finally:
            self._ranged.on = False

    def read_many_ranges(self, requests):
        self._ranged.on = True
        try:
            return super().read_many_ranges(requests)
        finally:
            self._ranged.on = False


def _mk_stats_poor_table(fs, base, n_chunks, rows, seed):
    """Chunks with full stats FOOTERS but metadata stripped of column
    stats — the footer pushdown is then the only pruning power (a writer
    or format view that carries no stats in its metadata layer)."""
    t = LakeTable.create(fs, base, SCHEMA, "delta")
    rng = np.random.default_rng(seed)
    metas = []
    for c in range(n_chunks):
        lo = int(rng.integers(0, 500)) * 10
        k = rng.integers(lo, lo + 200, size=rows)
        v = rng.normal(size=rows)
        v[rng.random(rows) < 0.2] = np.nan       # NaN rows in play
        if c == n_chunks - 1:
            v[:] = np.nan                        # one all-NaN chunk
        m = chunkfile.write_chunk(
            fs, base, f"data/part-{c:03d}.chunk",
            {"k": k, "v": v,
             "s": np.array([f"c{c:02d}r{i:03d}" for i in range(rows)])})
        metas.append(chunkfile.DataFileMeta(
            path=m.path, size_bytes=m.size_bytes,
            record_count=m.record_count, column_stats={}))
    t.handle.commit(metas, [])
    return t


def test_pruned_scan_identical_rows_and_never_reads_refuted_bodies():
    """Seeded property sweep: random predicates over random chunk data."""
    rng = np.random.default_rng(7)
    for trial in range(6):
        raw = _BodyCountingFS()
        base = f"bkt/t{trial}"
        _mk_stats_poor_table(raw, base, n_chunks=6, rows=25,
                             seed=100 + trial)
        server, fs, _clock = _server(raw)
        snap = server.read(base, "delta").snapshot
        footers = {
            f.path: chunkfile.read_chunk_stats(raw, base, f.path)[1]
            for f in snap.files.values()}

        unpruned = server.scan_snapshot(snap)    # no predicates: full table
        for _ in range(8):
            col = ("k", "v", "s")[int(rng.integers(0, 3))]
            op = ("==", "<", "<=", ">", ">=")[int(rng.integers(0, 5))]
            if col == "k":
                val = int(rng.integers(0, 5200))
            elif col == "v":
                val = float(rng.normal())
            else:
                val = f"c{int(rng.integers(0, 8)):02d}r010"
            pred = Predicate(col, op, val)

            raw.body_reads.clear()
            res = server.scan_snapshot(snap, (pred,))
            # (1) pruning is invisible in the rows: byte-identical to the
            # unpruned scan filtered row-by-row
            mask = pred.mask(unpruned.rows[col])
            for c in unpruned.rows:
                np.testing.assert_array_equal(res.rows.get(c, np.array([])),
                                              unpruned.rows[c][mask])
            # (2) no refuted chunk body was ever fetched
            for f in snap.files.values():
                if stats_refute(footers[f.path], col, op, val):
                    assert f"{base}/{f.path}" not in raw.body_reads
            # (3) the census adds up
            assert (res.files_scanned + res.files_pruned_stats +
                    res.files_pruned_meta) == res.files_total == 6


def test_all_nan_and_missing_stats_chunks_are_conservatively_kept():
    raw = _BodyCountingFS()
    _mk_stats_poor_table(raw, "bkt/t", n_chunks=3, rows=10, seed=1)
    server, fs, _clock = _server(raw)
    snap = server.read("bkt/t", "delta").snapshot
    # v > 1e12 refutes the two chunks with real v stats; the all-NaN
    # chunk's stats are (None, None) so it MUST be conservatively read —
    # and the row mask then drops everything (NaN never compares true)
    res = server.scan_snapshot(snap, (Predicate("v", ">", 1e12),))
    assert res.files_scanned == 1 and res.files_pruned_stats == 2
    assert all(a.shape[0] == 0 for a in res.rows.values())
    # a predicate on a column with no stats footer entry at all
    res2 = server.scan_snapshot(snap, (Predicate("nope", ">", 0),))
    assert res2.files_scanned == 3               # kept: nothing refutable
    assert res2.rows["k"].shape[0] == 30         # no mask applies


def test_footer_cache_reused_across_scans_and_byte_bounded():
    raw = MemoryFS()
    _mk_stats_poor_table(raw, "bkt/t", n_chunks=5, rows=10, seed=3)
    server, fs, _clock = _server(raw)
    pred = (Predicate("k", ">=", 10_000),)       # refutes everything
    server.scan("bkt/t", "delta", pred)
    assert server.stats_cache.misses == 5
    before = fs.stats().requests
    res = server.scan("bkt/t", "delta", pred)
    assert fs.stats().requests == before         # footers cached, 0 requests
    assert server.stats_cache.hits == 5
    assert res.files_scanned == 0 and res.files_pruned_stats == 5

    # a tiny budget still answers correctly, it just evicts
    tiny = ChunkStatsCache(max_bytes=1)
    paths = [f.path for f in
             server.read("bkt/t", "delta").snapshot.files.values()]
    out = tiny.get_many(raw, "bkt/t", paths)
    assert len(out) == 5 and all(n == 10 for n, _ in out)
    assert tiny.evictions > 0 and len(tiny) == 1


# ------------------------------------------------ restore through a snapshot
def test_checkpoint_restore_through_pinned_snapshot_state(fs):
    import tempfile

    from repro.checkpoint import LSTCheckpointManager
    base = tempfile.mkdtemp() + "/ckpt"
    mgr = LSTCheckpointManager(fs, base, fmt="hudi",
                               sync_targets=("iceberg",))
    tree = {"x": np.arange(12, dtype=np.float32).reshape(3, 4)}
    mgr.save(4, tree)

    server = SnapshotServer(fs)
    snap = server.read(base, "iceberg").snapshot
    step, flat = mgr.restore(fmt="iceberg", state=snap.state)
    assert step == 4
    np.testing.assert_array_equal(flat["x"], tree["x"])
    step2, flat2 = mgr.restore(fmt="iceberg")    # un-pinned reference
    assert step2 == step
    np.testing.assert_array_equal(flat2["x"], flat["x"])


# ------------------------------------------------------- serve engine fix
def test_generate_stops_stepping_after_last_needed_token():
    import jax

    from repro.configs import smoke_config
    from repro.models.model import Model
    from repro.models.param import init_params
    from repro.serve.engine import Request, ServeEngine

    from dataclasses import replace
    cfg = replace(smoke_config("yi-9b"), vocab_size=64)
    model = Model(cfg)
    params = init_params(model.param_template(), jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, cache_len=32)

    steps = {"n": 0}
    inner = eng._step

    def counting(*a, **kw):
        steps["n"] += 1
        return inner(*a, **kw)

    eng._step = counting
    reqs = [Request(prompt=[1, 2, 3], max_new=5),
            Request(prompt=[4, 5], max_new=2)]
    outs = eng.generate(reqs, temperature=0.7, seed=3)
    assert [len(o) for o in outs] == [5, 2]
    # the prefill supplies token 1; steps only run while SOME request
    # still needs a token — the old loop burned one extra trailing step
    assert steps["n"] == 4

    # outputs identical to the pre-fix loop (same RNG split sequence)
    eng2 = ServeEngine(model, params, cache_len=32)
    ref = _reference_generate(eng2, reqs, temperature=0.7, seed=3)
    assert outs == ref


def _reference_generate(eng, requests, *, temperature, seed):
    """The pre-fix decode loop, verbatim (always runs the global max)."""
    import jax
    import jax.numpy as jnp
    b = len(requests)
    max_prompt = max(len(r.prompt) for r in requests)
    max_new = max(r.max_new for r in requests)
    pad = eng.model.cfg.vocab_size - 1
    toks = np.full((b, max_prompt), pad, np.int32)
    for i, r in enumerate(requests):
        toks[i, -len(r.prompt):] = r.prompt
    logits, cache = eng._prefill(eng.params, jnp.asarray(toks))
    key = jax.random.PRNGKey(seed)
    outs = [[] for _ in range(b)]
    pos = jnp.full((b,), max_prompt, jnp.int32)
    tok = eng._sample(logits, temperature, key)
    for step in range(max_new):
        for i in range(b):
            if step < requests[i].max_new:
                outs[i].append(int(tok[i]))
        key, sub = jax.random.split(key)
        logits, cache = eng._step(eng.params, cache, tok, pos)
        tok = eng._sample(logits, temperature, sub)
        pos = pos + 1
    return outs
