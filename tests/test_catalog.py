"""Catalog subsystem: atomic multi-table group-commit publish.

What this file pins:

* ``catalog:`` config parsing (camelCase keys, defaults, validation);
* pointer records roundtrip JSON and never silently substitute views;
* the store's publish is ONE conditional put: racing publishers of the
  same base generation get exactly one winner, the loser a
  ``CatalogConflict`` — and the transaction layer rebases the loser so
  updates to different tables interleave without loss;
* the daemon group-publishes each cycle's drained tables as ONE catalog
  generation, converges on restart without minting generations, and the
  generation cursor rides the checkpoint;
* **binary atomicity**: a crash injected at EVERY request index of a
  3-table group publish leaves ``read_group`` observing either the full
  previous or the full next catalog generation — byte-identical rows,
  never a mix;
* counting-FS census: catalog-pinned group reads cost O(1) requests per
  table beyond the existing read-plane floors (a warm group read is ONE
  request total — the catalog freshness LIST).
"""

import json
import threading

import numpy as np
import pytest

from repro.core import ManualClock, MetadataCache, SyncConfig, SyncDaemon
from repro.core.config import CatalogOptions
from repro.lst import LakeTable
from repro.lst.catalog import (Catalog, CatalogConflict, CatalogStore,
                               TablePointer, UnknownTableError, ViewRef,
                               pointer_from_json, pointer_to_json)
from repro.lst.schema import Field, PartitionSpec, Schema
from repro.lst.storage import (CrashSchedule, MemoryFS, SimulatedCrash,
                               SimulatedObjectStore, StorageProfile, layer_fs)
from repro.serve import SnapshotServer

SCHEMA = Schema([Field("k", "int64"), Field("part", "string")])


def _mk_table(fs, base, fmt="delta", n_commits=3, start=0):
    t = LakeTable.create(fs, base, SCHEMA, fmt, PartitionSpec(["part"]),
                         {"delta.checkpointInterval": "100000"})
    for i in range(start, start + n_commits):
        t.append({"k": np.array([i, i + 100], np.int64),
                  "part": np.array([f"p{i % 2}", "p0"])})
    return t


def _cfg(bases, *, targets=("iceberg",), **catalog_kw):
    cat = {"enabled": True}
    cat.update(catalog_kw)
    return SyncConfig.from_dict({
        "sourceFormat": "DELTA",
        "targetFormats": [t.upper() for t in targets],
        "datasets": [{"tableBasePath": b} for b in bases],
        "catalog": cat,
    })


def _ptr(name, token="tok-1", commit="c-1", **views):
    allv = {"delta": ViewRef(token, commit)}
    allv.update(views)
    return TablePointer(name=name, base_path=f"bkt/{name}",
                        source_format="delta", views=allv)


# ------------------------------------------------------------------- config
def test_catalog_options_defaults_and_camelcase_keys():
    assert SyncConfig.from_dict({
        "sourceFormat": "DELTA", "targetFormats": ["ICEBERG"],
        "datasets": [{"tableBasePath": "bkt/t"}]}).catalog == CatalogOptions()
    opts = CatalogOptions.from_dict({
        "enabled": True, "path": "bkt/cat", "group": "sales",
        "publishViews": "source", "retain": 3})
    assert opts == CatalogOptions(enabled=True, path="bkt/cat",
                                  group="sales", publish_views="source",
                                  retain=3)


@pytest.mark.parametrize("bad", [{"publishViews": "nope"},
                                 {"retain": 0}, {"group": ""}])
def test_catalog_options_validate(bad):
    with pytest.raises(ValueError):
        CatalogOptions.from_dict(bad)


# ----------------------------------------------------------------- pointers
def test_pointer_roundtrips_json_and_orders_formats():
    p = _ptr("orders", iceberg=ViewRef("tok-i", "c-i"),
             hudi=ViewRef("tok-h", "c-h"))
    assert pointer_from_json(json.loads(json.dumps(pointer_to_json(p)))) == p
    assert p.formats[0] == "delta"               # source view leads
    assert p.view().commit == "c-1"              # default = source view
    assert p.view("iceberg").token == "tok-i"


def test_pointer_never_substitutes_a_missing_view():
    p = _ptr("orders")
    with pytest.raises(KeyError, match="hudi"):
        p.view("hudi")
    with pytest.raises(ValueError):              # source view is mandatory
        TablePointer(name="t", base_path="b", source_format="delta",
                     views={"iceberg": ViewRef("t", "c")})


# -------------------------------------------------------------------- store
def test_store_racing_publishers_get_exactly_one_winner():
    fs = MemoryFS()
    a = CatalogStore(fs, "bkt/cat")
    b = CatalogStore(fs, "bkt/cat")
    assert a.publish({"tables": {}}, base_generation=0) == 1
    with pytest.raises(CatalogConflict):
        b.publish({"tables": {}}, base_generation=0)
    assert b.conflicts == 1 and a.head_generation() == 1


def test_store_skips_corrupt_head_and_prunes_old_generations():
    fs = MemoryFS()
    store = CatalogStore(fs, "bkt/cat", retain=2)
    for g in range(4):
        store.publish({"g": g}, base_generation=g)
    assert store.head_generation() == 4
    # retain=2 pruned generations 1 and 2 best-effort
    assert store.load_generation(1) is None
    fs.write_bytes(store._path(5), b"{ torn", overwrite=True)
    gen, manifest = store.load()                 # corrupt head falls back
    assert (gen, manifest["g"]) == (4, 3) and store.load_fallbacks == 1


# ------------------------------------------------------------- transactions
def test_group_commit_is_one_visible_unit():
    fs = MemoryFS()
    cat = Catalog(fs, "bkt/cat")
    before = cat.snapshot()
    assert before.generation == 0 and before.table_names() == []
    with cat.transaction() as txn:
        txn.put(_ptr("orders"))
        txn.put(_ptr("customers"))
        txn.set_group("sales", ["orders", "customers"])
    after = Catalog(fs, "bkt/cat").snapshot()    # a fresh reader
    assert after.generation == 1
    assert after.table_names() == ["customers", "orders"]
    assert after.group("sales") == ("orders", "customers")
    with pytest.raises(UnknownTableError):
        before.resolve("orders")                 # the old snapshot is immutable


def test_drop_leaves_every_group_and_unknowns_raise():
    fs = MemoryFS()
    cat = Catalog(fs, "bkt/cat")
    cat.register_table(_ptr("orders"), group="sales")
    cat.register_table(_ptr("customers"), group="sales")
    with cat.transaction() as txn:
        txn.drop("orders")
    snap = cat.snapshot()
    assert snap.group("sales") == ("customers",)
    with pytest.raises(UnknownTableError):
        snap.resolve("orders")
    with pytest.raises(UnknownTableError):
        snap.group("nope")


def test_losing_transaction_rebases_on_the_winner():
    fs = MemoryFS()
    ours, theirs = Catalog(fs, "bkt/cat"), Catalog(fs, "bkt/cat")
    ours.snapshot()                              # both read base gen 0
    theirs.register_table(_ptr("customers"))     # they win generation 1
    # interleave: our commit's freshness LIST answers from BEFORE the
    # winner's publish, exactly the stale-base window of a real race
    real = ours.store.head_generation
    calls = []
    ours.store.head_generation = \
        lambda: (calls.append(1), 0 if len(calls) == 1 else real())[1]
    snap = ours.register_table(_ptr("orders"))   # conflict, rebase, win 2
    assert snap.generation == 2
    assert snap.table_names() == ["customers", "orders"]
    assert ours.store.conflicts == 1


def test_concurrent_transactions_all_land_without_loss():
    fs = MemoryFS()
    cat = Catalog(fs, "bkt/cat")
    errors = []

    def publish(i):
        try:
            Catalog(fs, "bkt/cat").register_table(_ptr(f"t{i}"))
        except Exception as e:                   # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=publish, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = cat.snapshot()
    assert not errors
    assert snap.table_names() == sorted(f"t{i}" for i in range(8))
    assert snap.generation == 8                  # one generation per winner


def test_empty_transaction_publishes_nothing():
    fs = MemoryFS()
    cat = Catalog(fs, "bkt/cat")
    with cat.transaction():
        pass
    assert cat.store.head_generation() == 0 and cat.store.publishes == 0


# ------------------------------------------------------------------- daemon
def test_daemon_group_publishes_each_cycle_and_converges_on_restart():
    fs = MemoryFS()
    orders = _mk_table(fs, "bkt/orders")
    _mk_table(fs, "bkt/customers")
    cfg = _cfg(["bkt/orders", "bkt/customers"], group="sales")
    d = SyncDaemon(cfg, fs, clock=ManualClock())
    rep = d.run_cycle()
    assert rep.catalog_generation == 1           # BOTH tables in ONE publish
    snap = d.catalog.snapshot()
    assert snap.group("sales") == ("orders", "customers")
    for name in ("orders", "customers"):
        ptr = snap.resolve(name)
        assert ptr.formats == ("delta", "iceberg")

    assert d.run_cycle().catalog_generation is None     # idle: no publish
    orders.append({"k": np.array([999], np.int64), "part": np.array(["p0"])})
    assert d.run_cycle().catalog_generation == 2

    # a restarted daemon re-resolves everything, finds identical pointers
    # and converges WITHOUT minting a generation per boot
    d2 = SyncDaemon(cfg, fs, clock=ManualClock())
    assert d2.run_cycle().catalog_generation == 2
    assert d2.catalog.store.publishes == 0
    assert d2.catalog.store.head_generation() == 2


def test_daemon_publish_views_source_skips_target_views():
    fs = MemoryFS()
    _mk_table(fs, "bkt/orders")
    cfg = _cfg(["bkt/orders"], publishViews="source")
    d = SyncDaemon(cfg, fs, clock=ManualClock())
    d.run_cycle()
    ptr = d.catalog.resolve("orders")
    assert ptr.formats == ("delta",)


def test_catalog_generation_rides_the_checkpoint():
    fs = MemoryFS()
    _mk_table(fs, "bkt/orders")
    cfg = SyncConfig.from_dict({
        "sourceFormat": "DELTA", "targetFormats": ["ICEBERG"],
        "datasets": [{"tableBasePath": "bkt/orders"}],
        "catalog": {"enabled": True},
        "checkpoint": {"enabled": True},
    })
    d = SyncDaemon(cfg, fs, clock=ManualClock())
    rep = d.run_cycle()
    assert rep.checkpoint_gen is not None and rep.catalog_generation == 1
    _gen, payload = d._ckpt.load()
    assert payload["catalog"]["generation"] == 1
    d2 = SyncDaemon(cfg, fs, clock=ManualClock())
    assert d2.restored_from_checkpoint
    assert d2.catalog.store._gen_hint == 1       # advisory cursor seeded


def test_backed_off_table_keeps_its_last_published_pointer():
    """A table mid-backoff must not block the healthy table's group — and
    until it drains cleanly again the catalog keeps serving its LAST
    cleanly published pointer, never a half-synced head."""
    fs = MemoryFS()
    orders = _mk_table(fs, "bkt/orders")
    customers = _mk_table(fs, "bkt/customers")
    cfg = _cfg(["bkt/orders", "bkt/customers"], group="sales")
    clock = ManualClock()
    d = SyncDaemon(cfg, fs, clock=clock)
    assert d.run_cycle().catalog_generation == 1
    old_ref = d.catalog.resolve("customers").view()

    orders.append({"k": np.array([7], np.int64), "part": np.array(["p0"])})
    customers.append({"k": np.array([8], np.int64), "part": np.array(["p0"])})
    # customers enters a backoff window (as a failed probe/drain would
    # leave it): skipped this cycle, excluded from this cycle's group
    d._watch["bkt/customers"].not_before = clock.now() + 100.0
    rep2 = d.run_cycle()
    assert rep2.backed_off == 1 and rep2.catalog_generation == 2
    snap = d.catalog.snapshot()
    assert snap.group("sales") == ("orders", "customers")   # still grouped
    assert snap.resolve("customers").view() == old_ref      # old pointer
    assert snap.resolve("orders").view().token != old_ref.token

    clock.advance(200.0)          # window passes: customers drains and
    rep3 = d.run_cycle()          # joins a LATER group generation
    assert rep3.catalog_generation == 3
    assert d.catalog.resolve("customers").view() != old_ref


# -------------------------------------------------- read plane: group reads
def _serving_stack(bases, **catalog_kw):
    raw = MemoryFS()
    tables = [_mk_table(raw, b) for b in bases]
    fs = layer_fs(raw)
    cfg = _cfg(bases, group="sales", **catalog_kw)
    clock = ManualClock()
    d = SyncDaemon(cfg, fs, clock=clock)
    server = SnapshotServer(fs, cache=d.cache, clock=clock)
    d.read_plane = server
    assert d.run_cycle().catalog_generation == 1
    return raw, fs, cfg, d, server, tables


def test_read_group_pins_every_member_at_one_generation():
    _raw, _fs, _cfg_, d, server, (orders, _customers) = \
        _serving_stack(["bkt/orders", "bkt/customers"])
    g1 = server.read_group(d.catalog, group="sales")
    assert g1.generation == 1 and len(g1) == 2
    rows1 = sorted(server.scan_snapshot(g1["orders"]).rows["k"].tolist())

    orders.append({"k": np.array([999], np.int64), "part": np.array(["p0"])})
    assert d.run_cycle().catalog_generation == 2
    g2 = server.read_group(d.catalog, group="sales")
    assert g2.generation == 2
    assert 999 in server.scan_snapshot(g2["orders"]).rows["k"].tolist()
    # the held group snapshot stays pinned at its OWN generation's rows
    again = sorted(server.scan_snapshot(g1["orders"]).rows["k"].tolist())
    assert again == rows1 and 999 not in again


def test_read_group_by_view_format_and_unknowns():
    _raw, _fs, _cfg_, d, server, _tables = \
        _serving_stack(["bkt/orders", "bkt/customers"])
    gi = server.read_group(d.catalog, group="sales", fmt="iceberg")
    assert all(s.view_format == "iceberg" for s in gi.snapshots.values())
    # the iceberg view serves the same rows as the source view
    gd = server.read_group(d.catalog, tables=["orders"])
    assert sorted(server.scan_snapshot(gi["orders"]).rows["k"].tolist()) == \
        sorted(server.scan_snapshot(gd["orders"]).rows["k"].tolist())
    with pytest.raises(UnknownTableError):
        server.read_group(d.catalog, tables=["nope"])
    with pytest.raises(KeyError):
        _serving_stack(["bkt/solo"], publishViews="source")[4].read_group(
            SyncDaemon(_cfg(["bkt/solo"]), _fs).catalog, fmt="hudi")


def test_census_warm_group_read_is_one_request_total():
    """The O(1) pin: beyond the read plane's existing floors, a warm
    catalog-pinned group read costs exactly ONE storage request — the
    catalog freshness LIST — and zero per table."""
    _raw, fs, _cfg_, d, server, _tables = \
        _serving_stack(["bkt/orders", "bkt/customers", "bkt/parts"])
    server.read_group(d.catalog, group="sales")      # prime the memo
    for _ in range(3):
        before = fs.stats().requests
        g = server.read_group(d.catalog, group="sales")
        assert fs.stats().requests - before == 1     # catalog LIST only
        assert len(g) == 3
    # a COLD reader process: catalog resolution (LIST + GET) plus the
    # normal one-replay-per-table floor, amortized across later reads
    cold_cache = MetadataCache(fs)
    cold_server = SnapshotServer(fs, cache=cold_cache)
    cold_catalog = Catalog(fs, d.catalog.store.base_path)
    cold_server.read_group(cold_catalog, group="sales")
    before = fs.stats().requests
    cold_server.read_group(cold_catalog, group="sales")
    assert fs.stats().requests - before == 1


# ------------------------------------------- chaos: binary group atomicity
def _group_digest(fs, catalog_path, bases):
    """(generation, rows-per-table) as one pinned read through a COLD
    reader stack — what any external reader would observe."""
    server = SnapshotServer(fs, cache=MetadataCache(fs))
    group = server.read_group(Catalog(fs, catalog_path))
    rows = {}
    for name in group.table_names():
        got = server.scan_snapshot(group[name]).rows
        rows[name] = sorted(zip(got["k"].tolist(), got["part"].tolist()))
    return group.generation, rows


def _publish_campaign_base():
    """Pre-crash store: 3 tables synced + group-published at generation 1,
    then fresh commits land on ALL of them while the daemon is down."""
    bases = ["bkt/orders", "bkt/customers", "bkt/parts"]
    raw = MemoryFS()
    tables = [_mk_table(raw, b, n_commits=2) for b in bases]
    cfg = _cfg(bases, group="sales")
    d = SyncDaemon(cfg, layer_fs(raw), clock=ManualClock())
    assert d.run_cycle().catalog_generation == 1
    for i, t in enumerate(tables):
        t.append({"k": np.array([50 + i], np.int64),
                  "part": np.array(["p1"])})
    catalog_path = d.catalog.store.base_path
    return raw, cfg, bases, catalog_path


def _crash_sweep(*, after_apply):
    base, cfg, bases, catalog_path = _publish_campaign_base()
    serial = StorageProfile(pipeline_depth=1)

    # golden arm: the same cycle, no crash -> the full next generation
    golden = SimulatedObjectStore(base.clone(), serial)
    d = SyncDaemon(cfg, layer_fs(golden), clock=ManualClock())
    assert d.run_cycle().catalog_generation == 2
    prev_digest = _group_digest(base, catalog_path, bases)
    next_digest = _group_digest(golden.inner, catalog_path, bases)
    assert prev_digest[0] == 1 and next_digest[0] == 2
    assert prev_digest[1] != next_digest[1]
    total = golden.requests
    assert total > 30            # the sweep covers a real drain + publish

    mixed_seen = 0
    for n in range(1, total + 1):
        sim = SimulatedObjectStore(base.clone(), serial)
        sim.arm_crash(CrashSchedule(n, after_apply=after_apply))
        daemon = SyncDaemon(cfg, layer_fs(sim), clock=ManualClock())
        with pytest.raises(SimulatedCrash):
            daemon.run_cycle()
        assert sim.crashed, f"crash at request {n} never fired"
        sim.arm_crash(None)
        got = _group_digest(sim.inner, catalog_path, bases)
        if got == prev_digest:
            continue
        if got == next_digest:
            mixed_seen += 1      # fine: the publish PUT landed before n
            continue
        raise AssertionError(
            f"crash at request {n} left a MIXED catalog view: "
            f"generation {got[0]}")
    # the torn-write arm must actually exercise the published-next case
    if after_apply:
        assert mixed_seen >= 1
    return total


def test_crash_at_every_request_index_leaves_binary_catalog_view():
    """The acceptance gate: a crash at EVERY request index of a 3-table
    group publish leaves ``read_group`` observing either the full
    previous or the full next catalog generation — byte-identical rows,
    never a mix."""
    _crash_sweep(after_apply=False)


@pytest.mark.slow
def test_crash_torn_publish_put_leaves_full_next_generation():
    _crash_sweep(after_apply=True)
