"""Property-based tests of XTable's system invariants (hypothesis).

For arbitrary generated commit sequences applied to a source table in any
format:

  * omni-directional equivalence — translating to any target yields the
    identical logical table state (files, rows, schema, statistics);
  * incremental == full — commit-by-commit incremental sync ends in the
    same target state as a single full-snapshot sync;
  * metadata-only — translation never rewrites or copies a data file;
  * idempotence + crash recovery — re-running a sync (or resuming after a
    partial multi-target failure) converges without corruption.
"""

import tempfile

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import SyncConfig, run_sync
from repro.lst import LakeTable, LocalFS
from repro.lst.fs import join
from repro.lst.schema import Field, PartitionSpec, Schema
from repro.lst.table import Predicate

FORMATS = ("delta", "iceberg", "hudi")
SCHEMA = Schema([Field("k", "int64"), Field("part", "string")])

# one hypothesis "op" = (kind, payload)
_op = st.one_of(
    st.tuples(st.just("append"),
              st.lists(st.integers(0, 99), min_size=1, max_size=5)),
    st.tuples(st.just("delete"), st.integers(0, 99)),
    st.tuples(st.just("evolve"), st.sampled_from(["c1", "c2", "c3"])),
)


def _apply_ops(table: LakeTable, ops, offset=0):
    added_fields = set(table.state().schema.names())
    for i, (kind, payload) in enumerate(ops):
        if kind == "append":
            vals = np.array(payload, np.int64) + offset
            table.append({"k": vals,
                          "part": np.array([f"p{v % 2}" for v in payload])})
        elif kind == "delete":
            table.delete_where(Predicate("k", "==", payload + offset))
        elif kind == "evolve":
            if payload not in added_fields:
                added_fields.add(payload)
                table.evolve_schema(
                    table.state().schema.add_field(Field(payload, "float64")))


def _logical_state(table: LakeTable):
    st_ = table.state()
    rows = table.read_all()
    return {
        "rows": sorted(rows.get("k", np.array([], np.int64)).tolist()),
        "schema": [(f.name, f.type, f.nullable) for f in st_.schema.fields],
        "files": sorted(st_.files),
        "stats": {p: f.stats_dict() for p, f in sorted(st_.files.items())},
    }


@settings(max_examples=15, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(src=st.sampled_from(FORMATS), ops=st.lists(_op, min_size=1, max_size=6))
def test_omni_directional_equivalence(src, ops):
    fs = LocalFS()
    base = tempfile.mkdtemp() + "/t"
    t = LakeTable.create(fs, base, SCHEMA, src, PartitionSpec(["part"]))
    _apply_ops(t, ops)
    targets = [f for f in FORMATS if f != src]
    cfg = SyncConfig.from_dict({
        "sourceFormat": src.upper(),
        "targetFormats": [x.upper() for x in targets],
        "datasets": [{"tableBasePath": base}]})
    res = run_sync(cfg, fs)
    assert all(r.ok for r in res), res
    want = _logical_state(t)
    for tf in targets:
        got = _logical_state(LakeTable.open(fs, base, tf))
        assert got == want, (src, tf)


@settings(max_examples=10, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(src=st.sampled_from(FORMATS),
       ops1=st.lists(_op, min_size=1, max_size=4),
       ops2=st.lists(_op, min_size=1, max_size=4))
def test_incremental_equals_full(src, ops1, ops2):
    fs = LocalFS()
    base_i = tempfile.mkdtemp() + "/ti"      # incremental: sync, write, sync
    base_f = tempfile.mkdtemp() + "/tf"      # full: all writes, then one sync
    tgt = [f for f in FORMATS if f != src][0]
    cfg_i = SyncConfig.from_dict({"sourceFormat": src.upper(),
                                  "targetFormats": [tgt.upper()],
                                  "datasets": [{"tableBasePath": base_i}]})
    cfg_f = SyncConfig.from_dict({"sourceFormat": src.upper(),
                                  "targetFormats": [tgt.upper()],
                                  "datasets": [{"tableBasePath": base_f}]})
    ti = LakeTable.create(fs, base_i, SCHEMA, src, PartitionSpec(["part"]))
    tf_ = LakeTable.create(fs, base_f, SCHEMA, src, PartitionSpec(["part"]))
    _apply_ops(ti, ops1)
    run_sync(cfg_i, fs)                      # first sync (FULL bootstrap)
    _apply_ops(ti, ops2, offset=1000)
    res = run_sync(cfg_i, fs)   # second sync: INCREMENTAL (or SKIP if ops2
    #                             produced no commits, e.g. no-match deletes)
    assert all(r.mode in ("INCREMENTAL", "SKIP")
               for r in res if r.target_format == tgt)
    _apply_ops(tf_, ops1)
    _apply_ops(tf_, ops2, offset=1000)
    run_sync(cfg_f, fs)
    got_i = _logical_state(LakeTable.open(fs, base_i, tgt))
    got_f = _logical_state(LakeTable.open(fs, base_f, tgt))
    # drop file-path comparison: COW rewrites may differ file-wise between
    # orderings; logical rows/schema/stats totals must match
    assert got_i["rows"] == got_f["rows"]
    assert got_i["schema"] == got_f["schema"]


@settings(max_examples=10, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(src=st.sampled_from(FORMATS), ops=st.lists(_op, min_size=1, max_size=5))
def test_translation_never_touches_data_files(src, ops):
    fs = LocalFS()
    base = tempfile.mkdtemp() + "/t"
    t = LakeTable.create(fs, base, SCHEMA, src, PartitionSpec(["part"]))
    _apply_ops(t, ops)
    before = {}
    for rel in t.state().files:
        before[rel] = fs.read_bytes(join(base, rel))
    targets = [f for f in FORMATS if f != src]
    run_sync(SyncConfig.from_dict({
        "sourceFormat": src.upper(),
        "targetFormats": [x.upper() for x in targets],
        "datasets": [{"tableBasePath": base}]}), fs)
    for rel, data in before.items():
        assert fs.read_bytes(join(base, rel)) == data   # byte-identical
    # and targets reference the SAME paths — no duplication
    for tf in targets:
        assert set(LakeTable.open(fs, base, tf).state().files) == set(before)


def test_sync_idempotent_and_skip(fs):
    base = tempfile.mkdtemp() + "/t"
    t = LakeTable.create(fs, base, SCHEMA, "hudi", PartitionSpec(["part"]))
    t.append({"k": np.arange(4, dtype=np.int64),
              "part": np.array(["p0", "p1", "p0", "p1"])})
    cfg = SyncConfig.from_dict({"sourceFormat": "HUDI",
                                "targetFormats": ["DELTA", "ICEBERG"],
                                "datasets": [{"tableBasePath": base}]})
    run_sync(cfg, fs)
    r2 = run_sync(cfg, fs)
    assert all(r.mode == "SKIP" for r in r2), r2
    d = LakeTable.open(fs, base, "delta")
    assert sorted(d.read_all()["k"].tolist()) == [0, 1, 2, 3]


def test_crash_between_targets_recovers(fs, monkeypatch):
    """First target succeeds, second 'crashes'; rerun converges both."""
    import repro.core.sync as sync_mod
    base = tempfile.mkdtemp() + "/t"
    t = LakeTable.create(fs, base, SCHEMA, "delta", PartitionSpec(["part"]))
    t.append({"k": np.arange(3, dtype=np.int64),
              "part": np.array(["p0", "p1", "p0"])})
    cfg = SyncConfig.from_dict({"sourceFormat": "DELTA",
                                "targetFormats": ["ICEBERG", "HUDI"],
                                "datasets": [{"tableBasePath": base}]})
    from repro.core.targets import HudiTarget
    orig = HudiTarget.full_sync
    calls = {"n": 0}

    def boom(self, snapshot):
        calls["n"] += 1
        raise RuntimeError("simulated crash")

    monkeypatch.setattr(HudiTarget, "full_sync", boom)
    res = run_sync(cfg, fs)
    assert res[0].ok and not res[1].ok        # iceberg ok, hudi crashed
    monkeypatch.setattr(HudiTarget, "full_sync", orig)
    res2 = run_sync(cfg, fs)
    by_fmt = {r.target_format: r for r in res2}
    assert by_fmt["iceberg"].mode == "SKIP"   # already current
    assert by_fmt["hudi"].ok
    assert sorted(LakeTable.open(fs, base, "hudi").read_all()["k"].tolist()) \
        == [0, 1, 2]


def test_full_sync_fallback_when_history_cleaned(fs):
    """Delta log truncation behind a checkpoint: the target's sync token
    disappears from the source history (while the snapshot stays valid via
    the _delta_log checkpoint) -> XTable falls back to FULL and converges."""
    base = tempfile.mkdtemp() + "/t"
    t = LakeTable.create(fs, base, SCHEMA, "delta", PartitionSpec(["part"]))
    for i in range(10):                      # v1..v10; checkpoint at v10
        t.append({"k": np.array([i], np.int64),
                  "part": np.array([f"p{i % 2}"])})
    cfg = SyncConfig.from_dict({"sourceFormat": "DELTA",
                                "targetFormats": ["HUDI"],
                                "datasets": [{"tableBasePath": base}]})
    run_sync(cfg, fs)                        # token = "10"
    t.append({"k": np.array([100], np.int64), "part": np.array(["p0"])})
    # vacuum the log: drop every commit file <= v10 (checkpoint covers them)
    for v in range(0, 11):
        fs.delete(join(base, "_delta_log", f"{v:020d}.json"))
    res = run_sync(cfg, fs)
    assert res[0].mode == "FULL", res
    want = sorted(t.read_all()["k"].tolist())
    got = sorted(LakeTable.open(fs, base, "hudi").read_all()["k"].tolist())
    assert got == want == sorted(list(range(10)) + [100])


def test_manifest_compaction_bounds_snapshot_reads():
    """A 64-commit incremental chain with ``manifestCompactionThreshold: 8``
    keeps a cold snapshot read FLAT (bounded by the threshold, not the chain
    length — without compaction it reads one manifest per commit), and the
    compacted target's end state is identical to the uncompacted drain's."""
    from repro.lst import MemoryFS

    def grow_and_drain(threshold, commits):
        raw = MemoryFS()
        base = "bkt/t"
        t = LakeTable.create(raw, base, SCHEMA, "delta",
                             PartitionSpec(["part"]),
                             {"delta.checkpointInterval": "100000"})
        t.append({"k": np.array([1], np.int64), "part": np.array(["p0"])})
        d = {"sourceFormat": "DELTA", "targetFormats": ["ICEBERG"],
             "datasets": [{"tableBasePath": base}]}
        if threshold:
            d["manifestCompactionThreshold"] = threshold
        cfg = SyncConfig.from_dict(d)
        assert run_sync(cfg, raw)[0].mode == "FULL"
        # the incremental chain, drained in rounds like a daemon would
        for r in range(8):
            for i in range(commits // 8):
                t.append({"k": np.array([100 * r + i], np.int64),
                          "part": np.array(["p1"])})
            res = run_sync(cfg, raw)
            assert res[0].ok and res[0].mode == "INCREMENTAL"
        return raw, base, t

    def snapshot_reads(raw, base):
        from repro.lst.storage import layer_fs
        fs = layer_fs(raw)
        st = LakeTable.open(fs, base, "iceberg").state()
        return fs.stats().get, st

    raw32, base, _ = grow_and_drain(8, 32)
    raw64, _, t64 = grow_and_drain(8, 64)
    reads32, _ = snapshot_reads(raw32, base)
    reads64, st64 = snapshot_reads(raw64, base)
    # flat in chain length, and bounded by the threshold (+ metadata JSON,
    # hint, manifest list), instead of one read per chain commit
    assert reads64 == reads32, (reads32, reads64)
    assert reads64 <= 8 + 4, reads64

    raw_plain, _, t_plain = grow_and_drain(None, 64)
    reads_plain, st_plain = snapshot_reads(raw_plain, base)
    # the uncompacted arm really does pay O(chain) manifest reads
    assert reads_plain > 64, reads_plain

    # end states equivalent: each target mirrors ITS source exactly (file
    # names embed per-run uuids, so arms compare against their own source),
    # and the two arms agree on the logical rows
    assert set(st64.files) == set(t64.state().files)
    assert set(st_plain.files) == set(t_plain.state().files)
    got64 = sorted(LakeTable.open(raw64, base, "iceberg")
                   .read_all()["k"].tolist())
    got_plain = sorted(LakeTable.open(raw_plain, base, "iceberg")
                       .read_all()["k"].tolist())
    assert got64 == got_plain == sorted(t64.read_all()["k"].tolist())
    # stats carried through the fold (compared against the source metadata)
    src_files = t64.state().files
    for p, f in st64.files.items():
        assert f.stats_dict() == src_files[p].stats_dict(), p


def test_listing2_config_parsing():
    cfg = SyncConfig.from_yaml("""
sourceFormat: HUDI
targetFormats:
  - DELTA
  - ICEBERG
datasets:
  -
    tableBasePath: abfs://container@ac.dfs.core.windows.net/sales
""")
    assert cfg.source_format == "hudi"
    assert cfg.target_formats == ("delta", "iceberg")
    assert cfg.datasets[0].name == "sales"
    with pytest.raises(ValueError):
        SyncConfig.from_dict({"sourceFormat": "HUDI",
                              "targetFormats": ["HUDI"], "datasets": []})
