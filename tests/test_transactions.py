"""Transactional target writers, commit coalescing, chunkfile footer.

The headline guarantees this file pins:

* draining an incremental backlog costs O(1) target-side metadata READS —
  both in the length of the target's own history (flat as the table grows
  8 -> 64 commits) and in the length of the backlog (the transaction parses
  the target state once and threads it through the drain, so commit k never
  re-reads what commit k-1 just wrote);
* ``coalesceIncremental`` folds an N-commit backlog into ONE net target
  commit with an end state identical to the per-commit drain (files, stats,
  schema, sync token), keeping per-commit lineage in the commit metadata;
* ``maxCommitsPerSync`` bounds a drain and the next run continues from the
  recorded token;
* ``read_chunk_stats`` range-reads the stats footer and never fetches the
  column data; Hudi ``extraMetadata`` values round-trip through one codec.
"""

import json
import tempfile

import numpy as np
import pytest

from repro.core import MetadataCache, SyncConfig, run_sync
from repro.core.targets import LINEAGE_KEY, TOKEN_KEY
from repro.lst import LakeTable, LocalFS, chunkfile
from repro.lst.fs import join
from repro.lst.schema import Field, PartitionSpec, Schema
from repro.lst.table import Predicate

SCHEMA = Schema([Field("k", "int64"), Field("part", "string")])
ALL = ("delta", "iceberg", "hudi")
META_DIR = {"delta": "_delta_log", "iceberg": "metadata", "hudi": ".hoodie"}


class CountingFS(LocalFS):
    """LocalFS counting read_bytes / read_bytes_range / write_bytes calls."""

    def __init__(self):
        super().__init__()
        self.reads = {}
        self.range_reads = {}
        self.writes = {}

    def read_bytes(self, path):
        self.reads[path] = self.reads.get(path, 0) + 1
        return super().read_bytes(path)

    def read_bytes_range(self, path, offset, length):
        self.range_reads[path] = self.range_reads.get(path, 0) + 1
        return super().read_bytes_range(path, offset, length)

    def write_bytes(self, path, data, *, overwrite=False):
        self.writes[path] = self.writes.get(path, 0) + 1
        return super().write_bytes(path, data, overwrite=overwrite)

    def reset(self):
        self.reads, self.range_reads, self.writes = {}, {}, {}

    def reads_under(self, base, subdir):
        d = join(base, subdir)
        return sum(n for p, n in self.reads.items() if p.startswith(d))

    def writes_under(self, base, subdir):
        d = join(base, subdir)
        return sum(n for p, n in self.writes.items() if p.startswith(d))


def _mk_table(fs, fmt, n_commits, properties=None):
    base = tempfile.mkdtemp() + "/t"
    t = LakeTable.create(fs, base, SCHEMA, fmt, PartitionSpec(["part"]),
                         properties)
    for i in range(n_commits):
        t.append({"k": np.array([i, i + 100], np.int64),
                  "part": np.array([f"p{i % 2}", "p0"])})
    return base, t


def _cfg(bases, src, targets, **kw):
    d = {"sourceFormat": src.upper(),
         "targetFormats": [t.upper() for t in targets],
         "datasets": [{"tableBasePath": b} for b in bases]}
    d.update(kw)
    return SyncConfig.from_dict(d)


# --------------------------------------------------- O(1) in table history
@pytest.mark.parametrize("src,tgt", [("delta", "iceberg"), ("delta", "hudi"),
                                     ("hudi", "delta")])
def test_target_reads_flat_in_history(src, tgt):
    """Reads of the target's metadata during a fixed-size incremental drain
    do not grow with the target's history length (8 vs 64 prior commits)."""

    def drain_reads(history):
        fs = CountingFS()
        # a huge checkpoint interval keeps the delta-target measurement free
        # of (bounded, but noisy) checkpoint-maintenance reads
        base, t = _mk_table(fs, src, 1,
                            properties={"delta.checkpointInterval": "1000"})
        cfg = _cfg([base], src, [tgt])
        cache = MetadataCache(fs)
        run_sync(cfg, fs, cache=cache)                   # FULL bootstrap
        for i in range(history):                         # grow BOTH histories
            t.append({"k": np.array([1000 + i], np.int64),
                      "part": np.array(["p0"])})
            res = run_sync(cfg, fs, cache=cache)
            assert res[0].ok and res[0].mode == "INCREMENTAL"
        for i in range(4):                               # the measured backlog
            t.append({"k": np.array([5000 + i], np.int64),
                      "part": np.array(["p1"])})
        fs.reset()
        res = run_sync(cfg, fs, cache=cache)
        assert res[0].ok and res[0].commits_synced == 4
        return fs.reads_under(base, META_DIR[tgt])

    r8, r64 = drain_reads(8), drain_reads(64)
    assert r64 == r8, f"target reads grew with history: {r8} -> {r64}"


def test_target_reads_flat_in_backlog_length():
    """Reads of the target's metadata are also independent of how MANY
    commits the unit drains — the per-commit flushes never re-read."""

    def drain_reads(backlog):
        fs = CountingFS()
        base, t = _mk_table(fs, "delta", 4)
        cfg = _cfg([base], "delta", ["iceberg", "hudi"])
        run_sync(cfg, fs)
        for i in range(backlog):
            t.append({"k": np.array([100 + i], np.int64),
                      "part": np.array(["p1"])})
        fs.reset()
        res = run_sync(cfg, fs)
        assert all(r.ok and r.commits_synced == backlog for r in res)
        return (fs.reads_under(base, "metadata"),
                fs.reads_under(base, ".hoodie"))

    assert drain_reads(16) == drain_reads(4)


def test_per_commit_path_rereads_and_transaction_does_not():
    """The seed per-commit path re-reads target state every commit; the
    transactional path reads it once — the mechanism behind the speedup."""
    reads = {}
    for label, txn in (("per-commit", False), ("transactional", True)):
        fs = CountingFS()
        base, t = _mk_table(fs, "delta", 4)
        cfg = _cfg([base], "delta", ["iceberg"], transactionalTargets=txn)
        run_sync(cfg, fs)
        for i in range(8):
            t.append({"k": np.array([100 + i], np.int64),
                      "part": np.array(["p1"])})
        fs.reset()
        res = run_sync(cfg, fs)
        assert res[0].ok and res[0].commits_synced == 8
        reads[label] = fs.reads_under(base, "metadata")
    assert reads["transactional"] < reads["per-commit"] / 2, reads


# ------------------------------------------------ coalescing / equivalence
def _scenario(fs, src):
    """Deterministic source: 3 base commits + a backlog containing appends,
    a delete, and a schema evolution (then a write in the new schema)."""
    base, t = _mk_table(fs, src, 3)
    return base, t


def _backlog(t):
    new = []
    new.append(t.append({"k": np.array([50, 51], np.int64),
                         "part": np.array(["p0", "p1"])}))
    new.append(t.delete_where(Predicate("k", "==", 1)))
    new.append(t.evolve_schema(SCHEMA.add_field(Field("extra", "float64"))))
    new.append(t.append({"k": np.array([60], np.int64),
                         "part": np.array(["p1"]),
                         "extra": np.array([2.5])}))
    return new


@pytest.mark.parametrize("src", ALL)
def test_coalesced_drain_matches_per_commit_end_state(src):
    """FULL bootstrap + (appends, delete, schema evolution) backlog, drained
    three ways — per-commit, transactional, coalesced — all land every
    target on the source's exact logical state."""
    targets = [f for f in ALL if f != src]
    states = {}
    for label, kw in (("per-commit", {"transactionalTargets": False}),
                      ("transactional", {}),
                      ("coalesced", {"coalesceIncremental": True})):
        fs = LocalFS()
        base, t = _scenario(fs, src)
        cfg = _cfg([base], src, targets, **kw)
        run_sync(cfg, fs)
        new = _backlog(t)
        res = run_sync(cfg, fs)
        assert all(r.ok and r.mode == "INCREMENTAL" for r in res), (label, res)
        assert all(r.commits_synced == len(new) for r in res)
        if label == "coalesced":
            assert all(r.target_commits == 1 for r in res)
        else:
            assert all(r.target_commits == len(new) for r in res)
        want_rows = sorted(t.read_all()["k"].tolist())
        want_schema = [(f.name, f.type) for f in t.state().schema.fields]
        src_state = t.state()
        for tf in targets:
            tt = LakeTable.open(fs, base, tf)
            st = tt.state()
            assert sorted(tt.read_all()["k"].tolist()) == want_rows, (label, tf)
            assert [(f.name, f.type) for f in st.schema.fields] == \
                want_schema, (label, tf)
            assert set(st.files) == set(src_state.files), (label, tf)
            for p, f in st.files.items():   # stats carried through the fold
                assert f.record_count == src_state.files[p].record_count
                assert {k: (v.min, v.max) for k, v in f.column_stats.items()} \
                    == {k: (v.min, v.max) for k, v in
                        src_state.files[p].column_stats.items()}, (label, tf, p)
        # idempotence: all targets report the source head as their token
        res2 = run_sync(_cfg([base], src, targets), fs)
        assert all(r.mode == "SKIP" for r in res2), (label, res2)
        states[label] = want_rows
    assert states["per-commit"] == states["transactional"] == \
        states["coalesced"]


def test_coalesced_commit_preserves_lineage():
    fs = LocalFS()
    base, t = _scenario(fs, "delta")
    cfg = _cfg([base], "delta", ["iceberg", "hudi"], coalesceIncremental=True)
    run_sync(cfg, fs)
    new = _backlog(t)
    res = run_sync(cfg, fs)
    assert all(r.ok and r.target_commits == 1 for r in res)
    # hudi: lineage in the completed instant's extraMetadata
    ht = LakeTable.open(fs, base, "hudi").handle
    _, _, _, info = ht.changes(ht.current_version())
    assert json.loads(info[LINEAGE_KEY]) == new
    assert info[TOKEN_KEY] == new[-1]
    # iceberg: lineage in the snapshot summary
    it = LakeTable.open(fs, base, "iceberg").handle
    _, _, _, summary = it.changes(it.current_version())
    assert json.loads(summary[f"xtable.{LINEAGE_KEY}"]) == new


def test_max_commits_per_sync_caps_and_resumes():
    fs = LocalFS()
    base, t = _mk_table(fs, "delta", 2)
    run_sync(_cfg([base], "delta", ["hudi"]), fs)
    new = [t.append({"k": np.array([70 + i], np.int64),
                     "part": np.array(["p0"])}) for i in range(5)]
    res = run_sync(_cfg([base], "delta", ["hudi"], maxCommitsPerSync=2), fs)
    assert res[0].commits_synced == 2
    assert res[0].source_commit == new[1]     # stopped at the cap
    res = run_sync(_cfg([base], "delta", ["hudi"]), fs)
    assert res[0].commits_synced == 3         # continued from the token
    got = sorted(LakeTable.open(fs, base, "hudi").read_all()["k"].tolist())
    assert got == sorted(t.read_all()["k"].tolist())


# ------------------------------------------------- handle-level transactions
@pytest.mark.parametrize("fmt", ALL)
def test_transaction_matches_handle_commits(fmt, fs):
    """N commits through a transaction == N commits through the handle."""
    base_a, ta = _mk_table(fs, fmt, 0)
    base_b, tb = _mk_table(fs, fmt, 0)
    txn = ta.handle.transaction()
    for i in range(4):
        add = chunkfile.DataFileMeta(path=f"data/f{i}.chunk", size_bytes=10,
                                     record_count=1)
        txn.commit([add], [], properties={"step": str(i)})
        tb.handle.commit([add], [], properties={"step": str(i)})
    txn.close()
    sa, sb = ta.handle.snapshot(), tb.handle.snapshot()
    assert set(sa.files) == set(sb.files)
    assert sa.properties.get("step") == sb.properties.get("step") == "3"
    assert len(ta.handle.versions()) == len(tb.handle.versions())


def test_delta_transaction_writes_checkpoint_at_boundary(fs):
    """A long transactional drain still maintains delta checkpoints: the
    file list is materialized once at the boundary (bounded by the
    interval), then tracked in memory."""
    base, t = _mk_table(fs, "delta", 0)
    txn = t.handle.transaction()
    for i in range(12):
        add = chunkfile.DataFileMeta(path=f"data/f{i}.chunk", size_bytes=1,
                                     record_count=1)
        txn.commit([add], [], properties={"i": str(i)})
    txn.close()
    assert fs.exists(join(base, "_delta_log", f"{10:020d}.checkpoint.json"))
    assert len(t.handle.snapshot().files) == 12
    # vacuum the pre-checkpoint log: state still reconstructs exactly
    for v in range(0, 10):
        fs.delete(join(base, "_delta_log", f"{v:020d}.json"))
    st = t.handle.snapshot()
    assert sorted(st.files) == sorted(f"data/f{i}.chunk" for i in range(12))
    assert st.properties["i"] == "11"


def test_delta_transaction_survives_concurrent_writer(fs):
    """A commit landing mid-transaction is detected via put-if-absent; the
    transaction re-syncs from the tail and lands on the next version."""
    base, t = _mk_table(fs, "delta", 1)
    txn = t.handle.transaction()
    # interloper commits behind the transaction's back
    t.append({"k": np.array([9], np.int64), "part": np.array(["p0"])})
    add = chunkfile.DataFileMeta(path="data/x.chunk", size_bytes=1,
                                 record_count=1)
    v = txn.commit([add], [], properties={"who": "txn"})
    st = t.handle.snapshot()
    assert st.version == v
    assert "data/x.chunk" in st.files
    assert len(st.files) == 3        # create-era file + interloper + txn


# ------------------------------------------- staged (pipelined) write path
class _DieAfterPuts:
    """Pass-through FS whose writes fail hard after a budget — a
    deterministic 'process died mid-flush' for staged-write recovery."""

    def __init__(self, inner, puts_allowed: int):
        self.inner = inner
        self.puts_allowed = puts_allowed

    def write_bytes(self, path, data, *, overwrite=False):
        if self.puts_allowed <= 0:
            raise IOError("simulated crash (connection gone)")
        self.puts_allowed -= 1
        return self.inner.write_bytes(path, data, overwrite=overwrite)

    def write_many(self, items, *, overwrite=False):
        for p, d in items:
            self.write_bytes(p, d, overwrite=overwrite)

    def __getattr__(self, name):
        return getattr(self.inner, name)


def test_crash_between_staged_flush_and_commit_point():
    """Kill the drain AFTER the staged flush (manifests + manifest-lists
    landed) but BEFORE the first commit-point metadata put: the staged
    objects are unreferenced orphans, the table stays readable at the
    previous version, and a clean re-run converges."""
    from repro.lst.storage import MemoryFS

    raw = MemoryFS()
    base = "bkt/t"
    t = LakeTable.create(raw, base, SCHEMA, "delta", PartitionSpec(["part"]))
    for i in range(2):
        t.append({"k": np.array([i], np.int64), "part": np.array(["p0"])})
    cfg = _cfg([base], "delta", ["iceberg"])
    res = run_sync(cfg, raw)
    assert res[0].ok and res[0].mode == "FULL"
    prev = LakeTable.open(raw, base, "iceberg")
    prev_version = prev.handle.current_version()
    prev_rows = sorted(prev.read_all()["k"].tolist())
    for i in range(4):
        t.append({"k": np.array([100 + i], np.int64),
                  "part": np.array(["p1"])})

    # a 4-commit iceberg drain stages 8 objects (4 add-manifests + 4
    # manifest-lists); the source-side chunk writes happen before the sync.
    # Allow exactly the staged flush, then die on the commit-point put.
    dying = _DieAfterPuts(raw, 8)
    res = run_sync(cfg, dying)
    assert not res[0].ok                          # the unit died

    after = LakeTable.open(raw, base, "iceberg")
    assert after.handle.current_version() == prev_version
    assert sorted(after.read_all()["k"].tolist()) == prev_rows
    # staged orphans exist but are unreferenced — the table is coherent
    res = run_sync(cfg, raw)                      # recovery = rerun
    assert res[0].ok and res[0].mode == "INCREMENTAL"
    assert res[0].commits_synced == 4
    got = sorted(LakeTable.open(raw, base, "iceberg").read_all()["k"].tolist())
    assert got == sorted(t.read_all()["k"].tolist())


def test_aborted_flush_still_moves_hint_over_landed_prefix():
    """A flush that lands some commit points and then dies must still move
    ``version-hint.text`` over the landed prefix — otherwise a daemon's
    ``head_token`` probe keeps reporting the old head and never replans
    the table (missed-change bug)."""
    from repro.lst.storage import MemoryFS

    raw = MemoryFS()
    base = "bkt/t"
    t = _mk_table2(raw, base, "iceberg", 1)
    handle = t.handle
    tok_before = handle.head_token()
    txn = handle.transaction()
    for i in range(3):
        txn.commit([chunkfile.DataFileMeta(path=f"data/h{i}.chunk",
                                           size_bytes=1, record_count=1)], [])

    # fail the SECOND commit-point put hard (not a conflict): one commit
    # lands, then the flush aborts
    orig = raw.write_bytes
    state = {"meta_puts": 0}

    def failing(path, data, *, overwrite=False):
        if path.endswith(".metadata.json"):
            state["meta_puts"] += 1
            if state["meta_puts"] == 2:
                raise IOError("simulated crash")
        return orig(path, data, overwrite=overwrite)

    raw.write_bytes = failing
    with pytest.raises(IOError):
        txn.flush()
    raw.write_bytes = orig

    assert handle.head_token() != tok_before       # probe sees the prefix
    assert len(handle.versions()) == 2             # pre-txn append + 1 landed


def _mk_table2(fs, base, fmt, n_commits):
    t = LakeTable.create(fs, base, SCHEMA, fmt, PartitionSpec(["part"]))
    for i in range(n_commits):
        t.append({"k": np.array([i, i + 100], np.int64),
                  "part": np.array([f"p{i % 2}", "p0"])})
    return t


def test_serial_round_trips_per_commit_are_o1():
    """The write side of a transactional drain occupies O(1) *serial*
    round-trip slots per commit: all staged objects of the chain share
    pipelined batch rounds, so growing the backlog 4 -> 16 adds ~1 serial
    slot per extra commit (its metadata put), not ~4."""
    from repro.lst.storage import (MemoryFS, RetryPolicy, SimulatedObjectStore,
                                   StorageProfile, layer_fs)

    def drain_rounds(backlog):
        raw = MemoryFS()
        base = "bkt/t"
        t = LakeTable.create(raw, base, SCHEMA, "delta",
                             PartitionSpec(["part"]),
                             {"delta.checkpointInterval": "100000"})
        t.append({"k": np.array([1], np.int64), "part": np.array(["p0"])})
        cfg = _cfg([base], "delta", ["iceberg"])
        assert run_sync(cfg, raw)[0].ok
        for i in range(backlog):
            t.append({"k": np.array([100 + i], np.int64),
                      "part": np.array(["p1"])})
        sim = SimulatedObjectStore(raw, StorageProfile(pipeline_depth=16))
        fs = layer_fs(sim, retry=RetryPolicy())
        before = sim.serial_rounds()
        res = run_sync(cfg, fs)
        assert res[0].ok and res[0].commits_synced == backlog
        return sim.serial_rounds() - before

    r4, r16 = drain_rounds(4), drain_rounds(16)
    per_extra_commit = (r16 - r4) / 12
    assert per_extra_commit <= 2.0, (r4, r16)
def test_chunk_stats_footer_range_read(tmp_table_path):
    fs = CountingFS()
    cols = {"a": np.arange(50_000, dtype=np.int64),
            "b": np.linspace(-1, 1, 50_000)}
    meta = chunkfile.write_chunk(fs, tmp_table_path, "d/x.chunk", cols)
    fs.reset()
    nrows, stats = chunkfile.read_chunk_stats(fs, tmp_table_path, "d/x.chunk")
    assert nrows == 50_000
    assert stats["a"].min == 0 and stats["a"].max == 49_999
    assert stats["b"].min == -1.0 and stats["b"].max == 1.0
    assert stats == meta.column_stats
    # the column data was never fetched: no whole-object read, and the two
    # ranged reads (trailer + footer) cover a tiny fraction of the object
    full = f"{tmp_table_path}/d/x.chunk"
    assert full not in fs.reads
    assert fs.range_reads[full] == 2
    assert fs.size(full) > 100 * 1024


def test_chunk_roundtrip_with_footer(fs, tmp_table_path):
    cols = {"a": np.arange(10, dtype=np.int64),
            "s": np.array(["x", "y"] * 5)}
    chunkfile.write_chunk(fs, tmp_table_path, "x.chunk", cols,
                          extra={"shard": "0/4"}, compress=True)
    back, extra = chunkfile.read_chunk(fs, tmp_table_path, "x.chunk")
    np.testing.assert_array_equal(back["a"], cols["a"])
    np.testing.assert_array_equal(back["s"], cols["s"])
    assert extra == {"shard": "0/4"}
    nrows, stats = chunkfile.read_chunk_stats(fs, tmp_table_path, "x.chunk")
    assert nrows == 10 and stats["a"].max == 9


def test_chunkfile_v1_clearly_rejected(fs, tmp_table_path):
    """Old-layout files (stats inline, no footer) fail with a version error,
    not a garbage footer-offset parse."""
    fs.write_bytes(join(tmp_table_path, "old.chunk"),
                   b"CHK1" + b"\x81\xa1a\x01" * 8 + b"CHK1")
    with pytest.raises(ValueError, match="v1"):
        chunkfile.read_chunk_stats(fs, tmp_table_path, "old.chunk")
    with pytest.raises(ValueError, match="v1"):
        chunkfile.read_chunk(fs, tmp_table_path, "old.chunk")
    # and a truncated object fails with a chunkfile error, not an OSError
    fs.write_bytes(join(tmp_table_path, "tiny.chunk"), b"CHK2")
    with pytest.raises(ValueError, match="truncated"):
        chunkfile.read_chunk_stats(fs, tmp_table_path, "tiny.chunk")


# ------------------------------------------------- hudi extraMetadata codec
def test_hudi_extrametadata_roundtrip_exact(fs):
    """Values round-trip through the shared codec — including strings that
    start with a quote, which the old startswith('\"') heuristic mangled."""
    base, t = _mk_table(fs, "hudi", 1)
    tricky = {"plain": "value",
              "quoted": '"looks like json but is a string',
              "jsonish": '["not", "a", "list"]'}
    t.handle.commit([], [], extra_meta=tricky, operation="meta")
    em = t.handle.latest_extra_metadata()
    for k, v in tricky.items():
        assert em[k] == v, k
    _, _, _, info = t.handle.changes(t.handle.current_version())
    for k, v in tricky.items():
        assert info[k] == v, k
