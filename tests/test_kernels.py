"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

All kernels run in interpret mode on CPU (TPU is the compile target; the
kernel body semantics are identical).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd.kernel import ssd_chunk_scan
from repro.kernels.ssd.ref import ssd_ref

KEY = jax.random.PRNGKey(7)
TOL = {jnp.float32: 3e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,sk,h,kv,dh,causal,window,softcap",
    [
        (2, 256, 256, 4, 2, 64, True, 0, 0.0),       # GQA causal
        (1, 256, 256, 4, 4, 128, True, 128, 50.0),   # window + softcap
        (2, 128, 384, 8, 2, 64, False, 0, 0.0),      # cross/bidir
        (1, 384, 384, 2, 1, 128, True, 0, 0.0),      # MQA, non-pow2 blocks
    ])
def test_flash_attention_sweep(b, sq, sk, h, kv, dh, causal, window, softcap,
                               dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, sk, kv, dh), dtype)
    v = jax.random.normal(ks[2], (b, sk, kv, dh), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, bq=128, bk=128, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window,
                        softcap=softcap)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,S,h,kv,dh,window",
    [
        (2, 512, 4, 2, 64, 0),
        (2, 512, 4, 4, 128, 128),     # MHA + sliding window
        (1, 300, 8, 2, 64, 0),        # ragged cache length
        (3, 256, 16, 2, 128, 64),
    ])
def test_decode_attention_sweep(b, S, h, kv, dh, window, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, S, kv, dh), dtype)
    v = jax.random.normal(ks[2], (b, S, kv, dh), dtype)
    lengths = jax.random.randint(ks[3], (b,), max(window, 8), S)
    out = decode_attention(q, k, v, lengths, window=window, bk=128,
                           interpret=True)
    ref = decode_attention_ref(q, k, v, lengths, window=window)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize(
    "b,s,h,p,g,n,chunk",
    [
        (2, 128, 4, 16, 1, 32, 32),
        (1, 256, 8, 32, 2, 16, 64),
        (1, 128, 4, 1, 1, 16, 16),    # head_dim=1 (jamba / mamba-1 mode)
        (2, 192, 6, 8, 3, 8, 64),     # uneven groups
    ])
def test_ssd_sweep(b, s, h, p, g, n, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.5)
    B = jax.random.normal(ks[3], (b, s, g, n), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, g, n), jnp.float32)
    y, state = ssd_chunk_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    yr, sr = ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(y, yr, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(state, sr, atol=5e-4, rtol=5e-4)


def test_ssd_kernel_matches_model_path():
    """The XLA chunked SSD in models/ssm.py and the Pallas kernel agree."""
    from repro.models.config import ModelConfig, LayerSpec, SSMConfig
    from repro.models import ssm as S
    from repro.models.param import init_params
    cfg = ModelConfig(
        name="t", family="ssm", d_model=32, n_layers=1, n_heads=0,
        n_kv_heads=0, head_dim=0, d_ff=0, vocab_size=64,
        cycle=(LayerSpec(kind="ssm", mlp=False),),
        ssm=SSMConfig(d_inner=32, d_state=16, n_heads=4, head_dim=8,
                      n_groups=1, conv_width=4, chunk=16), dtype="float32")
    p = init_params(S.ssm_template(cfg), KEY)
    x = jax.random.normal(KEY, (2, 64, 4, 8), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(KEY, (2, 64, 4)))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    B = jax.random.normal(KEY, (2, 64, 1, 16))
    C = jax.random.normal(KEY, (2, 64, 1, 16))
    y_kernel, _ = ssd_chunk_scan(x, dt, A, B, C, chunk=16, interpret=True)
    y_ref, _ = ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(y_kernel, y_ref, atol=5e-4, rtol=5e-4)


def test_flash_attention_jit_wrapper():
    from repro.kernels.flash_attention.ops import flash_attention_op
    q = jax.random.normal(KEY, (1, 128, 2, 64))
    k = jax.random.normal(KEY, (1, 128, 2, 64))
    out = flash_attention_op(q, k, k, interpret=True)
    assert out.shape == q.shape
