"""Per-architecture smoke tests (reduced configs) + decode consistency."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.models.config import LayerSpec, SHAPE_CELLS
from repro.models.model import Model
from repro.models.param import count_params, init_params

KEY = jax.random.PRNGKey(0)
B, S = 2, 24


def _inputs(cfg, s=S):
    tokens = jax.random.randint(KEY, (B, s), 0, cfg.vocab_size)
    kw = {}
    if cfg.encoder:
        kw["enc_embeds"] = jax.random.normal(
            KEY, (B, cfg.encoder.n_frames, cfg.d_model), jnp.dtype(cfg.dtype))
    return tokens, kw


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_step(arch):
    """One forward + one train step on CPU: shapes + finiteness."""
    cfg = smoke_config(arch)
    model = Model(cfg)
    params = init_params(model.param_template(), KEY)
    tokens, kw = _inputs(cfg)
    logits, aux = jax.jit(lambda p, t: model.forward(p, t, **kw))(params,
                                                                  tokens)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # one train step
    from repro.optim import AdamWConfig, adamw_init
    from repro.train.loop import make_train_step
    step = jax.jit(make_train_step(model, AdamWConfig(), ce_chunk=S))
    opt = adamw_init(params)
    batch = {"inputs": tokens, "targets": tokens}
    batch.update(kw)
    if cfg.encoder:
        batch["enc_embeds"] = kw["enc_embeds"]
    p2, o2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(o2["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_teacher_forcing(arch):
    """prefill+decode must reproduce the teacher-forced logits (fp32,
    capacity high enough that MoE drops nothing)."""
    cfg = replace(smoke_config(arch), dtype="float32", capacity_factor=8.0)
    if arch == "gemma2-27b":   # exercise the ring-buffer window path
        cfg = replace(cfg, cycle=(LayerSpec(kind="attn", window=8),
                                  LayerSpec(kind="attn", window=0)))
    model = Model(cfg)
    params = init_params(model.param_template(), KEY)
    tokens, kw = _inputs(cfg, S + 1)
    full, _ = model.forward(params, tokens, **kw)
    last, cache = model.prefill(params, tokens[:, :S], cache_len=S + 8, **kw)
    assert float(jnp.max(jnp.abs(full[:, S - 1] - last))) < 2e-3
    logits2, _ = model.decode_step(params, cache, tokens[:, S],
                                   jnp.full((B,), S, jnp.int32))
    assert float(jnp.max(jnp.abs(full[:, S] - logits2))) < 2e-3


def test_full_config_parameter_counts():
    """Full configs build templates with plausible parameter counts
    (templates only — no allocation)."""
    expect = {
        "gemma2-27b": (24e9, 30e9),
        "stablelm-3b": (2e9, 4e9),
        "yi-9b": (8e9, 10e9),
        "starcoder2-15b": (14e9, 17e9),
        "dbrx-132b": (120e9, 142e9),
        "granite-moe-3b-a800m": (2.5e9, 4.5e9),
        "jamba-v0.1-52b": (45e9, 60e9),
        "chameleon-34b": (30e9, 38e9),
        "whisper-small": (0.15e9, 0.35e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params(Model(get_config(arch)).param_template())
        assert lo <= n <= hi, (arch, f"{n:,}")


def test_moe_capacity_drops_tokens():
    cfg = replace(smoke_config("dbrx-132b"), dtype="float32",
                  capacity_factor=0.25)
    model = Model(cfg)
    params = init_params(model.param_template(), KEY)
    tokens, _ = _inputs(cfg)
    logits, aux = model.forward(params, tokens)
    assert bool(jnp.isfinite(logits).all())      # drops are benign
    assert float(aux) > 0.0                      # aux losses active


def test_shape_cells_defined():
    names = [c.name for c in SHAPE_CELLS]
    assert names == ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    assert SHAPE_CELLS[3].global_batch == 1
    assert SHAPE_CELLS[0].step == "train"
