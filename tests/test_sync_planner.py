"""Planner unit tests: FULL / INCREMENTAL / SKIP decisions and exact commit
ranges, asserted WITHOUT executing any sync (the whole point of splitting
plan from execute)."""

import tempfile

import numpy as np

from repro.core import SyncConfig, XTableSyncer, run_sync
from repro.core.plan import ERROR, FULL, INCREMENTAL, SKIP, SyncPlanner
from repro.core.targets import SOURCE_FMT_KEY, TOKEN_KEY
from repro.lst import LakeTable
from repro.lst.fs import join
from repro.lst.iceberg import IcebergTable
from repro.lst.schema import Field, PartitionSpec, Schema

SCHEMA = Schema([Field("k", "int64"), Field("part", "string")])


def _mk_delta(fs, n_commits=3):
    base = tempfile.mkdtemp() + "/t"
    t = LakeTable.create(fs, base, SCHEMA, "delta", PartitionSpec(["part"]))
    for i in range(n_commits):
        t.append({"k": np.array([i], np.int64), "part": np.array(["p0"])})
    return base, t


def _cfg(base, src="DELTA", targets=("ICEBERG", "HUDI")):
    return SyncConfig.from_dict({
        "sourceFormat": src, "targetFormats": list(targets),
        "datasets": [{"tableBasePath": base}]})


def test_fresh_targets_plan_full_without_executing(fs):
    base, t = _mk_delta(fs)
    head = t.handle.current_version()
    plan = SyncPlanner(_cfg(base), fs).plan()
    assert [u.mode for u in plan.units] == [FULL, FULL]
    assert all(u.source_head == head for u in plan.units)
    assert [u.target_format for u in plan.units] == ["iceberg", "hudi"]
    # planning is read-only: no target metadata came into existence
    assert not fs.list_dir(join(base, "metadata"))
    assert not fs.exists(join(base, ".hoodie", "hoodie.properties"))
    assert plan.summary() == {FULL: 2}
    assert len(plan.pending()) == 2


def test_synced_targets_plan_skip(fs):
    base, _ = _mk_delta(fs)
    run_sync(_cfg(base), fs)
    plan = SyncPlanner(_cfg(base), fs).plan()
    assert [u.mode for u in plan.units] == [SKIP, SKIP]
    assert plan.pending() == []


def test_backlog_plans_incremental_with_exact_commit_range(fs):
    base, t = _mk_delta(fs, n_commits=2)          # versions 0..2
    run_sync(_cfg(base), fs)
    new = [t.append({"k": np.array([10 + i], np.int64),
                     "part": np.array(["p0"])}) for i in range(3)]
    plan = SyncPlanner(_cfg(base), fs).plan()
    for u in plan.units:
        assert u.mode == INCREMENTAL
        assert list(u.commits) == new              # exactly the new commits
        assert u.source_head == new[-1]


def test_diverged_token_plans_full(fs):
    """A target whose token never existed in the source history -> FULL."""
    base, _ = _mk_delta(fs)
    run_sync(_cfg(base, targets=("ICEBERG",)), fs)
    IcebergTable.open(fs, base).commit(
        [], [], properties={TOKEN_KEY: "999999", SOURCE_FMT_KEY: "delta"})
    plan = SyncPlanner(_cfg(base, targets=("ICEBERG",)), fs).plan()
    (u,) = plan.units
    assert u.mode == FULL
    assert "not in source history" in u.reason


def test_source_format_change_plans_full(fs):
    """Target synced from delta, then planned against an iceberg source at
    the same path: recorded source format no longer matches -> FULL."""
    base, _ = _mk_delta(fs)
    run_sync(_cfg(base, targets=("ICEBERG", "HUDI")), fs)
    plan = SyncPlanner(_cfg(base, src="ICEBERG", targets=("HUDI",)), fs).plan()
    (u,) = plan.units
    assert u.mode == FULL
    assert "source format changed" in u.reason


def test_vacuumed_history_plans_full(fs):
    """Delta log truncated behind a checkpoint: token vanishes from the
    source history while the snapshot stays reachable -> FULL fallback."""
    base = tempfile.mkdtemp() + "/t"
    t = LakeTable.create(fs, base, SCHEMA, "delta", PartitionSpec(["part"]))
    for i in range(10):                           # v1..v10; checkpoint at v10
        t.append({"k": np.array([i], np.int64),
                  "part": np.array([f"p{i % 2}"])})
    cfg = _cfg(base, targets=("HUDI",))
    run_sync(cfg, fs)                             # token = "10"
    t.append({"k": np.array([100], np.int64), "part": np.array(["p0"])})
    for v in range(0, 11):
        fs.delete(join(base, "_delta_log", f"{v:020d}.json"))
    plan = SyncPlanner(cfg, fs).plan()
    assert plan.units[0].mode == FULL
    # and executing that plan converges the target onto the full state
    res = run_sync(cfg, fs)
    assert res[0].mode == "FULL" and res[0].ok
    got = sorted(LakeTable.open(fs, base, "hudi").read_all()["k"].tolist())
    assert got == sorted(list(range(10)) + [100])


def test_incremental_disabled_plans_full(fs):
    base, t = _mk_delta(fs)
    run_sync(_cfg(base, targets=("ICEBERG",)), fs)
    t.append({"k": np.array([9], np.int64), "part": np.array(["p0"])})
    cfg = SyncConfig.from_dict({
        "sourceFormat": "DELTA", "targetFormats": ["ICEBERG"],
        "datasets": [{"tableBasePath": base}], "incremental": False})
    (u,) = SyncPlanner(cfg, fs).plan().units
    assert u.mode == FULL and "incremental disabled" in u.reason


def test_broken_target_isolated_as_error_unit(fs, monkeypatch):
    """A target whose state read blows up plans as ERROR; others unaffected."""
    from repro.core.targets import HudiTarget
    base, _ = _mk_delta(fs)
    run_sync(_cfg(base), fs)

    def boom(self):
        raise RuntimeError("corrupt target metadata")

    monkeypatch.setattr(HudiTarget, "get_sync_token", boom)
    plan = SyncPlanner(_cfg(base), fs).plan()
    by_fmt = {u.target_format: u for u in plan.units}
    assert by_fmt["iceberg"].mode == SKIP
    assert by_fmt["hudi"].mode == ERROR
    assert "corrupt target metadata" in by_fmt["hudi"].reason


def test_crash_between_targets_recovers_via_replan(fs, monkeypatch):
    """First target succeeds, second 'crashes'; rerun converges both
    (the seed's recovery contract, preserved across the refactor)."""
    from repro.core.targets import HudiTarget
    base, _ = _mk_delta(fs)
    cfg = _cfg(base, targets=("ICEBERG", "HUDI"))
    orig = HudiTarget.full_sync

    def boom(self, snapshot):
        raise RuntimeError("simulated crash")

    monkeypatch.setattr(HudiTarget, "full_sync", boom)
    res = run_sync(cfg, fs)
    assert res[0].ok and not res[1].ok            # plan-order results
    monkeypatch.setattr(HudiTarget, "full_sync", orig)
    res2 = run_sync(cfg, fs)
    by_fmt = {r.target_format: r for r in res2}
    assert by_fmt["iceberg"].mode == "SKIP"
    assert by_fmt["hudi"].ok and by_fmt["hudi"].mode == "FULL"
    assert sorted(LakeTable.open(fs, base, "hudi").read_all()["k"].tolist()) \
        == [0, 1, 2]


def test_syncer_plan_then_run_skip_idempotent(fs):
    base, _ = _mk_delta(fs)
    syncer = XTableSyncer(_cfg(base), fs)
    r1 = syncer.run()
    assert all(r.ok and r.mode == "FULL" for r in r1)
    r2 = XTableSyncer(_cfg(base), fs).run()
    assert all(r.mode == "SKIP" for r in r2)
