"""Train-loop numerics, sharding resolver, and HLO cost-model unit tests."""

import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.sharding import Sharder
from repro.train.loop import chunked_cross_entropy


# ------------------------------------------------------------------ loss
def test_chunked_ce_matches_direct():
    key = jax.random.PRNGKey(0)
    b, s, d, v = 2, 32, 16, 50
    hidden = jax.random.normal(key, (b, s, d), jnp.float32)
    w = jax.random.normal(key, (d, v), jnp.float32) * 0.1
    targets = jax.random.randint(key, (b, s), 0, v)
    loss_c, ce_c, n = chunked_cross_entropy(hidden, w, targets, chunk=8,
                                            z_weight=0.0)
    logits = hidden @ w
    lse = jax.scipy.special.logsumexp(logits, -1)
    tgt = jnp.take_along_axis(logits, targets[..., None], -1)[..., 0]
    ce_direct = jnp.mean(lse - tgt)
    assert abs(float(ce_c - ce_direct)) < 1e-5
    assert int(n) == b * s


def test_chunked_ce_ignores_padding():
    key = jax.random.PRNGKey(1)
    hidden = jax.random.normal(key, (1, 8, 4))
    w = jax.random.normal(key, (4, 11))
    targets = jnp.array([[1, 2, -1, -1, 3, -1, 4, 5]])
    _, ce, n = chunked_cross_entropy(hidden, w, targets, chunk=4)
    assert int(n) == 5


def test_grad_accum_matches_full_batch():
    from dataclasses import replace
    from repro.configs import smoke_config
    from repro.models.model import Model
    from repro.models.param import init_params
    from repro.optim import AdamWConfig, adamw_init
    from repro.train.loop import make_train_step

    cfg = replace(smoke_config("stablelm-3b"), dtype="float32")
    model = Model(cfg)
    key = jax.random.PRNGKey(2)
    params = init_params(model.param_template(), key)
    batch = {"inputs": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
             "targets": jax.random.randint(key, (4, 16), 0, cfg.vocab_size)}
    s1 = make_train_step(model, AdamWConfig(), grad_accum=1, ce_chunk=16)
    s2 = make_train_step(model, AdamWConfig(), grad_accum=2, ce_chunk=16)
    p1, _, m1 = jax.jit(s1)(params, adamw_init(params), batch)
    p2, _, m2 = jax.jit(s2)(params, adamw_init(params), batch)
    # losses are means over microbatches; grads averaged — params must agree
    l1 = jax.tree.leaves(p1)
    l2 = jax.tree.leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-4, rtol=2e-3)


def test_adamw_descends_quadratic():
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(peak_lr=0.5, warmup_steps=0, total_steps=100,
                      weight_decay=0.0)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(params, grads, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_int8_grad_compression_roundtrip():
    from repro.optim.adamw import compress_int8
    g = jax.random.normal(jax.random.PRNGKey(3), (1024,)) * 0.1
    q = compress_int8(g, jax.random.PRNGKey(4))
    # unbiased-ish, bounded quantization error
    assert float(jnp.abs(q - g).max()) <= float(jnp.abs(g).max()) / 127 + 1e-6


# ------------------------------------------------------------ sharding rules
def test_sharder_divisibility_fallback():
    sh = Sharder({"data": 16, "model": 16})
    # kv=4 cannot shard 16 ways -> replicated
    assert sh.resolve(("embed", "kv_heads", "head_dim"),
                      (4096, 4, 128)) == jax.sharding.PartitionSpec("data")
    # heads=32 shard over model
    spec = sh.resolve(("embed", "heads", "head_dim"), (4096, 32, 128))
    assert spec == jax.sharding.PartitionSpec("data", "model")


def test_sharder_duplicate_axis_avoidance():
    sh = Sharder({"data": 16, "model": 16})
    # experts takes model; mlp then cannot reuse it
    spec = sh.resolve(("experts", "embed", "mlp"), (16, 6144, 10752))
    assert spec == jax.sharding.PartitionSpec("model", "data")
    # 40 experts: unshardable -> mlp gets model instead
    spec = sh.resolve(("experts", "embed", "mlp"), (40, 1536, 512))
    assert spec == jax.sharding.PartitionSpec(None, "data", "model")


def test_sharder_batch_multi_axis():
    sh = Sharder({"pod": 2, "data": 16, "model": 16})
    spec = sh.resolve(("batch", "seq"), (256, 4096))
    assert spec == jax.sharding.PartitionSpec(("pod", "data"))
    # batch=1 (long_500k): replicate
    assert sh.resolve(("batch",), (1,)) == jax.sharding.PartitionSpec()


def test_sharder_null_noop():
    sh = Sharder.null()
    x = jnp.ones((4, 4))
    assert sh(x, "batch", "seq") is x


# ---------------------------------------------------------- hlo cost model
def test_hlo_walker_counts_scan_trips():
    """Scan-of-matmuls: walker flops must be ~L x the single-layer flops
    (XLA's own cost_analysis undercounts while bodies)."""
    from repro.launch.hlo_analysis import analyze
    L, M = 7, 64

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    x = jnp.ones((M, M))
    ws = jnp.ones((L, M, M))
    compiled = jax.jit(f).lower(x, ws).compile()
    res = analyze(compiled.as_text())
    expect = 2 * M * M * M * L
    assert 0.9 * expect <= res["dot_flops"] <= 1.2 * expect, res["dot_flops"]


def test_hlo_walker_matches_xla_on_straightline():
    from repro.launch.hlo_analysis import analyze

    def f(a, b):
        return (a @ b).sum()

    a = jnp.ones((128, 256))
    b = jnp.ones((256, 64))
    compiled = jax.jit(f).lower(a, b).compile()
    res = analyze(compiled.as_text())
    ca = compiled.cost_analysis()
    if isinstance(ca, list):     # newer jax returns [per-device dict]
        ca = ca[0]
    xla = ca["flops"]
    assert abs(res["dot_flops"] - 2 * 128 * 256 * 64) / xla < 0.1


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    """Full dry-run machinery on one small cell, in a subprocess (needs its
    own XLA_FLAGS before jax init)."""
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=512'\n"
        "import sys; sys.path.insert(0, 'src')\n"
        "from repro.launch.dryrun import run_cell\n"
        "rec = run_cell('stablelm-3b', 'decode_32k', True, '/tmp/dr', save=False)\n"
        "assert rec['ok'], rec.get('error')\n"
        "assert rec['hlo']['flops'] > 0\n"
        "print('CELL-OK')\n")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), env=env,
                         timeout=560)
    assert "CELL-OK" in out.stdout, out.stderr[-2000:]
