"""End-to-end behaviour of the LST substrate (paper §2, Listing 1 / Fig 1)."""

import numpy as np
import pytest

from repro.lst import LakeTable, chunkfile
from repro.lst.fs import PutIfAbsentError, join
from repro.lst.schema import Field, PartitionSpec, Schema
from repro.lst.table import Predicate

FORMATS = ["delta", "iceberg", "hudi"]
SCHEMA = Schema([Field("s_id", "int64"), Field("s_type", "string"),
                 Field("price", "float64")])


# ------------------------------------------------------------------ chunkfile
def test_chunkfile_roundtrip(fs, tmp_table_path):
    cols = {"a": np.arange(10, dtype=np.int64),
            "b": np.linspace(0, 1, 10).astype(np.float32),
            "c": np.array([f"s{i}" for i in range(10)])}
    meta = chunkfile.write_chunk(fs, tmp_table_path, "d/x.chunk", cols,
                                 extra={"k": "v"})
    back, extra = chunkfile.read_chunk(fs, tmp_table_path, "d/x.chunk")
    for k in cols:
        np.testing.assert_array_equal(back[k], cols[k])
    assert extra == {"k": "v"}
    assert meta.record_count == 10
    assert meta.column_stats["a"].min == 0 and meta.column_stats["a"].max == 9


def test_chunkfile_immutable(fs, tmp_table_path):
    cols = {"a": np.arange(3)}
    chunkfile.write_chunk(fs, tmp_table_path, "x.chunk", cols)
    with pytest.raises(PutIfAbsentError):
        chunkfile.write_chunk(fs, tmp_table_path, "x.chunk", cols)


def test_fs_put_if_absent(fs, tmp_table_path):
    p = join(tmp_table_path, "obj")
    fs.write_bytes(p, b"one")
    with pytest.raises(PutIfAbsentError):
        fs.write_bytes(p, b"two")
    fs.write_bytes(p, b"three", overwrite=True)
    assert fs.read_bytes(p) == b"three"


# ---------------------------------------------------------------- listing 1
@pytest.mark.parametrize("fmt", FORMATS)
def test_listing1_lifecycle(fmt, fs, tmp_table_path, sales_columns):
    """CREATE -> INSERT -> DELETE (copy-on-write) -> time travel."""
    t = LakeTable.create(fs, tmp_table_path, SCHEMA, fmt,
                         PartitionSpec(["s_type"]))
    v1 = t.append(sales_columns)
    assert t.state().total_records() == 6
    v2 = t.delete_where(Predicate("s_id", "==", 2))
    assert sorted(t.read_all()["s_id"].tolist()) == [1, 3, 4, 5, 6]
    # time travel: v1 still shows all six (old data files untouched)
    assert sorted(t.read_all(version=v1)["s_id"].tolist()) == [1, 2, 3, 4, 5, 6]
    assert v2 in t.history()


@pytest.mark.parametrize("fmt", FORMATS)
def test_partition_and_stats_pruning(fmt, fs, tmp_table_path, sales_columns):
    t = LakeTable.create(fs, tmp_table_path, SCHEMA, fmt,
                         PartitionSpec(["s_type"]))
    t.append(sales_columns)
    st = t.state()
    assert len(st.files) == 3          # one per partition
    # partition pruning
    planned = t.plan_files(st, (Predicate("s_type", "==", "a"),))
    assert len(planned) == 1
    # stats pruning (min/max in the metadata layer — scenario 3 mechanism)
    assert t.plan_files(st, (Predicate("s_id", ">=", 100),)) == []
    assert len(t.plan_files(st, (Predicate("price", "<=", 15.0),))) == 1


@pytest.mark.parametrize("fmt", FORMATS)
def test_schema_evolution(fmt, fs, tmp_table_path, sales_columns):
    t = LakeTable.create(fs, tmp_table_path, SCHEMA, fmt)
    t.append(sales_columns)
    t.evolve_schema(SCHEMA.add_field(Field("qty", "int32")))
    assert t.state().schema.names() == ["s_id", "s_type", "price", "qty"]
    # data written before evolution still readable
    assert len(t.read_all()["s_id"]) == 6


@pytest.mark.parametrize("fmt", FORMATS)
def test_commit_conflict_detection(fmt, fs, tmp_table_path, sales_columns):
    """Two handles racing: optimistic concurrency resolves both commits."""
    t1 = LakeTable.create(fs, tmp_table_path, SCHEMA, fmt)
    t2 = LakeTable.open(fs, tmp_table_path, fmt)
    t1.append(sales_columns)
    t2.append(sales_columns)          # retries internally on conflict
    assert t1.state().total_records() == 12


def test_delta_checkpoint_compaction(fs, tmp_table_path, sales_columns):
    """11+ commits -> _last_checkpoint exists and replay stays correct."""
    t = LakeTable.create(fs, tmp_table_path, SCHEMA, "delta")
    for _ in range(12):
        t.append(sales_columns)
    assert fs.exists(join(tmp_table_path, "_delta_log", "_last_checkpoint"))
    assert t.state().total_records() == 72


def test_iceberg_manifest_reuse(fs, tmp_table_path, sales_columns):
    """Append-only commits must not rewrite prior manifests (O(change))."""
    t = LakeTable.create(fs, tmp_table_path, SCHEMA, "iceberg")
    t.append(sales_columns)
    meta_dir = join(tmp_table_path, "metadata")
    before = {n for n in fs.list_dir(meta_dir) if n.startswith("manifest-")}
    t.append(sales_columns)
    after = {n for n in fs.list_dir(meta_dir) if n.startswith("manifest-")}
    assert before < after              # old manifests untouched, one added
    assert len(after - before) == 1


def test_hudi_timeline_states(fs, tmp_table_path, sales_columns):
    """requested -> inflight -> completed instant files exist."""
    t = LakeTable.create(fs, tmp_table_path, SCHEMA, "hudi")
    v = t.append(sales_columns)
    names = fs.list_dir(join(tmp_table_path, ".hoodie"))
    assert f"{v}.commit" in names
    assert f"{v}.commit.requested" in names
    assert f"{v}.commit.inflight" in names
