"""Full-loop integration: train -> LST checkpoint -> XTable sync ->
restart via a DIFFERENT format -> serve (paper Scenarios 2 + 3 inside the
training framework)."""

import tempfile
from dataclasses import replace

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data import LakeDataLoader, write_synth_corpus
from repro.lst import LocalFS
from repro.models.model import Model
from repro.serve.engine import Request, ServeEngine
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def world():
    fs = LocalFS()
    root = tempfile.mkdtemp()
    write_synth_corpus(fs, f"{root}/corpus", fmt="delta", n_docs=32,
                       pack_len=33, vocab=256)
    cfg = replace(smoke_config("yi-9b"), vocab_size=256)
    model = Model(cfg)
    loader = LakeDataLoader(fs, f"{root}/corpus", "delta", batch_size=4,
                            seq_len=32)
    from repro.optim import AdamWConfig
    tr = Trainer(model, loader, fs, f"{root}/ckpt",
                 TrainerConfig(steps=7, save_every=3, log_every=100,
                               ce_chunk=32,
                               opt=AdamWConfig(peak_lr=3e-3, warmup_steps=2,
                                               total_steps=10)))
    tr.init_or_restore()
    hist = tr.run()
    return {"fs": fs, "root": root, "model": model, "hist": hist, "tr": tr}


def test_training_learns(world):
    losses = [h[1] for h in world["hist"]]
    assert losses[-1] < losses[0]


def test_restart_from_translated_format_resumes_exactly(world):
    fs, root, model = world["fs"], world["root"], world["model"]
    loader2 = LakeDataLoader(fs, f"{root}/corpus", "delta", batch_size=4,
                             seq_len=32)
    tr2 = Trainer(model, loader2, fs, f"{root}/ckpt",
                  TrainerConfig(steps=9, save_every=100, log_every=100,
                                ce_chunk=32, restore_format="iceberg"))
    start = tr2.init_or_restore()
    assert start == 7                         # resumes after the final save
    assert loader2.row == world["tr"].loader.row
    # params byte-identical to what was saved
    a = jax.tree.leaves(tr2.params)[0]
    b = jax.tree.leaves(world["tr"].params)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_different_topology(world):
    """Restore host arrays and device_put against a 1-device 'new mesh' —
    chunk metadata carries global shapes, so any topology works."""
    fs, root, model = world["fs"], world["root"], world["model"]
    from repro.checkpoint import LSTCheckpointManager
    from repro.models.param import template_shapes
    mgr = LSTCheckpointManager(fs, f"{root}/ckpt", fmt="delta",
                               sync_targets=())
    step, flat = mgr.restore()
    sharded = {k: jax.device_put(v) for k, v in list(flat.items())[:3]}
    for k, v in sharded.items():
        assert tuple(v.shape) == tuple(flat[k].shape)


def test_serve_from_iceberg_view(world):
    fs, root, model = world["fs"], world["root"], world["model"]
    eng = ServeEngine.from_lake(model, fs, f"{root}/ckpt", fmt="iceberg",
                                cache_len=48)
    outs = eng.generate([Request(prompt=[5, 6, 7], max_new=6),
                         Request(prompt=[1, 2], max_new=3)])
    assert len(outs[0]) == 6 and len(outs[1]) == 3
    assert all(0 <= t < model.cfg.vocab_size for t in outs[0])


def test_serve_resolves_checkpoint_by_catalog_name(world):
    """Scenario 3 through the catalog: the serving fleet addresses the
    checkpoint table by registered NAME, and the restore pins at the
    published (token, commit) — not whatever head a concurrent sync may
    have half-landed."""
    fs, root, model = world["fs"], world["root"], world["model"]
    from repro.core import MetadataCache
    from repro.lst.catalog import Catalog, TablePointer, ViewRef
    from repro.serve import SnapshotServer

    cache = MetadataCache(fs)
    idx = cache.index("iceberg", f"{root}/ckpt")
    token = idx.probe()
    idx.refresh_to(token)
    head, _state = idx.pinned_state()
    idx.end_cycle()
    catalog = Catalog(fs, f"{root}/catalog")
    catalog.register_table(
        TablePointer(name="yi-9b-ckpt", base_path=f"{root}/ckpt",
                     source_format="iceberg",
                     views={"iceberg": ViewRef(token, head)}),
        group="serving")

    eng = ServeEngine.from_lake(model, fs, fmt="iceberg", cache_len=48,
                                read_plane=SnapshotServer(fs, cache=cache),
                                catalog=catalog, table="yi-9b-ckpt")
    outs = eng.generate([Request(prompt=[5, 6, 7], max_new=4)])
    assert len(outs[0]) == 4
    with pytest.raises(ValueError):
        ServeEngine.from_lake(model, fs, catalog=catalog)   # needs table=


def test_serve_greedy_deterministic(world):
    fs, root, model = world["fs"], world["root"], world["model"]
    eng = ServeEngine.from_lake(model, fs, f"{root}/ckpt", fmt="delta",
                                cache_len=48)
    a = eng.generate([Request(prompt=[9, 8, 7], max_new=5)])
    b = eng.generate([Request(prompt=[9, 8, 7], max_new=5)])
    assert a == b
