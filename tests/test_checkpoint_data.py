"""Checkpoint-as-LST + data pipeline tests (the framework integration)."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import LSTCheckpointManager
from repro.data import LakeDataLoader, write_synth_corpus
from repro.lst import LakeTable


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": {"a": jax.random.normal(k, (64, 32), jnp.float32),
              "b": jax.random.normal(k, (8, 128), jnp.bfloat16)},
        "step_count": jnp.asarray(7, jnp.int32),
    }


def test_checkpoint_roundtrip_all_formats(fs):
    base = tempfile.mkdtemp() + "/ckpt"
    mgr = LSTCheckpointManager(fs, base, fmt="hudi",
                               sync_targets=("delta", "iceberg"))
    tree = _tree()
    mgr.save(10, tree)
    for fmt in (None, "delta", "iceberg"):     # None = native hudi
        step, back = mgr.restore_pytree(tree, fmt=fmt)
        assert step == 10
        np.testing.assert_array_equal(np.asarray(back["w"]["a"]),
                                      np.asarray(tree["w"]["a"]))
        assert back["w"]["b"].dtype == np.asarray(tree["w"]["b"]).dtype


def test_checkpoint_multiple_steps_and_latest(fs):
    base = tempfile.mkdtemp() + "/ckpt"
    mgr = LSTCheckpointManager(fs, base, fmt="delta", sync_targets=())
    for s in (1, 5, 9):
        mgr.save(s, {"x": jnp.full((4,), s, jnp.float32)})
    assert mgr.steps() == [1, 5, 9]
    step, flat = mgr.restore()
    assert step == 9
    np.testing.assert_array_equal(flat["x"], np.full((4,), 9, np.float32))
    step, flat = mgr.restore(5)
    np.testing.assert_array_equal(flat["x"], np.full((4,), 5, np.float32))


def test_checkpoint_resave_step_replaces(fs):
    base = tempfile.mkdtemp() + "/ckpt"
    mgr = LSTCheckpointManager(fs, base, fmt="hudi", sync_targets=())
    mgr.save(3, {"x": jnp.zeros((4,))})
    mgr.save(3, {"x": jnp.ones((4,))})
    step, flat = mgr.restore(3)
    np.testing.assert_array_equal(flat["x"], np.ones((4,)))


def test_checkpoint_sharding_large_leaf(fs, monkeypatch):
    import repro.checkpoint.manager as m
    monkeypatch.setattr(m, "MAX_CHUNK_BYTES", 1024)
    base = tempfile.mkdtemp() + "/ckpt"
    mgr = LSTCheckpointManager(fs, base, fmt="iceberg", sync_targets=())
    big = jnp.arange(4096, dtype=jnp.float32).reshape(64, 64)
    mgr.save(0, {"big": big})
    st = mgr.handle.snapshot()
    assert len(st.files) > 1                      # split into shards
    _, flat = mgr.restore(0)
    np.testing.assert_array_equal(flat["big"], np.asarray(big))


def test_gc_respects_translated_targets(fs):
    base = tempfile.mkdtemp() + "/ckpt"
    mgr = LSTCheckpointManager(fs, base, fmt="hudi",
                               sync_targets=("delta",), keep_last=1)
    for s in range(4):
        mgr.save(s, {"x": jnp.full((2,), s, jnp.float32)})
    # targets synced after each save -> gc may collect
    dropped = mgr.gc()
    assert dropped == [0, 1, 2]
    assert mgr.steps() == [3]
    # delta view (after the gc sync) also converges to step 3 only
    mgr.sync_now()
    t = LakeTable.open(fs, base, "delta")
    steps = {int(f.partition_values["step"]) for f in t.state().files.values()}
    assert steps == {3}


def test_gc_deferred_when_target_unsynced(fs, monkeypatch):
    base = tempfile.mkdtemp() + "/ckpt"
    mgr = LSTCheckpointManager(fs, base, fmt="hudi",
                               sync_targets=("delta",), keep_last=1)
    mgr.save(0, {"x": jnp.zeros((2,))})
    mgr.save(1, {"x": jnp.ones((2,))})
    # break the delta sync token by pretending sync never ran:
    # write extra commits without syncing
    monkeypatch.setattr(mgr, "sync_now", lambda: [])
    mgr.save(2, {"x": jnp.ones((2,))})
    assert mgr.gc() == []                         # deferred, not corrupted


# ------------------------------------------------------------ data pipeline
def test_loader_determinism_and_resume(fs):
    base = tempfile.mkdtemp() + "/corpus"
    write_synth_corpus(fs, base, fmt="delta", n_docs=16, pack_len=17,
                       vocab=64)
    l1 = LakeDataLoader(fs, base, "delta", batch_size=4, seq_len=16)
    batches1 = [l1.next_batch() for _ in range(3)]
    cursor = l1.state_dict()
    next1 = l1.next_batch()

    l2 = LakeDataLoader(fs, base, "delta", batch_size=4, seq_len=16)
    batches2 = [l2.next_batch() for _ in range(3)]
    for a, b in zip(batches1, batches2):
        np.testing.assert_array_equal(a["inputs"], b["inputs"])
    l3 = LakeDataLoader(fs, base, "delta", batch_size=4, seq_len=16)
    l3.load_state_dict(cursor)
    np.testing.assert_array_equal(l3.next_batch()["inputs"], next1["inputs"])


def test_loader_multi_host_striping(fs):
    base = tempfile.mkdtemp() + "/corpus"
    write_synth_corpus(fs, base, fmt="iceberg", n_docs=16, pack_len=17,
                       vocab=64)
    rows = []
    for host in range(2):
        ld = LakeDataLoader(fs, base, "iceberg", batch_size=4, seq_len=16,
                            host_id=host, n_hosts=2, loop=False)
        b = ld.next_batch()
        rows.append(b["inputs"][:, 0])
    # hosts see disjoint rows
    assert not set(map(tuple, rows[0][:, None])) & \
        set(map(tuple, rows[1][:, None]))


def test_loader_reads_any_format_after_sync(fs):
    """Write corpus as hudi, sync, read as delta — single copy of data."""
    from repro.core import SyncConfig, run_sync
    base = tempfile.mkdtemp() + "/corpus"
    write_synth_corpus(fs, base, fmt="hudi", n_docs=8, pack_len=17, vocab=64)
    run_sync(SyncConfig.from_dict({
        "sourceFormat": "HUDI", "targetFormats": ["DELTA"],
        "datasets": [{"tableBasePath": base}]}), fs)
    lh = LakeDataLoader(fs, base, "hudi", batch_size=2, seq_len=16)
    ld = LakeDataLoader(fs, base, "delta", batch_size=2, seq_len=16)
    np.testing.assert_array_equal(lh.next_batch()["inputs"],
                                  ld.next_batch()["inputs"])


def test_loader_prefetch_thread(fs):
    base = tempfile.mkdtemp() + "/corpus"
    write_synth_corpus(fs, base, fmt="delta", n_docs=8, pack_len=17, vocab=64)
    ld = LakeDataLoader(fs, base, "delta", batch_size=2, seq_len=16,
                        prefetch=2).start()
    b1 = ld.get()
    b2 = ld.get()
    assert b1["inputs"].shape == (2, 16)
    assert b2["cursor"] > b1["cursor"]
    ld.stop()
