"""Sharded sync fleet: scheduler, sharding, stealing, daemon integration.

What this file pins (all on a fake clock — no test ever wall-sleeps,
except the event-gated stall test, which is timeout-guarded):

* the ``fleet:`` config block parses its camelCase keys and validates its
  knobs;
* the commit-rate EWMA is a deterministic function of the observation
  trace (first sighting, decay blend, quiet-table halving, decayed reads);
* the urgency scheduler orders cells backlog x rate with lexicographic
  tie-breaks, FULL bootstraps rank by rate alone, and FIFO preserves plan
  order;
* hash sharding is stable (same cell -> same shard, across fleets);
  round-robin cycles uniformly;
* an idle fleet cycle costs exactly ONE head probe per table at ANY
  worker count — the serial daemon's cost pin survives the fan-out;
* a fleet cycle reaches the same end state as the serial daemon (same
  commits applied, targets at the same head);
* ``maxUnitsPerCycle`` defers the surplus (reported, counted as lag) and
  the deferred tables drain on later cycles;
* a worker stalled on a throttled store gets its queued cells stolen by
  the rest of the fleet instead of idling it;
* under a drain budget the urgency scheduler keeps a hot table fresh
  while FIFO lets cold tables crowd it out.
"""

import threading

import numpy as np
import pytest

from repro.core import (FleetOptions, LagAwareScheduler, ManualClock,
                        SyncConfig, SyncDaemon)
from repro.core.fleet import CommitRateEstimator, SyncFleet, _ShardQueue, _Cell
from repro.core.plan import FULL, INCREMENTAL, SyncUnit
from repro.core.targets import make_target
from repro.lst import LakeTable
from repro.lst.schema import Field, PartitionSpec, Schema
from repro.lst.storage import MemoryFS, layer_fs
from repro.lst.table import FORMATS

SCHEMA = Schema([Field("k", "int64"), Field("part", "string")])


def _mk_table(fs, base, fmt="delta", n_commits=3):
    t = LakeTable.create(fs, base, SCHEMA, fmt, PartitionSpec(["part"]),
                         {"delta.checkpointInterval": "100000"})
    for i in range(n_commits):
        t.append({"k": np.array([i, i + 100], np.int64),
                  "part": np.array([f"p{i % 2}", "p0"])})
    return t


def _append(t, k=1):
    for i in range(k):
        t.append({"k": np.array([7 + i], np.int64),
                  "part": np.array(["p0"])})


def _cfg(bases, targets=("iceberg",), **kw):
    d = {"sourceFormat": "DELTA",
         "targetFormats": [t.upper() for t in targets],
         "datasets": [{"tableBasePath": b} for b in bases]}
    d.update(kw)
    return SyncConfig.from_dict(d)


def _unit(ds, base, backlog=0, mode=INCREMENTAL, target="iceberg"):
    commits = [str(i) for i in range(backlog)] if mode == INCREMENTAL else []
    return SyncUnit(dataset=ds, base_path=base, source_format="delta",
                    target_format=target, mode=mode, source_head="h",
                    commits=commits, backlog=backlog)


# ------------------------------------------------------------------- config
def test_fleet_config_block_parses_camelcase_keys():
    cfg = SyncConfig.from_dict({
        "sourceFormat": "DELTA", "targetFormats": ["HUDI"],
        "datasets": [{"tableBasePath": "bkt/t"}],
        "fleet": {"workers": 4, "shardStrategy": "roundRobin",
                  "stealThresholdMs": 250, "urgencyHalfLifeMs": 30000,
                  "scheduler": "fifo", "maxUnitsPerCycle": 100,
                  "mode": "thread"}})
    f = cfg.fleet
    assert f.workers == 4
    assert f.shard_strategy == "round_robin"     # camelCase normalized
    assert f.steal_threshold_ms == 250.0
    assert f.urgency_half_life_ms == 30000.0
    assert f.scheduler == "fifo"
    assert f.max_units_per_cycle == 100
    # defaults: serial, hash-sharded, urgency-ordered, unbounded, threads
    d = SyncConfig.from_dict({
        "sourceFormat": "DELTA", "targetFormats": ["HUDI"],
        "datasets": [{"tableBasePath": "bkt/t"}]}).fleet
    assert (d.workers, d.shard_strategy, d.scheduler, d.mode) == \
        (1, "hash", "urgency", "thread")
    assert d.max_units_per_cycle is None


@pytest.mark.parametrize("bad", [
    {"workers": 0}, {"workers": -2}, {"shardStrategy": "random"},
    {"stealThresholdMs": -1}, {"urgencyHalfLifeMs": 0},
    {"scheduler": "lifo"}, {"maxUnitsPerCycle": 0}, {"mode": "fiber"}])
def test_fleet_config_rejects_bad_knobs(bad):
    with pytest.raises(ValueError):
        SyncConfig.from_dict({
            "sourceFormat": "DELTA", "targetFormats": ["HUDI"],
            "datasets": [{"tableBasePath": "bkt/t"}], "fleet": bad})


def test_process_mode_requires_local_storage():
    raw = MemoryFS()
    _mk_table(raw, "bkt/t")
    with pytest.raises(ValueError, match="local storage"):
        SyncDaemon(_cfg(["bkt/t"]), layer_fs(raw), clock=ManualClock(),
                   fleet=FleetOptions(workers=2, mode="process"))


# ---------------------------------------------------------------- estimator
def test_ewma_first_sighting_and_decay_blend():
    est = CommitRateEstimator(half_life_s=10.0)
    assert est.rate("t", now=0.0) == 0.0           # unseen
    assert est.observe("t", 4, now=0.0) == 4.0     # first sighting: the burst
    # 10s later (one half-life): old rate halves, instantaneous 2/10 blends
    r = est.observe("t", 2, now=10.0)
    assert r == pytest.approx(0.5 * 4.0 + 0.5 * (2 / 10.0))
    # a decayed *read* halves again after another half-life, observing nothing
    assert est.rate("t", now=20.0) == pytest.approx(r / 2)


def test_ewma_is_deterministic_and_guards_zero_dt():
    trace = [("a", 3, 0.0), ("b", 1, 0.0), ("a", 0, 5.0), ("a", 7, 5.0)]

    def run():
        est = CommitRateEstimator(half_life_s=60.0)
        return [est.observe(k, c, t) for k, c, t in trace]

    assert run() == run()                          # pure function of the trace
    # two observations on the same ManualClock reading must not divide by 0
    est = CommitRateEstimator(half_life_s=60.0)
    est.observe("t", 1, now=0.0)
    assert np.isfinite(est.observe("t", 1, now=0.0))


# ---------------------------------------------------------------- scheduler
def test_urgency_orders_backlog_times_rate_with_stable_ties():
    sched = LagAwareScheduler(half_life_s=60.0, kind="urgency")
    now = 0.0
    sched.observe("bkt/hot", 8, now)      # rate 8
    sched.observe("bkt/warm", 2, now)     # rate 2
    units = [_unit("cold", "bkt/cold", backlog=9),      # unseen: MIN_RATE
             _unit("warm", "bkt/warm", backlog=4),      # urgency 8
             _unit("hot", "bkt/hot", backlog=2),        # urgency 16
             _unit("boot", "bkt/boot", mode=FULL)]      # backlog floor 1
    got = [u.dataset for u in sched.order(units, now)]
    assert got == ["hot", "warm", "cold", "boot"]

    # ties break lexicographically on (dataset, target): deterministic
    tied = [_unit("b", "bkt/x", backlog=3), _unit("a", "bkt/x", backlog=3)]
    assert [u.dataset for u in sched.order(tied, now)] == ["a", "b"]
    assert [u.dataset for u in sched.order(list(reversed(tied)), now)] == \
        ["a", "b"]


def test_fifo_scheduler_preserves_plan_order():
    sched = LagAwareScheduler(half_life_s=60.0, kind="fifo")
    sched.observe("bkt/hot", 50, 0.0)
    units = [_unit("cold", "bkt/cold", backlog=1),
             _unit("hot", "bkt/hot", backlog=9)]
    assert [u.dataset for u in sched.order(units, 0.0)] == ["cold", "hot"]


# ----------------------------------------------------------------- sharding
def test_hash_sharding_is_stable_and_spreads():
    fleet = SyncFleet(FleetOptions(workers=4), ManualClock())
    units = [_unit(f"t{i}", f"bkt/t{i}", backlog=1) for i in range(64)]
    shards = [fleet.shard_of(u) for u in units]
    assert shards == [fleet.shard_of(u) for u in units]      # stable
    fleet2 = SyncFleet(FleetOptions(workers=4), ManualClock())
    assert shards == [fleet2.shard_of(u) for u in units]     # across fleets
    assert len(set(shards)) == 4                             # all shards used
    # a table's two targets may land apart, but the same cell never moves
    u_ice = _unit("t0", "bkt/t0", backlog=1, target="iceberg")
    assert fleet.shard_of(u_ice) == fleet2.shard_of(u_ice)
    fleet.close(), fleet2.close()


def test_round_robin_sharding_cycles():
    fleet = SyncFleet(FleetOptions(workers=3, shard_strategy="round_robin"),
                      ManualClock())
    units = [_unit(f"t{i}", f"bkt/t{i}", backlog=1) for i in range(7)]
    assert [fleet.shard_of(u) for u in units] == [0, 1, 2, 0, 1, 2, 0]
    fleet.close()


def test_steal_threshold_protects_fresh_cells():
    q = _ShardQueue()
    q.push(_Cell(0, _unit("a", "bkt/a"), enqueued_at=100.0))
    assert q.steal_back(now=100.05, threshold_s=0.25) is None   # too fresh
    assert q.steal_back(now=100.30, threshold_s=0.25) is not None


# --------------------------------------------------------- daemon: cost pins
@pytest.mark.parametrize("workers", [1, 2, 4])
def test_fleet_idle_cycle_costs_one_probe_per_table(workers):
    """The serial daemon's idle-cost pin survives the fan-out: a quiet
    fleet cycle is exactly one head probe per table — no planning reads,
    no target reads — at any worker count."""
    raw = MemoryFS()
    bases = [f"bkt/t{i}" for i in range(6)]
    for b in bases:
        _mk_table(raw, b)
    fs = layer_fs(raw)
    daemon = SyncDaemon(_cfg(bases), fs, clock=ManualClock(),
                        fleet=FleetOptions(workers=workers))
    try:
        rep0 = daemon.run_cycle()                  # bootstrap: 6 FULL syncs
        assert rep0.units_drained == 6 and rep0.workers == workers
        for _ in range(3):
            rep = daemon.run_cycle()
            assert rep.idle and rep.quiet == 6 and rep.probed == 6
            ops = rep.storage_ops
            assert ops["list"] == 6                # one log-tail LIST each
            assert ops["get"] == 0 and ops["head"] == 0
            assert ops["put"] == 0 and ops["requests"] == 6
    finally:
        daemon.close()


def test_fleet_cycle_matches_serial_end_state():
    """Same workload through the serial daemon and a 3-worker fleet: same
    units drained, same commits applied, and every target lands on the
    same source head."""
    def run(workers):
        raw = MemoryFS()
        bases = [f"bkt/t{i}" for i in range(5)]
        tables = [_mk_table(raw, b, n_commits=2) for b in bases]
        daemon = SyncDaemon(_cfg(bases, targets=("iceberg", "hudi")),
                            layer_fs(raw), clock=ManualClock(),
                            fleet=FleetOptions(workers=workers))
        try:
            rep0 = daemon.run_cycle()
            for i, t in enumerate(tables):
                _append(t, i + 1)                  # uneven backlogs
            rep1 = daemon.run_cycle()
        finally:
            daemon.close()
        heads = {b: make_target("iceberg", raw, b).get_sync_token()
                 for b in bases}
        src_heads = {b: FORMATS["delta"].open(raw, b).head() for b in bases}
        assert heads == src_heads                  # every target caught up
        return (rep0.units_drained, rep1.units_drained,
                rep1.commits_applied, rep1.total_lag, heads)

    assert run(1) == run(3)


def test_fleet_error_isolation_backs_off_one_table():
    """A table whose probe 503s is backed off without stalling the rest —
    the serial daemon's isolation contract, through the fan-out path."""
    from repro.lst.storage import TransientStorageError

    class _Flaky:
        def __init__(self, inner, match):
            self.inner, self.match, self.armed = inner, match, False

        def __getattr__(self, name):
            fn = getattr(self.inner, name)
            if not callable(fn):
                return fn

            def wrapped(*args, **kw):
                if self.armed and args and isinstance(args[0], str) \
                        and self.match in args[0]:
                    raise TransientStorageError(f"503 ({args[0]})")
                return fn(*args, **kw)
            return wrapped

    raw = MemoryFS()
    t0, t1 = _mk_table(raw, "bkt/t0"), _mk_table(raw, "bkt/t1")
    flaky = _Flaky(raw, "bkt/t0")
    daemon = SyncDaemon(_cfg(["bkt/t0", "bkt/t1"]), layer_fs(flaky),
                        clock=ManualClock(), fleet=FleetOptions(workers=2))
    try:
        daemon.run_cycle()
        flaky.armed = True
        _append(t0), _append(t1)
        rep = daemon.run_cycle()
        assert rep.table_errors == 1
        assert rep.units_drained == 1 and rep.commits_applied == 1
        rep = daemon.run_cycle()                   # t0 now inside its window
        assert rep.backed_off == 1 and rep.probed == 1
    finally:
        daemon.close()


# -------------------------------------------------------------- drain budget
def test_max_units_per_cycle_defers_and_later_cycles_finish():
    raw = MemoryFS()
    bases = [f"bkt/t{i}" for i in range(6)]
    tables = [_mk_table(raw, b) for b in bases]
    daemon = SyncDaemon(_cfg(bases), layer_fs(raw), clock=ManualClock(),
                        fleet=FleetOptions(workers=2, max_units_per_cycle=4))
    try:
        rep0 = daemon.run_cycle()                  # bootstrap is budgeted too
        assert rep0.units_drained == 4 and rep0.units_deferred == 2
        rep1 = daemon.run_cycle()                  # deferred tables stay pending
        assert rep1.units_drained == 2 and rep1.units_deferred == 0
        assert daemon.run_cycle().idle

        for t in tables:
            _append(t, 2)
        rep = daemon.run_cycle()
        assert rep.units_drained == 4 and rep.units_deferred == 2
        assert rep.commits_applied == 8
        assert rep.total_lag == 4                  # 2 deferred x 2 commits
        rep = daemon.run_cycle()
        assert rep.units_drained == 2 and rep.commits_applied == 4
        assert rep.total_lag == 0
    finally:
        daemon.close()
    for b in bases:                                # nothing lost to deferral
        assert make_target("iceberg", raw, b).get_sync_token() == \
            FORMATS["delta"].open(raw, b).head()


# ------------------------------------------------------------- work stealing
def test_stalled_worker_gets_its_queue_stolen():
    """Worker 0 stalls on its first cell (event-gated, as a throttled
    store would); worker 1 finishes its own shard and steals the rest of
    worker 0's queue instead of idling.  The stall releases only after
    every other cell completed — so without stealing this would deadlock
    (timeout-guarded)."""
    opts = FleetOptions(workers=2, shard_strategy="round_robin")
    fleet = SyncFleet(opts, ManualClock())
    units = [_unit(f"t{i}", f"bkt/t{i}", backlog=1) for i in range(6)]
    # round-robin: evens -> shard 0, odds -> shard 1; unit 0 is the stall
    stall = threading.Event()
    done = []
    lock = threading.Lock()

    class _Executor:
        def execute_unit(self, unit):
            if unit.dataset == "t0":
                assert stall.wait(timeout=30.0), "stall never released"
            with lock:
                done.append(unit.dataset)
                if len(done) == len(units) - 1:
                    stall.set()                    # everyone else finished
            return unit.dataset

    try:
        out = fleet.drain(units, _Executor())
    finally:
        fleet.close()
    assert out.results == [u.dataset for u in units]   # aligned, complete
    assert out.deferred == []
    # worker 1's own shard was 3 cells; it stole worker 0's queued tail
    # (t4, t2 — and t0 itself if worker 0 was slow to start) while t0's
    # stall blocked its home shard
    assert out.steals >= 2
    assert done[-1] == "t0"


# ---------------------------------------------------- urgency vs FIFO (pin)
@pytest.mark.parametrize("workers", [1, 2])
@pytest.mark.parametrize("kind,hot_lag_stays_zero", [("urgency", True),
                                                     ("fifo", False)])
def test_urgency_keeps_hot_table_fresh_under_budget(kind, hot_lag_stays_zero,
                                                    workers):
    """8 tables, drain budget 2/cycle, one hot table (4 commits/round,
    listed LAST in the config so FIFO cannot luck into it).  The urgency
    scheduler drains the hot table every cycle; FIFO spends the budget on
    the cold tables in plan order and the hot table starves.  Holds at
    any worker count: the budget trims to the top cells of the *global*
    ordering before sharding, so which cells drain is a pure function of
    the scheduler — never of thread timing or shard placement."""
    raw = MemoryFS()
    cold_bases = [f"bkt/c{i}" for i in range(7)]
    cold = [_mk_table(raw, b) for b in cold_bases]
    hot = _mk_table(raw, "bkt/hot")
    bases = cold_bases + ["bkt/hot"]
    clock = ManualClock()
    daemon = SyncDaemon(_cfg(bases), layer_fs(raw), clock=clock,
                        fleet=FleetOptions(workers=workers, scheduler=kind,
                                           max_units_per_cycle=2))
    try:
        for _ in range(8):                          # budgeted bootstrap
            if daemon.run_cycle().idle:
                break
        else:
            pytest.fail("bootstrap never went idle")
        hot_lag = 0
        for _ in range(3):
            for t in cold:
                _append(t, 1)
            _append(hot, 4)
            rep = daemon.run_cycle()
            assert rep.units_drained == 2
            hot_lag = rep.lag.get(("hot", "iceberg"), 0)
            clock.advance(1.0)
    finally:
        daemon.close()
    if hot_lag_stays_zero:
        assert hot_lag == 0                         # drained every cycle
    else:
        assert hot_lag >= 8                         # starved by cold tables


# ------------------------------------------------------------- process mode
@pytest.mark.slow
def test_process_mode_drains_full_bootstraps(tmp_path):
    """FULL bootstraps route through the process pool on local storage and
    land the same result; incremental cells stay on the worker threads."""
    import tempfile

    from repro.lst import LocalFS

    fs = LocalFS()
    bases = []
    for i in range(2):
        base = tempfile.mkdtemp(dir=tmp_path) + "/t"
        _mk_table(fs, base)
        bases.append(base)
    daemon = SyncDaemon(_cfg(bases), fs, clock=ManualClock(),
                        fleet=FleetOptions(workers=2, mode="process"))
    try:
        rep = daemon.run_cycle()
        assert rep.units_drained == 2
        assert all(r.mode == "FULL" for r in rep.results)
        for b in bases:
            assert make_target("iceberg", fs, b).get_sync_token() == \
                FORMATS["delta"].open(fs, b).head()
    finally:
        daemon.close()


# ------------------------------------------------------ bench-backed (slow)
@pytest.mark.slow
def test_fleet_scales_and_urgency_beats_fifo_at_1k_tables():
    """The headline numbers, conservatively: draining a tiered backlog
    across 1000 single-target tables behind a 0.5ms-RTT store scales
    >= 2x from 1 to 4 workers, and at equal width the urgency scheduler's
    hot-tier p99 lag never exceeds FIFO's."""
    import time

    from repro.lst.storage import RetryPolicy, StorageProfile

    n = 1000
    raw = MemoryFS()
    rng = np.random.default_rng(0)
    tables = []
    for i in range(n):
        base = f"bkt/t{i:04d}"
        t = LakeTable.create(raw, base, SCHEMA, "delta",
                             PartitionSpec(["part"]),
                             {"delta.checkpointInterval": "100000"})
        t.append({"k": np.array([i], np.int64), "part": np.array(["p0"])})
        tables.append((base, t))
    cfg = _cfg([b for b, _ in tables], maxCommitsPerSync=4)
    from repro.core import run_sync
    res = run_sync(cfg, layer_fs(raw))
    assert all(r.ok and r.mode == "FULL" for r in res)
    for i, (_, t) in enumerate(tables):
        _append(t, 8 if i % 10 == 0 else (4 if i % 10 < 4 else 1))

    def one_cycle(workers, kind="urgency"):
        fs = layer_fs(raw.clone(),
                      profile=StorageProfile(rtt_ms=0.5, pipeline_depth=16),
                      retry=RetryPolicy())
        daemon = SyncDaemon(cfg, fs, clock=ManualClock(),
                            fleet=FleetOptions(workers=workers,
                                               scheduler=kind))
        t0 = time.perf_counter()
        rep = daemon.run_cycle()
        dt = time.perf_counter() - t0
        daemon.close()
        assert rep.units_drained == n, rep.summary()
        hot = [rep.lag.get((f"t{i:04d}", "iceberg"), 0)
               for i in range(0, n, 10)]
        return dt, sorted(hot)[int(0.99 * (len(hot) - 1))]

    dt1, p99_1 = one_cycle(1)
    dt4, p99_u = one_cycle(4)
    _, p99_f = one_cycle(4, kind="fifo")
    assert dt1 / dt4 >= 2.0, (dt1, dt4)
    # one un-budgeted cycle caps every hot table at maxCommitsPerSync: the
    # remaining hot lag must be identical across widths and schedulers
    assert p99_1 == p99_u == p99_f == 4


# --------------------------------------------------------------- drain stop
def test_fleet_stop_drain_finishes_backlog_without_losing_cells():
    """``stop(drain=True)`` under fleet mode: a multi-cycle backlog — capped
    by BOTH maxCommitsPerSync and a drain budget that defers cells every
    cycle — must fully drain before the fleet stops, with no cell lost to
    a deferral raced against the stop."""
    raw = MemoryFS()
    bases = [f"bkt/t{i}" for i in range(3)]
    tables = [_mk_table(raw, b, n_commits=6) for b in bases]
    cfg = _cfg(bases, targets=("iceberg", "hudi"), maxCommitsPerSync=1)
    daemon = SyncDaemon(cfg, layer_fs(raw), clock=ManualClock(),
                        fleet=FleetOptions(workers=3, max_units_per_cycle=2))
    try:
        rep = daemon.run_cycle()             # budget: 2 of 6 cells ran
        assert rep.units_deferred == 4 and daemon._pending()
        daemon.stop(drain=True)
        reports = daemon.run()               # keeps cycling past the stop
        assert len(reports) > 1              # ... for as long as it must
        assert not daemon._pending()
        assert sum(r.units_deferred for r in reports) > 0
    finally:
        daemon.close()
    for b, t in zip(bases, tables):
        head = FORMATS["delta"].open(raw, b).head()
        src = sorted(t.read_all()["k"].tolist())
        for fmt in ("iceberg", "hudi"):
            assert make_target(fmt, raw, b).get_sync_token() == head
            got = LakeTable.open(raw, b, fmt).read_all()
            assert sorted(got["k"].tolist()) == src
