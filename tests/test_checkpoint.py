"""Durable daemon checkpoints: crash-safe warm restarts.

What this file pins (all on a fake clock — no test ever wall-sleeps):

* the checkpoint codecs round-trip real replayed ``CommitEntry`` /
  ``TableState`` objects byte-for-byte for all three formats;
* ``CheckpointStore`` generations are atomic conditional puts: racing
  writers take distinct generations, a corrupt newest generation falls
  back to the previous one, and retention prunes old generations;
* ``snapshot_seed`` / ``restore_seed`` rebuild a working index tail with
  ZERO storage reads, and a later ``refresh()`` replays only new commits;
* a seeded index whose anchor the live log no longer reaches (divergent
  rewrite) falls back to a full rebuild — never a wrong splice;
* a restarted daemon resumes from the checkpoint at O(new commits): its
  first-cycle request census is INDEPENDENT of history length, while a
  cold restart's census grows with it;
* the ``checkpoint:`` config block parses and validates.
"""

import json

import numpy as np
import pytest

from repro.core import ManualClock, SyncConfig, SyncDaemon
from repro.core.checkpoint import (CheckpointStore, decode_seed, encode_seed,
                                   entry_from_json, entry_to_json,
                                   state_from_json, state_to_json)
from repro.core.metadata_cache import TableMetadataIndex
from repro.core.targets import make_target
from repro.lst import LakeTable
from repro.lst.schema import Field, PartitionSpec, Schema
from repro.lst.storage import MemoryFS, PutIfAbsentError, layer_fs
from repro.lst.table import FORMATS

SCHEMA = Schema([Field("k", "int64"), Field("part", "string")])


def _mk_table(fs, base, fmt="delta", n_commits=3):
    t = LakeTable.create(fs, base, SCHEMA, fmt, PartitionSpec(["part"]),
                         {"delta.checkpointInterval": "100000"})
    for i in range(n_commits):
        t.append({"k": np.array([i, i + 100], np.int64),
                  "part": np.array([f"p{i % 2}", "p0"])})
    return t


def _append(t, k=1):
    for i in range(k):
        t.append({"k": np.array([7 + i], np.int64),
                  "part": np.array(["p0"])})


def _cfg(bases, src="delta", targets=("iceberg",), **kw):
    d = {"sourceFormat": src.upper(),
         "targetFormats": [t.upper() for t in targets],
         "datasets": [{"tableBasePath": b} for b in bases]}
    d.update(kw)
    return SyncConfig.from_dict(d)


# ------------------------------------------------------------------- codecs
@pytest.mark.parametrize("fmt", ["delta", "iceberg", "hudi"])
def test_codecs_round_trip_replayed_entries_and_states(fmt):
    raw = MemoryFS()
    _mk_table(raw, "bkt/t", fmt, n_commits=3)
    handle = FORMATS[fmt].open(raw, "bkt/t")
    base, entries = handle.replay()

    for e in entries:
        blob = json.dumps(entry_to_json(e), sort_keys=True)
        assert entry_from_json(json.loads(blob)) == e

    st = handle.snapshot()
    blob = json.dumps(state_to_json(st), sort_keys=True)
    back = state_from_json(json.loads(blob))
    assert back.version == st.version and back.files == st.files
    assert back.schema == st.schema and back.properties == st.properties

    if base is not None:
        again = state_from_json(json.loads(
            json.dumps(state_to_json(base), sort_keys=True)))
        assert again == base


def test_seed_encode_decode_round_trip():
    raw = MemoryFS()
    _mk_table(raw, "bkt/t", "delta", n_commits=4)
    idx = TableMetadataIndex(FORMATS["delta"].open(raw, "bkt/t"))
    idx.ensure_built()
    seed = idx.snapshot_seed(2)
    assert seed is not None
    back = decode_seed(json.loads(json.dumps(encode_seed(seed))))
    assert back[0] == seed[0] and back[1] == seed[1]
    assert encode_seed(None) is None and decode_seed(None) is None


# ------------------------------------------------------------ durable store
def test_checkpoint_store_generations_and_retention():
    fs = MemoryFS()
    store = CheckpointStore(fs, "bkt/ck", retain=2)
    assert store.load() is None                       # cold start
    assert store.save({"n": 1}) == 1
    assert store.save({"n": 2}) == 2
    assert store.save({"n": 3}) == 3                  # gen 1 pruned
    assert fs.list_dir("bkt/ck") == ["gen-0000000002.json",
                                     "gen-0000000003.json"]
    gen, payload = CheckpointStore(fs, "bkt/ck").load()
    assert gen == 3 and payload["n"] == 3


def test_checkpoint_store_race_takes_distinct_generations():
    fs = MemoryFS()
    a = CheckpointStore(fs, "bkt/ck")
    b = CheckpointStore(fs, "bkt/ck")
    assert a.save({"who": "a"}) == 1
    # b never observed gen 1: its conditional put of gen 1 must LOSE and
    # land on gen 2 instead of clobbering a's document
    assert b.save({"who": "b"}) == 2
    gen, payload = CheckpointStore(fs, "bkt/ck").load()
    assert (gen, payload["who"]) == (2, "b")


def test_checkpoint_store_skips_corrupt_newest_generation():
    fs = MemoryFS()
    store = CheckpointStore(fs, "bkt/ck")
    store.save({"n": 1})
    # a crash mid-save leaves a torn newest generation
    fs.write_bytes("bkt/ck/gen-0000000002.json", b"{torn", overwrite=True)
    fresh = CheckpointStore(fs, "bkt/ck")
    gen, payload = fresh.load()
    assert (gen, payload["n"]) == (1, 1) and fresh.load_fallbacks == 1
    # ... and the next save goes PAST the torn generation, never under it
    assert fresh.save({"n": 3}) == 3


def test_checkpoint_store_put_is_conditional():
    fs = MemoryFS()
    CheckpointStore(fs, "bkt/ck").save({"n": 1})
    with pytest.raises(PutIfAbsentError):
        fs.write_bytes("bkt/ck/gen-0000000001.json", b"{}")


# ------------------------------------------------------------- index seeding
@pytest.mark.parametrize("fmt", ["delta", "iceberg", "hudi"])
def test_restore_seed_serves_states_with_zero_reads_then_tail_refresh(fmt):
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/t", fmt, n_commits=6)
    live = TableMetadataIndex(FORMATS[fmt].open(raw, "bkt/t"))
    live.ensure_built()
    head = live.state_at()
    seed = live.snapshot_seed(3)
    assert seed is not None and len(seed[1]) == 3

    fs = layer_fs(raw)
    idx = TableMetadataIndex(FORMATS[fmt].open(fs, "bkt/t"))
    assert idx.restore_seed(*seed)
    before = fs.stats().requests
    st = idx.state_at(seed[1][-1].version)    # head state from the seed...
    assert fs.stats().requests == before      # ...with ZERO storage reads
    assert st.files == head.files and st.version == head.version

    _append(t, 2)                             # the table moves on
    idx.probe()
    idx.refresh()
    idx.end_cycle()
    assert idx.replays == 0                   # tail-only: never a rebuild
    assert idx.tail_replays >= 1
    assert idx.state_at().total_records() == \
        live.handle.snapshot().total_records()


def test_restore_seed_refuses_live_index_and_empty_seed():
    raw = MemoryFS()
    _mk_table(raw, "bkt/t", "delta", 2)
    idx = TableMetadataIndex(FORMATS["delta"].open(raw, "bkt/t"))
    idx.ensure_built()
    seed = idx.snapshot_seed(1)
    assert not idx.restore_seed(*seed)        # already built: live wins
    fresh = TableMetadataIndex(FORMATS["delta"].open(raw, "bkt/t"))
    assert not fresh.restore_seed(seed[0], [])


def test_divergent_rewrite_forces_rebuild_not_wrong_splice():
    raw = MemoryFS()
    _mk_table(raw, "bkt/t", "delta", n_commits=5)
    live = TableMetadataIndex(FORMATS["delta"].open(raw, "bkt/t"))
    live.ensure_built()
    seed = live.snapshot_seed(2)

    # the table is torn down and rewritten SHORTER while the daemon is off:
    # the checkpointed anchor (commit 3) no longer exists
    for name in list(raw._objects):
        if name.startswith("bkt/t/"):
            raw.delete(name)
    _mk_table(raw, "bkt/t", "delta", n_commits=2)

    idx = TableMetadataIndex(FORMATS["delta"].open(raw, "bkt/t"))
    assert idx.restore_seed(*seed)
    idx.probe()
    idx.refresh()                             # live head behind the anchor
    idx.end_cycle()
    assert idx.replays == 1                   # full rebuild, by design
    assert idx.versions() == ["0", "1", "2"]  # ... to the REAL history
    assert idx.state_at().total_records() == 4


# -------------------------------------------------------- daemon warm restart
def _restart_census(n_commits, *, warm):
    """Request census of the first daemon cycle after a restart, with 2 new
    commits landed while the daemon was down."""
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/t", "delta", n_commits)
    cfg = _cfg(["bkt/t"], targets=("iceberg",),
               checkpoint={"enabled": True})
    d1 = SyncDaemon(cfg, layer_fs(raw), clock=ManualClock())
    rep = d1.run_cycle()                      # FULL bootstrap + checkpoint
    assert rep.units_drained == 1 and rep.checkpoint_gen == 1

    _append(t, 2)                             # lands while the daemon is dead
    cfg2 = cfg if warm else _cfg(["bkt/t"], targets=("iceberg",))
    d2 = SyncDaemon(cfg2, layer_fs(raw), clock=ManualClock())
    assert d2.restored_from_checkpoint is warm
    rep = d2.run_cycle()
    assert rep.units_drained == 1 and rep.commits_applied == 2
    return rep.storage_ops["requests"]


def test_warm_restart_is_o_new_commits_not_o_history():
    # the warm census is a function of the NEW commits only: growing the
    # history 8x must not move it by a single request
    warm_short = _restart_census(8, warm=True)
    warm_long = _restart_census(64, warm=True)
    assert warm_short == warm_long

    # while a cold restart rebuilds O(history) and grows with it
    cold_short = _restart_census(8, warm=False)
    cold_long = _restart_census(64, warm=False)
    assert cold_long > cold_short
    assert cold_long > 3 * warm_long


def test_restarted_daemon_converges_and_idles_cheaply():
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/t", "delta", 3)
    cfg = _cfg(["bkt/t"], targets=("iceberg", "hudi"),
               checkpoint={"enabled": True}, maxCommitsPerSync=2)
    d1 = SyncDaemon(cfg, layer_fs(raw), clock=ManualClock())
    d1.run_cycle()
    _append(t, 3)
    d1.run_cycle()                            # capped: leaves a backlog
    assert d1.lag() == {"bkt/t": True}

    # restart mid-backlog: the pending flag survives, so the first cycle
    # keeps draining even though the head token did not move again
    fs2 = layer_fs(raw)
    d2 = SyncDaemon(cfg, fs2, clock=ManualClock())
    assert d2.restored_from_checkpoint
    for _ in range(4):
        rep = d2.run_cycle()
        if rep.idle:
            break
    assert not d2._pending()
    src_rows = sorted(t.read_all()["k"].tolist())
    for fmt in ("iceberg", "hudi"):
        got = LakeTable.open(raw, "bkt/t", fmt).read_all()
        assert sorted(got["k"].tolist()) == src_rows
        assert make_target(fmt, raw, "bkt/t").get_sync_token() == "6"

    # a quiet restarted table costs exactly its head probe per cycle
    before = fs2.stats().requests
    rep = d2.run_cycle()
    assert rep.quiet == 1
    assert fs2.stats().requests - before == 1


def test_checkpoint_saves_are_skipped_on_idle_cycles():
    raw = MemoryFS()
    _mk_table(raw, "bkt/t", "delta", 2)
    cfg = _cfg(["bkt/t"], checkpoint={"enabled": True})
    d = SyncDaemon(cfg, layer_fs(raw), clock=ManualClock())
    assert d.run_cycle().checkpoint_gen == 1
    for _ in range(3):
        rep = d.run_cycle()
        assert rep.idle and rep.checkpoint_gen is None
    assert d._ckpt.saves == 1


def test_checkpoint_config_block_parses_and_validates():
    cfg = _cfg(["bkt/t"], checkpoint={
        "enabled": True, "path": "bkt/ck", "intervalCycles": 2,
        "retain": 5, "minWindow": 8})
    ck = cfg.checkpoint
    assert ck.enabled and ck.path == "bkt/ck" and ck.interval_cycles == 2
    assert ck.retain == 5 and ck.min_window == 8
    assert not _cfg(["bkt/t"]).checkpoint.enabled
    with pytest.raises(ValueError):
        _cfg(["bkt/t"], checkpoint={"retain": 0})
    d = SyncDaemon(cfg, layer_fs(MemoryFS()), clock=ManualClock())
    assert d._ckpt.base_path == "bkt/ck"


def test_corrupt_checkpoint_degrades_to_cold_start():
    raw = MemoryFS()
    _mk_table(raw, "bkt/t", "delta", 2)
    cfg = _cfg(["bkt/t"], checkpoint={"enabled": True})
    d1 = SyncDaemon(cfg, layer_fs(raw), clock=ManualClock())
    d1.run_cycle()
    # poison the payload *content* (valid JSON, wrong shapes)
    path = d1._ckpt._path(1)
    raw.write_bytes(path, json.dumps(
        {"version": 1, "sourceFormat": "delta",
         "tables": {"bkt/t": {"watch": {"token": "1"},
                              "seed": {"base": {"bogus": 1},
                                       "entries": []}}}}).encode(),
        overwrite=True)
    d2 = SyncDaemon(cfg, layer_fs(raw), clock=ManualClock())
    assert not d2.restored_from_checkpoint
    rep = d2.run_cycle()                      # cold, but correct
    assert rep.table_errors == 0 and rep.quiet + rep.changed == 1
