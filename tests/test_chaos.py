"""Deterministic crash-point chaos campaign.

A :class:`CrashSchedule` armed on a :class:`SimulatedObjectStore` kills the
"process" at an exact 1-based request index — every later request dies too,
so the store is left holding exactly the applied prefix of the request
stream, like SIGKILL would.  The campaign here:

1. records a *golden* run — a daemon draining a 4-commit backlog into the
   target formats over an unarmed store — and its total request count R;
2. for EVERY request index n in 1..R, replays the same drain on a fresh
   clone of the pre-drain store with a crash armed at n, confirms the
   crash fires, then restarts a fresh daemon (checkpoint restore + live
   head re-verification) over the survivor store and drives it to idle;
3. asserts the recovered targets converge to the golden rows and sync
   token — for every crash point, for all three target formats.

``after_apply=True`` schedules are the torn-write variant: the fatal PUT
*lands* but the caller dies before the response — covering the
crash-between-staged-flush-and-commit-point and crash-after-commit-point
windows explicitly.

Everything runs on ``pipeline_depth=1`` + a manual clock, so the request
stream is fully serial and the sweep is deterministic request-for-request.
"""

import numpy as np
import pytest

from repro.core import ManualClock, SyncConfig, SyncDaemon
from repro.core.targets import make_target
from repro.lst import LakeTable
from repro.lst.schema import Field, PartitionSpec, Schema
from repro.lst.storage import (CrashSchedule, MemoryFS, SimulatedCrash,
                               SimulatedObjectStore, StorageProfile, layer_fs)

SCHEMA = Schema([Field("k", "int64"), Field("part", "string")])


def _mk_table(fs, base, fmt="delta", n_commits=3):
    t = LakeTable.create(fs, base, SCHEMA, fmt, PartitionSpec(["part"]),
                         {"delta.checkpointInterval": "100000"})
    for i in range(n_commits):
        t.append({"k": np.array([i, i + 100], np.int64),
                  "part": np.array([f"p{i % 2}", "p0"])})
    return t


def _cfg(src, targets):
    return SyncConfig.from_dict({
        "sourceFormat": src.upper(),
        "targetFormats": [t.upper() for t in targets],
        "datasets": [{"tableBasePath": "bkt/t"}],
        "maxCommitsPerSync": 2,          # the drain spans multiple cycles
        "checkpoint": {"enabled": True},
    })


def _serial_store(base):
    return SimulatedObjectStore(base.clone(),
                                StorageProfile(pipeline_depth=1))


def _drive_to_idle(cfg, sim, max_cycles=12):
    d = SyncDaemon(cfg, layer_fs(sim), clock=ManualClock())
    for _ in range(max_cycles):
        if d.run_cycle().idle:
            return d
    raise AssertionError("daemon never idled")


def _target_digest(fs, targets):
    """(rows, sync token) per target — the convergence fingerprint."""
    out = {}
    for fmt in targets:
        rows = LakeTable.open(fs, "bkt/t", fmt).read_all()
        key = sorted(zip(rows["k"].tolist(), rows["part"].tolist()))
        out[fmt] = (key, make_target(fmt, fs, "bkt/t").get_sync_token())
    return out


def _campaign_base(src, targets):
    """Pre-drain store: table pre-synced once (checkpoint gen 1 durable),
    then 4 fresh commits land while the daemon is 'down'."""
    raw = MemoryFS()
    t = _mk_table(raw, "bkt/t", src, n_commits=1)
    cfg = _cfg(src, targets)
    d = SyncDaemon(cfg, layer_fs(raw), clock=ManualClock())
    assert d.run_cycle().units_drained == len(targets)
    for i in range(4):
        t.append({"k": np.array([10 + i], np.int64),
                  "part": np.array(["p1"])})
    return raw, cfg


def _sweep(src, targets, *, after_apply=False):
    base, cfg = _campaign_base(src, targets)

    # golden arm: the same drain, no crash
    golden_sim = _serial_store(base)
    _drive_to_idle(cfg, golden_sim)
    golden = _target_digest(golden_sim.inner, targets)
    total = golden_sim.requests
    assert total > 30        # the sweep actually covers a real drain

    for n in range(1, total + 1):
        sim = _serial_store(base)
        sim.arm_crash(CrashSchedule(n, after_apply=after_apply))
        try:
            _drive_to_idle(cfg, sim)
            died = False
        except SimulatedCrash:
            died = True
        assert died and sim.crashed, f"crash at request {n} never fired"

        # restart over the survivor store: checkpoint restore + live-head
        # re-verification must converge to the golden state, byte for byte
        sim.arm_crash(None)
        recovered = SimulatedObjectStore(sim.inner,
                                         StorageProfile(pipeline_depth=1))
        _drive_to_idle(cfg, recovered)
        got = _target_digest(recovered.inner, targets)
        assert got == golden, f"divergence after crash at request {n}"
    return total


# ------------------------------------------------------------ schedule units
def test_crash_fires_at_exact_request_index():
    sim = SimulatedObjectStore(MemoryFS(), StorageProfile(pipeline_depth=1))
    sim.write_bytes("bkt/a", b"1")
    sim.arm_crash(CrashSchedule(3))           # counter keeps running: dies
    sim.read_bytes("bkt/a")                   # at global request 3
    with pytest.raises(SimulatedCrash):
        sim.read_bytes("bkt/a")
    assert sim.crashed
    # ... and the process STAYS dead: later requests die too
    with pytest.raises(SimulatedCrash):
        sim.exists("bkt/a")
    assert sim.requests == 4


def test_pre_apply_crash_leaves_no_object_torn_write_leaves_one():
    sim = SimulatedObjectStore(MemoryFS(), StorageProfile(pipeline_depth=1))
    sim.arm_crash(CrashSchedule(1))
    with pytest.raises(SimulatedCrash):
        sim.write_bytes("bkt/a", b"1")
    assert not sim.inner.exists("bkt/a")      # rejected before applying

    sim2 = SimulatedObjectStore(MemoryFS(), StorageProfile(pipeline_depth=1))
    sim2.arm_crash(CrashSchedule(1, after_apply=True))
    with pytest.raises(SimulatedCrash):
        sim2.write_bytes("bkt/a", b"1")
    assert sim2.inner.read_bytes("bkt/a") == b"1"   # landed, response lost


def test_disarm_resurrects_the_store():
    sim = SimulatedObjectStore(MemoryFS(), StorageProfile(pipeline_depth=1))
    sim.arm_crash(CrashSchedule(1))
    with pytest.raises(SimulatedCrash):
        sim.exists("x")
    sim.arm_crash(None)
    assert not sim.crashed and sim.exists("x") is False


def test_crash_rips_through_write_many_pipeline():
    sim = SimulatedObjectStore(MemoryFS(), StorageProfile(pipeline_depth=4))
    sim.arm_crash(CrashSchedule(3))
    with pytest.raises(SimulatedCrash):
        sim.write_many([(f"bkt/f{i}", b"x") for i in range(8)])
    # the applied prefix is bounded by the crash point
    assert len([p for p in range(8) if sim.inner.exists(f"bkt/f{p}")]) <= 2


def test_schedule_validates_index():
    with pytest.raises(ValueError):
        CrashSchedule(0)


# -------------------------------------------------------------- the campaign
def test_campaign_delta_to_iceberg_and_hudi_every_crash_point():
    total = _sweep("delta", ("iceberg", "hudi"))
    assert total > 50


def test_campaign_hudi_to_delta_every_crash_point():
    _sweep("hudi", ("delta",))


@pytest.mark.slow
def test_campaign_torn_writes_every_crash_point():
    # the after_apply variant: every PUT in the stream is also exercised as
    # a torn write (applied, response lost) — commit-point and staged-flush
    # objects land without their writer surviving to record them
    _sweep("delta", ("iceberg",), after_apply=True)
    _sweep("hudi", ("delta",), after_apply=True)
